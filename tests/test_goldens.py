"""Golden determinism regressions.

The determinism contract (DESIGN.md §3) says a (runtime, algorithm,
env, seed) tuple pins the ENTIRE training trajectory bit-for-bit. These
tests freeze that as data: sha256 digests of the 3-interval
reward/done stream and the final parameters for every
(host|mesh|sharded) x (a2c|ppo|vtrace) combination on catch, committed
in tests/goldens/determinism.json. A refactor that shifts a single bit
anywhere in the rollout/learner path fails here even if all
self-consistency tests still pass.

After an INTENTIONAL contract change, regenerate with:

    PYTHONPATH=src python -m pytest tests/test_goldens.py --update-goldens

and commit the diff (it IS the reviewable artifact of the change).

The sharded runtime is pinned to a 1-device mesh so the test is
runnable on any machine; since PR 9 the canonical tree-sum gradient
makes multi-device digests identical too (bit-exact across replica
counts — test_equivalence.py and test_batch_geometry.py pin that, and
CI's forced-2-device leg asserts golden-hash equality at
n_replicas ∈ {1, 2}).
"""
import hashlib
import json
import os

import numpy as np
import jax
import pytest

from repro import models
from repro.core import engine
from repro.core.engine import HTSConfig
from repro.envs import catch
from repro.optim import rmsprop

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "determinism.json")
RUNTIMES = ("host", "mesh", "sharded")
ALGORITHMS = ("a2c", "ppo", "vtrace")
INTERVALS = 3

_memo = {}


def _digest(tree) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(tree):
        arr = np.asarray(leaf)
        h.update(repr((str(arr.dtype), arr.shape)).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _run(runtime: str, algorithm: str) -> dict:
    if (runtime, algorithm) in _memo:
        return _memo[(runtime, algorithm)]
    env1 = catch.make()
    cfg = HTSConfig(alpha=4, n_envs=4, seed=3, algorithm=algorithm)
    policy = models.get_policy("mlp", env1)   # the obs-flattening MLP
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    papply = policy.apply
    kwargs = {}
    if runtime == "sharded":
        from jax.sharding import Mesh
        kwargs["mesh"] = Mesh(np.array(jax.devices()[:1]), ("data",))
    out = engine.make_runtime(runtime, env1, papply, params, opt, cfg,
                              **kwargs).run(INTERVALS)
    got = {"params": _digest(out.params),
           "stream": _digest([out.rewards, out.dones])}
    _memo[(runtime, algorithm)] = got
    return got


@pytest.mark.parametrize("algorithm", ALGORITHMS)
@pytest.mark.parametrize("runtime", RUNTIMES)
def test_golden_determinism(runtime, algorithm, request):
    key = f"{runtime}/{algorithm}/catch"
    got = _run(runtime, algorithm)
    if request.config.getoption("--update-goldens"):
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        goldens = {}
        if os.path.exists(GOLDEN_PATH):
            with open(GOLDEN_PATH) as f:
                goldens = json.load(f)
        goldens[key] = got
        with open(GOLDEN_PATH, "w") as f:
            json.dump(goldens, f, indent=1, sort_keys=True)
        pytest.skip(f"golden {key} rewritten")
    assert os.path.exists(GOLDEN_PATH), \
        "no goldens committed; generate with --update-goldens"
    with open(GOLDEN_PATH) as f:
        goldens = json.load(f)
    assert key in goldens, f"no golden for {key}; run --update-goldens"
    assert got == goldens[key], (
        f"{key} diverged from the committed golden — the determinism "
        f"contract shifted. If intentional, regenerate with "
        f"--update-goldens and commit the diff.")


@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_runtimes_agree_per_algorithm(algorithm):
    """host/mesh/sharded are one program under three concurrency models:
    their digests must agree with each other, independent of the
    committed goldens."""
    runs = {rt: _run(rt, algorithm) for rt in RUNTIMES}
    assert runs["host"] == runs["mesh"] == runs["sharded"], runs
