"""Runtime equivalence + determinism (paper's central properties)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import mesh_runtime
from repro.core.baselines import (AsyncConfig, async_init_carry,
                                  make_async_step, make_sync_step,
                                  sync_init_carry)
from repro.core.host_runtime import HostConfig, HostHTSRL
from repro.core.mesh_runtime import HTSConfig
from repro import models
from repro.envs import catch
from repro.envs.interfaces import vectorize
from repro.envs.steptime import StepTimeModel
from repro.optim import rmsprop


def _setup():
    env1 = catch.make()
    cfg = HTSConfig(alpha=5, n_envs=4, seed=3)
    policy = models.get_policy("mlp", env1)   # the obs-flattening MLP
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    return env1, cfg, policy.apply, params, opt


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_host_equals_mesh_bitexact():
    """The threaded (paper-faithful) runtime and the fused mesh step
    produce identical parameter trajectories."""
    env1, cfg, papply, params, opt = _setup()
    carry, _ = mesh_runtime.train(params, papply, vectorize(env1, 4), opt,
                                  cfg, n_intervals=4)
    host = HostHTSRL(env1, papply, params, opt, cfg, HostConfig(n_actors=2))
    out = host.run(3)
    assert _maxdiff(carry[0].params, out.state.params) == 0.0


def test_actor_count_determinism():
    """Paper Tab. 4: different actor counts -> identical results."""
    env1, cfg, papply, params, opt = _setup()
    outs = []
    for n_actors in (1, 2, 4):
        host = HostHTSRL(env1, papply, params, opt, cfg,
                         HostConfig(n_actors=n_actors))
        outs.append(host.run(3))
    assert _maxdiff(outs[0].params, outs[1].params) == 0.0
    assert _maxdiff(outs[0].params, outs[2].params) == 0.0
    np.testing.assert_array_equal(outs[0].rewards, outs[1].rewards)


def test_rerun_determinism():
    env1, cfg, papply, params, opt = _setup()
    a, _ = mesh_runtime.train(params, papply, vectorize(env1, 4), opt, cfg,
                              n_intervals=3)
    b, _ = mesh_runtime.train(params, papply, vectorize(env1, 4), opt, cfg,
                              n_intervals=3)
    assert _maxdiff(a[0].params, b[0].params) == 0.0


def test_hts_delay_is_one_sync_has_none():
    """HTS-RL rollout j uses theta_j while update j produces theta_{j+1}
    from interval j-1's data; sync baseline has no delay. Verify via the
    update rule on a quadratic toy."""
    env1, cfg, papply, params, opt = _setup()
    step = mesh_runtime.make_hts_step(papply, vectorize(env1, 4), opt, cfg)
    c = mesh_runtime.init_carry(params, opt, vectorize(env1, 4), cfg,
                                papply)
    c1, _ = step(c, None)
    # after j=0: update skipped, params unchanged, behavior snapshot same
    assert _maxdiff(c1[0].params, params) == 0.0
    c2, _ = step(c1, None)
    # after j=1: params moved, params_prev == theta_0? No: prev == theta_1's
    # predecessor theta_0 -> equals initial params
    assert _maxdiff(c2[0].params_prev, params) == 0.0
    assert _maxdiff(c2[0].params, params) > 0.0


def test_async_staleness_changes_training():
    env1, cfg, papply, params, opt = _setup()
    venv = vectorize(env1, 4)
    acfg = AsyncConfig(staleness=4, correction="none")
    astep = make_async_step(papply, venv, opt, cfg, acfg)
    ac = async_init_carry(params, opt, venv, cfg, acfg)
    sstep = make_sync_step(papply, venv, opt, cfg)
    sc = sync_init_carry(params, opt, venv, cfg)

    # 8 intervals: over the first few intervals the tiny rmsprop updates
    # can leave the stale policy sampling identical actions (identical
    # trajectories -> identical params); by interval ~5 the k=4 lag has
    # produced at least one different action and the runs split for good.
    @jax.jit
    def run_async(c):
        return jax.lax.scan(astep, c, None, length=8)

    @jax.jit
    def run_sync(c):
        return jax.lax.scan(sstep, c, None, length=8)

    (ap, *_), _ = run_async(ac)
    (sp, *_), _ = run_sync(sc)
    assert _maxdiff(ap, sp) > 0.0    # stale behavior policy diverges


def test_host_rejects_conflicting_config_forms():
    """host=HostConfig(...) plus HostConfig-field kwargs used to silently
    drop the kwargs — now a TypeError names the conflict."""
    env1, cfg, papply, params, opt = _setup()
    with pytest.raises(TypeError, match="n_actors"):
        HostHTSRL(env1, papply, params, opt, cfg,
                  host=HostConfig(n_actors=2), n_actors=8)
    # each form alone still works
    HostHTSRL(env1, papply, params, opt, cfg, host=HostConfig(n_actors=2))
    HostHTSRL(env1, papply, params, opt, cfg, n_actors=2)


def test_async_rejects_conflicting_config_forms():
    from repro.core.baselines import AsyncRuntime
    env1, cfg, papply, params, opt = _setup()
    with pytest.raises(TypeError, match="staleness"):
        AsyncRuntime(env1, papply, params, opt, cfg,
                     acfg=AsyncConfig(staleness=4), staleness=16)
    AsyncRuntime(env1, papply, params, opt, cfg, staleness=4)


# ------------------------------------------------ pool failure handling
class _BombTime(StepTimeModel):
    """A duration model that detonates in a worker thread at a chosen
    (id, index) — as step_time it kills an executor; as learner_time it
    kills the sim-learner thread."""

    def __init__(self, env_id, step):
        super().__init__()
        object.__setattr__(self, "env_id", env_id)
        object.__setattr__(self, "step", step)

    def sample(self, env_id, step, seed=0):
        if env_id == self.env_id and step >= self.step:
            raise RuntimeError("boom: simulated env failure")
        return 0.0


def test_executor_death_propagates_instead_of_hanging():
    """An executor thread dying mid-interval must fail run() loudly —
    with the worker's traceback — not leave the coordinator (and CI)
    blocked on the interval barrier forever."""
    env1, cfg, papply, params, opt = _setup()
    host = HostHTSRL(env1, papply, params, opt, cfg,
                     host=HostConfig(n_actors=2,
                                     step_time=_BombTime(2, 7)))
    with pytest.raises(RuntimeError) as ei:
        host.run(4)
    msg = str(ei.value)
    assert "worker thread died" in msg
    assert "boom: simulated env failure" in msg
    assert "worker thread traceback" in msg      # debuggable, not bare


def test_actor_death_propagates_instead_of_hanging():
    """Same contract for the actor/stepper pools: executors blocked on
    their action slots are unblocked by the shutdown sentinel, and the
    coordinator re-raises the original failure."""
    env1, cfg, papply, params, opt = _setup()
    host = HostHTSRL(env1, papply, params, opt, cfg,
                     host=HostConfig(n_actors=2))
    host._build()
    real = host._actor_fwd
    calls = []

    def dying_actor_fwd(*a, **k):
        calls.append(1)
        if len(calls) > 3:
            raise ValueError("actor fwd blew up")
        return real(*a, **k)

    host._actor_fwd = dying_actor_fwd
    try:
        with pytest.raises(RuntimeError, match="actor fwd blew up"):
            host.run(4)
    finally:
        host._actor_fwd = real
    # a later run on the SAME runtime recovers (pools respawn cleanly)
    out = host.run(2)
    assert out.steps == 2 * cfg.alpha * cfg.n_envs


def test_sim_learner_death_propagates_instead_of_hanging():
    """The simulated-learner thread dying (e.g. a user-supplied
    learner_time model raising) must not leave the coordinator parked on
    a pending gradient's ready gate forever — the release path wakes the
    gate and run() re-raises the worker failure."""
    env1, cfg, papply, params, opt = _setup()
    host = HostHTSRL(env1, papply, params, opt, cfg,
                     host=HostConfig(n_actors=2,
                                     learner_time=_BombTime(0, 2)))
    with pytest.raises(RuntimeError, match="boom: simulated env failure"):
        host.run(5)


def test_episode_returns_extraction():
    m = {"rewards": jnp.array([[[1.0, 0.0]], [[1.0, 1.0]]]),
         "dones": jnp.array([[[0.0, 1.0]], [[1.0, 0.0]]])}
    outs = mesh_runtime.episode_returns(m)
    got = np.asarray(outs)
    assert got[1, 0] == 2.0          # env0: 1+1 completed at t1
    assert got[0, 1] == 0.0          # env1: done at t0 with 0
