"""Deterministic fault injection + self-healing supervision (DESIGN.md
§11): the recovery contract, measured bit-exactly.

The load-bearing claim: a supervised fit under ANY FaultPlan — worker
thread deaths, env exceptions, learner divergence, corrupted
checkpoints — finishes with final parameters and an episode-return
stream EQUAL to the fault-free run's, because the supervisor restores a
``TrainState`` capsule and ``run_from`` is a bit-exact replay. Plus the
schedule machinery itself: events are validated eagerly, generated
plans are seed-deterministic, and every event fires at most once (a
transient fault — the replay after recovery proceeds cleanly).
"""
import numpy as np
import jax
import pytest

from repro import api, models
from repro.core import engine
from repro.core.engine import HTSConfig
from repro.core.trainer import Trainer
from repro.envs import catch
from repro.faults import (FaultEvent, FaultInjector, FaultPlan,
                          InjectedFault, SITES)
from repro.optim import rmsprop

N = 6          # intervals per fit
EVERY = 2      # checkpoint cadence


def _host(faults=None):
    env1 = catch.make()
    cfg = HTSConfig(alpha=4, n_envs=4, seed=3)
    policy = models.get_policy("mlp", env1)
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    return engine.make_runtime("host", env1, policy.apply, params, opt,
                               cfg, faults=faults)


def _fit(ckpt_dir, injector=None, n=N, every=EVERY):
    """One supervised host-runtime fit; runtime and trainer SHARE the
    injector (exactly how api.build threads one through a Session)."""
    rt = _host(faults=injector)
    return Trainer(rt, checkpoint_dir=str(ckpt_dir), ckpt_every=every,
                   faults=injector).fit(n)


def _assert_bitexact(got, want):
    for a, b in zip(jax.tree.leaves(got.params),
                    jax.tree.leaves(want.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(got.episode_returns,
                                  want.episode_returns)
    np.testing.assert_array_equal(got.rewards, want.rewards)


@pytest.fixture(scope="module")
def reference(tmp_path_factory):
    """The fault-free oracle every recovery test compares against."""
    return _fit(tmp_path_factory.mktemp("ref") / "ck")


# -------------------------------------------------------------- the plan
def test_event_validation_is_eager():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultEvent("gpu", 1)
    with pytest.raises(ValueError, match=">= 0"):
        FaultEvent("actor", -1)
    with pytest.raises(ValueError, match="supports kind"):
        FaultEvent("actor", 1, "nan")       # nan is learner-only
    with pytest.raises(ValueError, match="unknown fault event field"):
        FaultEvent.of({"site": "actor", "interval": 1, "when": "now"})
    with pytest.raises(ValueError, match="needs"):
        FaultEvent.of({"site": "actor"})
    # tuple and dict forms resolve the site's default kind
    assert FaultEvent.of(("learner", 3)).kind == "exc"
    assert FaultEvent.of({"site": "checkpoint", "interval": 2}).kind \
        == "truncate"


def test_plan_validation_and_canonical_roundtrip():
    with pytest.raises(ValueError, match="max_restarts"):
        FaultPlan(max_restarts=-1)
    with pytest.raises(ValueError, match="backoff_cap"):
        FaultPlan(backoff=1.0, backoff_cap=0.5)
    with pytest.raises(ValueError, match="unknown faults field"):
        FaultPlan.of({"budget": 3})
    plan = FaultPlan(events=(("stepper", 2), ("learner", 3, "nan")),
                     seed=9, max_restarts=2, backoff=0.01)
    assert FaultPlan.of(plan.canonical()) == plan


def test_generate_is_seed_deterministic():
    a = FaultPlan.generate(7, 8)
    assert a == FaultPlan.generate(7, 8)
    assert a != FaultPlan.generate(8, 8)
    assert all(1 <= e.interval < 8 and e.site in SITES for e in a.events)
    assert a.max_restarts == len(a.events)   # absorbs its own storm


# ---------------------------------------------------------- the injector
def test_events_fire_at_most_once():
    inj = FaultInjector(FaultPlan(events=(("stepper", 2),
                                          ("learner", 3, "nan"),
                                          ("stepper", 2))))
    assert inj.poll("stepper", 1) is None
    with pytest.raises(InjectedFault):       # exc kind raises at the site
        inj.fire("stepper", 2)
    ev = inj.fire("learner", 3)              # non-exc kinds are returned
    assert ev is not None and ev.kind == "nan"
    # the duplicate listing is a SECOND armed event (a persistent fault)
    with pytest.raises(InjectedFault):
        inj.fire("stepper", 2)
    assert inj.poll("stepper", 2) is None    # all spent
    assert not inj.armed and len(inj.fired) == 3


# ---------------------------------------------------- bit-exact recovery
@pytest.mark.parametrize("site,kind", [
    ("actor", ""), ("executor", ""), ("stepper", ""),
    ("env_step", ""), ("learner", "exc"), ("learner", "nan"),
])
def test_recovery_is_bitexact_per_site(tmp_path, reference, site, kind):
    """Kill each host-runtime site (or NaN the learner) mid-run: the
    supervisor restores the last capsule, replays, and the final params
    + episode-return + reward streams EQUAL the fault-free run's.
    Interval 2 sits inside the second segment, so the restore is from a
    real mid-run checkpoint, and (for kind=nan) the poisoned apply at
    j+K lands inside the same segment — caught by the finite check
    before the capsule could become durable."""
    inj = FaultInjector(FaultPlan(events=((site, 2, kind),),
                                  max_restarts=2, backoff=0.0,
                                  backoff_cap=0.0))
    rep = _fit(tmp_path / "ck", inj)
    assert rep.restarts == 1 and not inj.armed
    rec = rep.recoveries[0]
    assert set(rec) == {"failure", "restored_to", "backoff_s",
                        "restore_s"}
    assert rec["restored_to"] == 2 and rec["restore_s"] >= 0.0
    _assert_bitexact(rep, reference)


def test_corrupt_checkpoint_fallback_is_bitexact(tmp_path, reference):
    """checkpoint-site truncation + a later worker death: the recovery
    walk finds the newest checkpoint corrupt (CheckpointCorrupt), skips
    it loudly, and restores the one before — still bit-exact, because
    falling back further only means replaying more."""
    inj = FaultInjector(FaultPlan(events=(("checkpoint", 4, "truncate"),
                                          ("stepper", 5)),
                                  max_restarts=2, backoff=0.0,
                                  backoff_cap=0.0))
    rep = _fit(tmp_path / "ck", inj)
    assert rep.restarts == 1
    # step_4 was truncated, so the walk fell back to step_2
    assert rep.recoveries[0]["restored_to"] == 2
    _assert_bitexact(rep, reference)


def test_restart_budget_exhausted_reraises(tmp_path):
    """A persistent fault (the same event listed twice: it re-fires on
    the replay) exhausts max_restarts=1 and the second failure
    propagates — supervision is bounded, not a retry-forever loop."""
    inj = FaultInjector(FaultPlan(events=(("stepper", 2), ("stepper", 2)),
                                  max_restarts=1, backoff=0.0,
                                  backoff_cap=0.0))
    with pytest.raises(RuntimeError, match="injected fault"):
        _fit(tmp_path / "ck", inj)


def test_unsupervised_failure_propagates(tmp_path):
    """max_restarts=0 (the default plan): injection fires but nothing
    absorbs it — today's fail-loud semantics, unchanged."""
    inj = FaultInjector(FaultPlan(events=(("executor", 1),)))
    with pytest.raises(RuntimeError, match="injected fault"):
        _fit(tmp_path / "ck", inj)


def test_spec_driven_chaos_is_bitexact(tmp_path):
    """The whole surface end-to-end: a JSON-round-tripped ExperimentSpec
    carrying a 3-event storm (worker death, checkpoint truncation,
    a second worker death whose recovery must fall back PAST the
    corrupt capsule), built by api.build — one shared injector spans
    runtime pools and trainer — recovers bit-exactly vs the same spec
    with no faults block."""
    def spec(tag, faults):
        return api.ExperimentSpec(
            env="catch", policy="mlp",
            optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4}},
            algorithm="a2c", runtime="host",
            hts={"alpha": 4, "n_envs": 4, "seed": 3}, intervals=N,
            checkpoint={"dir": str(tmp_path / tag), "every": 1},
            faults=faults)

    chaos = spec("chaos", {
        "events": [{"site": "stepper", "interval": 2},
                   {"site": "checkpoint", "interval": 3,
                    "kind": "truncate"},
                   {"site": "executor", "interval": 3}],
        "max_restarts": 3, "backoff": 0.0, "backoff_cap": 0.0})
    chaos = api.loads(api.dumps(chaos))          # survives JSON round-trip
    rep = api.build(chaos).fit()
    clean = api.build(spec("clean", {})).fit()
    assert rep.restarts == 2
    # second recovery skipped the truncated step_3 and restored step_2
    assert rep.recoveries[1]["restored_to"] == 2
    _assert_bitexact(rep, clean)


def test_trivial_plan_adds_no_machinery():
    """An empty faults block builds no injector anywhere — the hot path
    stays exactly as wide as before this subsystem existed."""
    session = api.build(api.ExperimentSpec(
        env="catch", policy="mlp",
        optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4}},
        algorithm="a2c", runtime="host",
        hts={"alpha": 4, "n_envs": 4, "seed": 3}))
    assert session.faults is None
    assert session.runtime._faults is None
