"""Policy-as-a-service (repro.serve): the serving determinism contract.

The load-bearing claim mirrors the training executor discipline: a
request's sampling key is a pure function of (server seed, request
seed), and the dispatched program is row-independent, so the SAME
request yields the SAME action BIT-EXACTLY regardless of batch
composition, queue order, padding, or arrival timing. Plus the service
plumbing around it: the engine registry entry that refuses training,
Session.serve() loading checkpoint capsules from any runtime's format,
admission backpressure, and the fail-loud dispatcher discipline.
"""
import queue

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api, models
from repro.core import engine
from repro.core.engine import HTSConfig
from repro.core.rollout import actor_forward
from repro.core import determinism
from repro.envs import catch
from repro.optim import rmsprop
from repro.faults import FaultPlan
from repro.serve import (ActionResult, DeadlineExceeded, DispatcherError,
                         Overloaded, PolicyServer, ServeConfig,
                         ServerClosed)


def _setup(seed=3):
    env1 = catch.make()
    cfg = HTSConfig(alpha=5, n_envs=4, seed=seed)
    policy = models.get_policy("mlp", env1)
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    return env1, cfg, policy.apply, params, opt


def _server(max_batch=8, max_queue=64, timeout_ms=50.0, seed=3,
            faults=None, **serve_kw):
    env1, cfg, papply, params, opt = _setup(seed)
    _, obs0 = env1.reset(jax.random.key(0))
    srv = PolicyServer(papply, params, obs_like=np.asarray(obs0),
                       serve=ServeConfig(max_batch=max_batch,
                                         max_queue=max_queue,
                                         timeout_ms=timeout_ms,
                                         **serve_kw),
                       seed=seed, faults=faults)
    return srv, env1, papply, params


def _obs(env1, n, seed=0):
    _, obs = jax.vmap(env1.reset)(
        jax.random.split(jax.random.key(seed), n))
    return np.asarray(obs)


# -------------------------------------------------------- registry entry
def test_serve_is_registered_but_not_a_training_runtime():
    assert "serve" in engine.runtime_names()
    assert "serve" not in engine.training_runtime_names()
    assert set(engine.training_runtime_names()) < set(engine.runtime_names())


def test_serve_runtime_refuses_training_loudly():
    """run/state/run_from raise a TypeError that names the serving
    surface instead of pretending inference has interval semantics."""
    env1, cfg, papply, params, opt = _setup()
    rt = engine.make_runtime("serve", env1, papply, params, opt, cfg)
    for call in (lambda: rt.run(2), rt.state,
                 lambda: rt.run_from(None, 1)):
        with pytest.raises(TypeError, match="Session.serve"):
            call()


# ----------------------------------------------------------- determinism
def test_same_request_same_action_across_batch_compositions():
    """The contract: identical (obs, seed) requests get bit-identical
    answers whether dispatched alone or packed with 6 other requests.
    Batch compositions are staged by submitting to an UNSTARTED server
    (the queue accumulates until start())."""
    srv, env1, _, _ = _server(max_batch=8)
    obs = _obs(env1, 8)
    probe = (obs[0], 7)

    alone = srv.submit(*probe)
    srv.start()
    r_alone = alone.result(timeout=30)
    srv.stop()
    assert r_alone.batch_size == 1

    srv2, env1, _, _ = _server(max_batch=8)
    packed = srv2.submit(*probe)
    others = [srv2.submit(obs[i], seed=100 + i) for i in range(1, 7)]
    srv2.start()
    r_packed = packed.result(timeout=30)
    for f in others:
        f.result(timeout=30)
    srv2.stop()
    assert r_packed.batch_size == 7
    assert r_packed.action == r_alone.action
    assert r_packed.logprob == r_alone.logprob


def test_same_request_same_action_across_queue_orders():
    """Position in the dispatch slab is irrelevant: the same request
    first vs last in the queue answers identically."""
    srv, env1, _, _ = _server(max_batch=8)
    obs = _obs(env1, 4)
    reqs = [(obs[i], 11 * i) for i in range(4)]

    def roundtrip(order):
        srv, _, _, _ = _server(max_batch=8)
        futs = [srv.submit(*reqs[i]) for i in order]
        srv.start()
        out = {i: futs[k].result(timeout=30) for k, i in enumerate(order)}
        srv.stop()
        return out

    fwd = roundtrip([0, 1, 2, 3])
    rev = roundtrip([3, 2, 1, 0])
    for i in range(4):
        assert fwd[i].action == rev[i].action, i
        assert fwd[i].logprob == rev[i].logprob, i


def test_padding_rows_cannot_leak():
    """max_batch wildly larger than the occupancy (29 zero padding rows)
    answers bit-identically to a snug dispatch."""
    obs = None
    results = {}
    for B in (4, 32):
        srv, env1, _, _ = _server(max_batch=B)
        if obs is None:
            obs = _obs(env1, 3)
        futs = [srv.submit(obs[i], seed=5 + i) for i in range(3)]
        srv.start()
        results[B] = [f.result(timeout=30) for f in futs]
        srv.stop()
    for a, b in zip(results[4], results[32]):
        assert a.action == b.action
        assert a.logprob == b.logprob


def test_server_matches_direct_actor_forward():
    """The served answer IS the training hot path's answer: one
    actor_forward row under request_key, computed by hand."""
    srv, env1, papply, params = _server(max_batch=4, seed=3)
    obs = _obs(env1, 2)
    srv.start()
    got = [srv.act(obs[i], seed=40 + i) for i in range(2)]
    srv.stop()

    master = determinism.master_key(3)
    keys = jax.vmap(lambda s: determinism.request_key(master, s))(
        jnp.arange(40, 42))
    acts, logps = actor_forward(papply, params, jnp.asarray(obs), keys)
    for i in range(2):
        assert got[i].action == int(acts[i])
        assert got[i].logprob == float(logps[i])


# --------------------------------------------------------------- config
def test_serve_config_validates_eagerly():
    with pytest.raises(ValueError, match="max_batch"):
        ServeConfig(max_batch=0)
    with pytest.raises(ValueError, match="max_queue"):
        ServeConfig(max_queue=0)
    with pytest.raises(ValueError, match="timeout_ms"):
        ServeConfig(timeout_ms=0.0)
    with pytest.raises(ValueError):
        ServeConfig.of({"max_batch": 8, "burst": 2})   # unknown field


def test_spec_serve_block_validates_at_construction():
    """ServeConfig errors surface when the ExperimentSpec is built, not
    when a server finally starts."""
    with pytest.raises(ValueError, match="max_batch"):
        api.ExperimentSpec(
            env="catch", policy="mlp",
            optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4}},
            algorithm="a2c", runtime="serve",
            hts={"alpha": 4, "n_envs": 4, "seed": 0},
            serve={"max_batch": 0})


# ------------------------------------------------------------ admission
def test_overload_rejects_with_block_false():
    """At max_queue, block=False raises queue.Full and the rejection is
    counted; admitted requests still answer after start()."""
    srv, env1, _, _ = _server(max_batch=4, max_queue=2)
    obs = _obs(env1, 1)[0]
    f1 = srv.submit(obs, seed=0, block=False)
    f2 = srv.submit(obs, seed=1, block=False)
    with pytest.raises(queue.Full):
        srv.submit(obs, seed=2, block=False)
    srv.start()
    assert isinstance(f1.result(timeout=30), ActionResult)
    assert isinstance(f2.result(timeout=30), ActionResult)
    srv.stop()
    stats = srv.stats()
    assert stats["n_rejected"] == 1 and stats["n_requests"] == 2


def test_obs_shape_mismatch_raises():
    srv, env1, _, _ = _server()
    with pytest.raises(ValueError, match="obs shape"):
        srv.submit(np.zeros((3, 3), np.float32))


def test_stopped_server_refuses_new_requests():
    srv, env1, _, _ = _server()
    obs = _obs(env1, 1)[0]
    srv.start()
    assert srv.act(obs).batch_size >= 1
    srv.stop()
    with pytest.raises(ServerClosed):
        srv.submit(obs)


# ------------------------------------------------------- fail-loud loop
def test_dispatcher_death_fails_pending_and_future_requests():
    """A dispatcher crash must fail every pending future with the
    original error and poison subsequent submits — never hang clients
    on futures that cannot resolve."""
    srv, env1, _, _ = _server(max_batch=4)
    obs = _obs(env1, 1)[0]

    def boom(params, obs, seeds):
        raise RuntimeError("kaboom in dispatch")

    srv._program = boom
    fut = srv.submit(obs, seed=0)
    srv.start()
    with pytest.raises(RuntimeError, match="kaboom"):
        fut.result(timeout=30)
    srv._thread.join(timeout=30)
    assert srv.dead
    with pytest.raises(ServerClosed, match="died"):
        srv.submit(obs, seed=1)


# ------------------------------------------------- graceful degradation
def test_dispatcher_restart_keeps_health_green():
    """A dispatcher-site fault with max_restarts budget: only the
    in-flight batch is lost (typed DispatcherError, resubmission-safe),
    the thread survives, subsequent requests are answered, and the
    liveness probe stays ok throughout — the dispatcher kill is a blip,
    not an outage."""
    srv, env1, _, _ = _server(max_restarts=2, restart_backoff_ms=1.0,
                              faults=FaultPlan(events=(("dispatcher", 0),)))
    obs = _obs(env1, 1)[0]
    fut = srv.submit(obs, seed=0)          # will be in flight at kill
    srv.start()
    with pytest.raises(DispatcherError, match="in-place restart"):
        fut.result(timeout=30)
    # the server shrugged it off: still ready, still answering
    out = srv.act(obs, seed=0, timeout=30)
    assert isinstance(out, ActionResult)
    h = srv.health()
    assert h["ok"] and h["ready"] and h["restarts"] == 1 and not h["dead"]
    srv.stop()


def test_restart_budget_exhaustion_kills_server():
    """Persistent dispatcher faults (consecutive dispatch indices — the
    restarted loop's next dispatch dies again) beyond max_restarts: the
    server dies with the pre-existing fail-loud semantics."""
    srv, env1, _, _ = _server(
        max_restarts=1, restart_backoff_ms=1.0,
        faults=FaultPlan(events=(("dispatcher", 0), ("dispatcher", 1))))
    obs = _obs(env1, 1)[0]
    f0 = srv.submit(obs, seed=0)
    srv.start()
    with pytest.raises(DispatcherError):
        f0.result(timeout=30)              # kill 1: absorbed in place
    f1 = srv.submit(obs, seed=1)
    with pytest.raises(RuntimeError, match="injected fault"):
        f1.result(timeout=30)              # kill 2: budget spent, dead
    srv._thread.join(timeout=30)
    assert srv.dead and not srv.health()["ok"]
    with pytest.raises(ServerClosed, match="died"):
        srv.submit(obs, seed=2)


def test_deadline_sheds_stale_queued_requests():
    """deadline_ms measures admission -> dispatcher pickup: requests
    staged on an unstarted server go stale and are shed with a typed
    DeadlineExceeded at pickup, never served late silently."""
    import time
    srv, env1, _, _ = _server(deadline_ms=25.0)
    obs = _obs(env1, 1)[0]
    stale = srv.submit(obs, seed=0)
    time.sleep(0.2)                        # 200ms >> the 25ms deadline
    srv.start()
    with pytest.raises(DeadlineExceeded, match="deadline"):
        stale.result(timeout=30)
    # fresh requests on the running server make their deadline
    assert isinstance(srv.act(obs, seed=1, timeout=30), ActionResult)
    srv.stop()
    assert srv.stats()["n_deadline"] == 1


def test_close_fails_queued_requests_with_typed_error():
    """close() is the shedding teardown: admission stops NOW and every
    still-queued future resolves to ServerClosed — never a hang. (stop()
    remains the drain-everything variant, pinned elsewhere.)"""
    srv, env1, _, _ = _server()
    obs = _obs(env1, 1)[0]
    queued = [srv.submit(obs, seed=i) for i in range(3)]
    srv.close()                            # never started: all shed
    for f in queued:
        with pytest.raises(ServerClosed, match="closed"):
            f.result(timeout=5)
    with pytest.raises(ServerClosed):
        srv.submit(obs, seed=9)
    srv.close()                            # idempotent


def test_context_manager_closes_on_exit():
    srv, env1, _, _ = _server()
    obs = _obs(env1, 1)[0]
    with srv as s:
        assert s.ready
        assert isinstance(s.act(obs, seed=0, timeout=30), ActionResult)
    assert not srv.ready
    with pytest.raises(ServerClosed):
        srv.submit(obs, seed=1)


def test_overloaded_is_a_typed_queue_full():
    """The shed rejection is BOTH the new typed error and the
    pre-taxonomy queue.Full, so existing callers keep catching it."""
    assert issubclass(Overloaded, queue.Full)
    srv, env1, _, _ = _server(max_queue=1)
    obs = _obs(env1, 1)[0]
    srv.submit(obs, seed=0, block=False)
    with pytest.raises(Overloaded, match="shed"):
        srv.submit(obs, seed=1, block=False)
    srv.close()


# -------------------------------------------------------- session.serve
def _serve_spec(ckpt_dir=None, runtime="serve", **serve_kw):
    kw = {}
    if ckpt_dir is not None:
        kw["checkpoint"] = {"dir": ckpt_dir, "every": 1}
    return api.ExperimentSpec(
        env="catch", policy="mlp",
        optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4, "eps": 1e-5}},
        algorithm="a2c", runtime=runtime,
        hts={"alpha": 4, "n_envs": 4, "seed": 3},
        serve=dict({"max_batch": 8, "timeout_ms": 50.0}, **serve_kw),
        **kw)


def test_session_serve_loads_trained_capsule(tmp_path):
    """Train under a training runtime, then serve the SAME checkpoint
    dir under runtime='serve': the served params are the trained
    params (capsule leading leaves), not the init params."""
    ckpt_dir = str(tmp_path / "ck")
    train = api.build(_serve_spec(ckpt_dir, runtime="mesh").replace(
        intervals=2))
    train.fit()
    trained = train.state().algo.params

    session = api.build(_serve_spec(ckpt_dir))
    srv = session.serve(start=False)
    for got, want in zip(jax.tree.leaves(srv.params),
                         jax.tree.leaves(trained)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the served action comes from the trained params
    srv.start()
    out = srv.act(_obs(session.env, 1)[0], seed=1)
    srv.stop()
    assert isinstance(out, ActionResult)


def test_spec_serve_block_reaches_the_server():
    """build() threads spec.serve into the serve runtime: the spec's
    dispatch bounds govern the server, not ServeConfig defaults."""
    session = api.build(_serve_spec(max_queue=17))
    srv = session.serve(start=False)
    assert srv.serve.max_batch == 8          # _serve_spec's block
    assert srv.serve.max_queue == 17
    assert srv.serve.timeout_ms == 50.0


def test_session_serve_without_checkpoint_serves_init_params(tmp_path):
    session = api.build(_serve_spec())
    srv = session.serve(start=False)
    for got, want in zip(jax.tree.leaves(srv.params),
                         jax.tree.leaves(session.params)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_session_serve_works_under_training_runtimes():
    """Serving is not gated on runtime='serve' — any session can answer
    requests (the capsule invariant makes params loadable everywhere)."""
    session = api.build(_serve_spec(runtime="mesh"))
    srv = session.serve()
    try:
        r = srv.act(_obs(session.env, 1)[0], seed=9)
        assert isinstance(r, ActionResult)
    finally:
        srv.stop()


# --------------------------------------------------------------- loadgen
def test_loadgen_smoke_returns_finite_metrics():
    from repro.serve import loadgen
    metrics = loadgen.run(_serve_spec(), requests=40, rate=4000.0,
                          seed=0, warmup=8)
    assert set(metrics) == {"serve_qps", "serve_p50_ms", "serve_p99_ms",
                            "serve_mean_batch", "serve_shed",
                            "serve_restarts"}
    for k in ("serve_qps", "serve_p50_ms", "serve_p99_ms",
              "serve_mean_batch"):
        assert np.isfinite(metrics[k]) and metrics[k] > 0, (k, metrics[k])
    # a healthy un-faulted run sheds nothing and never restarts
    assert metrics["serve_shed"] == 0 and metrics["serve_restarts"] == 0
