"""BatchConfig and the scale-out determinism contract (DESIGN.md §12).

Three layers of pinning:

  * validation — accepted (global_batch, grad_accumulation, n_replicas)
    triples round-trip the spec's canonical JSON; rejected ones name
    the offending ``batch.<field>`` and suggest the nearest valid
    factorization (fuzzed with hypothesis when installed, plus an
    always-on exhaustive sweep over small global batches);
  * bit-exactness — for a fixed global batch, final params and
    episode-return streams are IDENTICAL across every
    (n_replicas, grad_accumulation) cell, on the mesh (in-process
    factorization bookkeeping), host (accumulated gradient pass), and
    sharded (real 2-device data parallelism, subprocess) runtimes —
    including a checkpoint capsule restored onto a different replica
    count;
  * multi-process — a 2-process ``jax.distributed`` run
    (repro.launch.distributed) produces the single-process mesh
    digest, bit-exact, on both processes.
"""
import json
import os
import socket
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api, models
from repro.core import engine
from repro.core.batch import BatchConfig, pairwise_tree_sum
from repro.core.engine import HTSConfig
from repro.envs import catch
from repro.optim import rmsprop

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# --------------------------------------------------------------- helpers
def _setup():
    env1 = catch.make()
    cfg = HTSConfig(alpha=5, n_envs=4, seed=3)
    policy = models.get_policy("mlp", env1)
    params = policy.init(jax.random.key(0))
    return env1, cfg, policy.apply, params, rmsprop(7e-4, eps=1e-5)


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------------------ validation
def test_field_level_errors():
    with pytest.raises(ValueError, match="batch.micro_batch"):
        BatchConfig(micro_batch=0)
    with pytest.raises(ValueError, match="batch.grad_accumulation"):
        BatchConfig(grad_accumulation=-1)
    with pytest.raises(ValueError, match="batch.n_replicas"):
        BatchConfig(n_replicas=True)      # bools are not counts
    with pytest.raises(ValueError, match="unknown batch field"):
        BatchConfig.of({"replicas": 2})


def test_resolve_divisibility_and_alignment():
    # divisibility: A*R must divide the global batch
    with pytest.raises(ValueError, match="batch.n_replicas=3"):
        BatchConfig(n_replicas=3).resolve(8)
    # alignment: A must be a power of two when the geometry is explicit
    with pytest.raises(ValueError, match="power of\\s+two"):
        BatchConfig(grad_accumulation=3).resolve(12)
    # ...but R is unconstrained beyond divisibility (the cross-replica
    # pairwise combine continues the global tree for any R)
    g = BatchConfig(n_replicas=3).resolve(12)
    assert g == (4, 1, 3, 12)
    # micro_batch derives replicas when they are omitted
    g = BatchConfig(micro_batch=2, grad_accumulation=2).resolve(16)
    assert (g.micro_batch, g.n_replicas) == (2, 4)
    # ...and is cross-checked when both are given
    with pytest.raises(ValueError, match="batch.micro_batch=4 inconsist"):
        BatchConfig(micro_batch=4, n_replicas=4).resolve(8)
    # legacy default geometry: divisibility only, no pow2 constraint
    assert BatchConfig().resolve(12, default_replicas=3).chunks == 3


def test_rejections_name_nearest_valid_factorization():
    with pytest.raises(ValueError, match="nearest valid factorization"):
        BatchConfig(n_replicas=5).resolve(8)
    try:
        BatchConfig(grad_accumulation=3, n_replicas=2).resolve(8)
    except ValueError as e:
        msg = str(e)
        assert "batch.grad_accumulation=3" in msg
        assert "grad_accumulation=" in msg and "n_replicas=" in msg
    else:
        pytest.fail("A=3,R=2 over 8 envs must be rejected")


def test_exhaustive_small_global_batches():
    """Always-on sweep (the hypothesis fuzz below needs the optional
    dep): every (N <= 16, A <= N, R <= N) triple either resolves —
    and then round-trips the spec's canonical JSON — or raises a
    ValueError naming a batch.<field> and suggesting a factorization."""
    for n_envs in (1, 2, 3, 4, 6, 8, 12, 16):
        for a in range(1, n_envs + 1):
            for r in range(1, n_envs + 1):
                bc = BatchConfig(grad_accumulation=a, n_replicas=r)
                try:
                    g = bc.resolve(n_envs)
                except ValueError as e:
                    assert "batch." in str(e)
                    assert "nearest valid factorization" in str(e)
                    continue
                assert g.micro_batch * a * r == n_envs
                spec = api.ExperimentSpec(
                    runtime="mesh", hts={"n_envs": n_envs}, batch=bc)
                again = api.loads(api.dumps(spec))
                assert again == spec
                assert again.batch.resolve(n_envs) == g


def test_hypothesis_fuzz_roundtrip():
    pytest.importorskip(
        "hypothesis", reason="optional dep: fuzz needs hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(n_envs=st.integers(1, 256), a=st.integers(1, 32),
           r=st.integers(1, 32))
    def fuzz(n_envs, a, r):
        bc = BatchConfig(grad_accumulation=a, n_replicas=r)
        try:
            g = bc.resolve(n_envs)
        except ValueError as e:
            assert "batch." in str(e)
            assert "nearest valid factorization" in str(e)
            return
        assert g.micro_batch * a * r == n_envs
        # accepted triples survive the canonical JSON round-trip
        spec = api.ExperimentSpec(runtime="mesh",
                                  hts={"n_envs": n_envs}, batch=bc)
        assert api.loads(api.dumps(spec)) == spec

    fuzz()


def test_pairwise_tree_sum_subtree_property():
    """Power-of-two blocks are exact subtrees: hierarchical reduction
    equals the flat one bit-for-bit (float32, adversarial magnitudes)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal(16)
                     * 10.0 ** rng.integers(-6, 6, 16)).astype(np.float32))
    flat = pairwise_tree_sum(x)
    for blocks in (2, 4, 8):
        sums = jax.vmap(pairwise_tree_sum)(x.reshape(blocks, -1))
        assert float(pairwise_tree_sum(sums)) == float(flat), blocks


# --------------------------------------------------- spec / fingerprint
def test_spec_validates_geometry_eagerly():
    with pytest.raises(ValueError, match="batch.n_replicas=3"):
        api.ExperimentSpec(runtime="sharded", hts={"n_envs": 8},
                           batch={"n_replicas": 3})


def test_fingerprint_default_popped_nondefault_kept():
    base = api.ExperimentSpec(runtime="mesh", hts={"n_envs": 8})
    fp_default = api.workload_fingerprint(base)
    assert "batch" not in fp_default     # committed baselines unchanged
    fp_r2 = api.workload_fingerprint(
        base.replace(batch={"n_replicas": 2}))
    assert fp_r2["batch"]["n_replicas"] == 2
    assert fp_default != fp_r2           # never compared across geometries


def test_baselines_reject_nondefault_batch():
    spec = api.ExperimentSpec(runtime="sync", hts={"n_envs": 8},
                              batch={"grad_accumulation": 2})
    with pytest.raises(ValueError, match="batch-geometry"):
        api.build(spec)


# -------------------------------------------------- in-process bit-exact
def test_mesh_factorization_cells_bitexact():
    """Fixed global batch: every (n_replicas, grad_accumulation) cell in
    {1,2}^2 produces the default geometry's params and episode-return
    streams bit-exactly (mesh = the single-process oracle)."""
    env1, cfg, papply, params, opt = _setup()
    base = engine.make_runtime("mesh", env1, papply, params, opt,
                               cfg).run(3)
    for R in (1, 2):
        for A in (1, 2):
            out = engine.make_runtime(
                "mesh", env1, papply, params, opt, cfg,
                batch={"n_replicas": R, "grad_accumulation": A}).run(3)
            assert _maxdiff(base.params, out.params) == 0.0, (R, A)
            np.testing.assert_array_equal(base.rewards, out.rewards)
            np.testing.assert_array_equal(base.dones, out.dones)


def test_host_accumulation_bitexact():
    env1, cfg, papply, params, opt = _setup()
    base = engine.make_runtime("host", env1, papply, params, opt,
                               cfg).run(3)
    out = engine.make_runtime("host", env1, papply, params, opt, cfg,
                              batch={"grad_accumulation": 2}).run(3)
    assert _maxdiff(base.params, out.params) == 0.0
    np.testing.assert_array_equal(base.rewards, out.rewards)


def test_sharded_replica_axis_sized_and_validated():
    env1, cfg, papply, params, opt = _setup()
    with pytest.raises(ValueError, match="n_replicas=2 but only"):
        # single visible device cannot host an explicit 2-replica axis
        engine.make_runtime("sharded", env1, papply, params, opt, cfg,
                            batch={"n_replicas": 2})
    from jax.sharding import Mesh
    mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="mesh"):
        engine.make_runtime("sharded", env1, papply, params, opt, cfg,
                            mesh=mesh1, batch={"n_replicas": 2})


def test_trainer_manifest_records_geometry(tmp_path):
    env1, cfg, papply, params, opt = _setup()
    spec = api.ExperimentSpec(
        runtime="mesh", hts={"alpha": 5, "n_envs": 4, "seed": 3},
        optimizer={"name": "rmsprop",
                   "kwargs": {"lr": 7e-4, "eps": 1e-5}},
        checkpoint={"dir": str(tmp_path), "every": 2},
        batch={"grad_accumulation": 2})
    api.build(spec).fit(2)
    manifest = sorted(tmp_path.glob("step_*.json"))[-1]
    meta = json.loads(manifest.read_text())["metadata"]
    assert meta["batch"] == {"micro_batch": 2, "grad_accumulation": 2,
                             "n_replicas": 1, "global_batch": 4}
    # resume onto a DIFFERENT factorization: loud note, bit-exact result
    # (same global batch — the n_envs check pins that)
    resumed = api.build(spec.replace(batch={"grad_accumulation": 1}))
    out = resumed.fit(4, resume=True)
    straight = api.build(spec.replace(
        checkpoint={"dir": None}, batch=None)).fit(4)
    assert _maxdiff(out.params, straight.params) == 0.0


# ------------------------------------------------- 2-device (subprocess)
_TWO_DEVICE_SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    assert len(jax.devices()) == 2, jax.devices()
    from repro import models
    from repro.core import engine
    from repro.core.engine import HTSConfig
    from repro.envs import catch
    from repro.optim import rmsprop
    env1 = catch.make()
    cfg = HTSConfig(alpha=5, n_envs=4, seed=3)
    policy = models.get_policy("mlp", env1)
    papply = policy.apply
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    def md(a, b):
        return max(float(jnp.max(jnp.abs(x - y))) for x, y in
                   zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    m = engine.make_runtime("mesh", env1, papply, params, opt, cfg).run(4)
    # (n_replicas=2) x (grad_accumulation 1, 2): real 2-device data
    # parallelism, bit-exact to the mesh oracle
    for A in (1, 2):
        s = engine.make_runtime(
            "sharded", env1, papply, params, opt, cfg,
            batch={"n_replicas": 2, "grad_accumulation": A}).run(4)
        assert np.array_equal(m.rewards, s.rewards), A
        assert md(m.params, s.params) == 0.0, (A, md(m.params, s.params))
    # checkpoint capsule round-trip onto a DIFFERENT replica count:
    # 2 mesh intervals -> capsule -> 2 more on 2-replica sharded
    rt1 = engine.make_runtime("mesh", env1, papply, params, opt, cfg)
    rt1.run(2)
    cap = rt1.state()
    rt2 = engine.make_runtime("sharded", env1, papply, params, opt, cfg,
                              batch={"n_replicas": 2})
    out = rt2.run_from(cap, 2)
    assert md(m.params, out.params) == 0.0, md(m.params, out.params)
    print("OK")
""")


def test_two_device_geometry_cells_and_restore():
    """The acceptance matrix on real devices: sharded (R=2) x (A in
    {1,2}) bit-exact to mesh, plus a capsule restored from a 1-replica
    mesh run onto a 2-replica sharded runtime continuing bit-exactly."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _TWO_DEVICE_SCRIPT],
                       env=env, capture_output=True, text=True,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.strip().endswith("OK")


# --------------------------------------------- 2-process jax.distributed
def test_two_process_distributed_matches_mesh(tmp_path):
    """Two OS processes join a jax.distributed cluster
    (repro.launch.distributed; gloo CPU collectives) and run the same
    spec sharded over one global 2-device mesh: every process prints
    the SAME final-params sha256, equal to the 1-process mesh digest."""
    from repro.launch.distributed import params_digest
    spec = api.ExperimentSpec(
        runtime="sharded",
        hts={"alpha": 5, "n_envs": 4, "seed": 3},
        optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4,
                                                 "eps": 1e-5}},
        intervals=3, batch={"n_replicas": 2})
    path = tmp_path / "spec.json"
    api.save(spec, str(path))
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)       # 1 local device per process
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro.launch.distributed",
         "--spec", str(path), "--coordinator", f"127.0.0.1:{port}",
         "--num-processes", "2", "--process-id", str(i)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True) for i in range(2)]
    outs = [p.communicate(timeout=600) for p in procs]
    for p, (so, se) in zip(procs, outs):
        assert p.returncode == 0, se[-3000:]
    digests = [json.loads(so)["params_sha256"] for so, _ in outs]
    assert digests[0] == digests[1]
    # ...and equals the single-process mesh run of the same workload
    mesh_out = api.build(
        spec.replace(runtime="mesh", batch=None)).run(3)
    assert digests[0] == params_digest(mesh_out.params)
