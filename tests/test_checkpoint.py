"""Checkpoint io: the corruption/error taxonomy (DESIGN.md §11).

Two disjoint failure families, because they demand different responses:

* ``CheckpointCorrupt`` (RuntimeError) — the BYTES cannot be trusted:
  torn capsule (manifest without npz), truncated/corrupt archive, a
  leaf failing its manifest crc32. Survivable: supervisors fall back to
  an older complete checkpoint (core/trainer.Trainer does).
* ``ValueError`` — the STRUCTURE disagrees with the restore template:
  leaf count, tree shape, leaf shapes, dtypes, a missing manifest.
  A caller error no amount of retrying fixes.

Plus the selection helpers (``complete_checkpoints`` / ``latest`` skip
torn capsules) and the ``restore_prefix`` error paths serving relies on.
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"b": rng.randn(3).astype(np.float32),
            "w": rng.randn(4, 3).astype(np.float32),
            "extra": rng.randn(2, 2).astype(np.float32)}


def _save(tmp_path, name="step_00000001", tree=None):
    path = str(tmp_path / name)
    ckpt_io.save(path, tree if tree is not None else _tree(),
                 metadata={"intervals": 1})
    return path


# ------------------------------------------------------------- checksums
def test_manifest_records_per_leaf_crc32(tmp_path):
    path = _save(tmp_path)
    m = ckpt_io.load_manifest(path)
    assert len(m["crc32"]) == m["n_leaves"] == 3
    restored = ckpt_io.restore(path, _tree(1))
    for k, want in _tree().items():
        np.testing.assert_array_equal(np.asarray(restored[k]), want)


def test_truncated_npz_raises_checkpoint_corrupt(tmp_path):
    path = _save(tmp_path)
    npz = path + ".npz"
    with open(npz, "r+b") as f:
        size = f.seek(0, os.SEEK_END)
        f.truncate(size // 2)
    with pytest.raises(ckpt_io.CheckpointCorrupt):
        ckpt_io.restore(path, _tree())


def test_modified_leaf_fails_its_checksum(tmp_path):
    """Content corruption the zip layer cannot see — the npz rewritten
    internally consistent but with one leaf's values changed — is
    exactly what the manifest's per-leaf crc32 exists to catch."""
    path = _save(tmp_path)
    npz = path + ".npz"
    arrays = dict(np.load(npz))
    arrays["leaf_1"] = arrays["leaf_1"] + 1.0
    with open(npz, "wb") as f:
        np.savez(f, **arrays)
    with pytest.raises(ckpt_io.CheckpointCorrupt, match="checksum"):
        ckpt_io.restore(path, _tree())


def test_missing_npz_is_torn_not_selectable(tmp_path):
    old = _save(tmp_path, "step_00000001")
    torn = _save(tmp_path, "step_00000002")
    os.remove(torn + ".npz")
    # restore of the torn capsule: corrupt (survivable), naming the tear
    with pytest.raises(ckpt_io.CheckpointCorrupt, match="torn"):
        ckpt_io.restore(torn, _tree())
    # selection skips it entirely: latest() is the older COMPLETE one
    assert ckpt_io.complete_checkpoints(str(tmp_path)) == [old]
    assert ckpt_io.latest(str(tmp_path)) == old


def test_complete_checkpoints_newest_first(tmp_path):
    paths = [_save(tmp_path, f"step_{i:08d}") for i in (1, 2, 3)]
    assert ckpt_io.complete_checkpoints(str(tmp_path)) == paths[::-1]
    assert ckpt_io.complete_checkpoints(str(tmp_path / "nowhere")) == []


def test_corrupt_is_not_a_valueerror():
    """The taxonomy is load-bearing: supervisors catch CheckpointCorrupt
    (fall back) while letting ValueError (config mismatch) propagate."""
    assert issubclass(ckpt_io.CheckpointCorrupt, RuntimeError)
    assert not issubclass(ckpt_io.CheckpointCorrupt, ValueError)


# ------------------------------------------------------ structural errors
def test_restore_validates_structure_loudly(tmp_path):
    path = _save(tmp_path)
    with pytest.raises(ValueError, match="leaves"):
        ckpt_io.restore(path, {"only": np.zeros(3, np.float32)})
    bad_shape = dict(_tree(), w=np.zeros((5, 3), np.float32))
    with pytest.raises(ValueError, match="shape"):
        ckpt_io.restore(path, bad_shape)


# ---------------------------------------------------- restore_prefix paths
def _prefix_template():
    t = _tree()
    return {"b": t["b"], "extra": t["extra"]}   # first 2 of 3 flat leaves


def test_restore_prefix_happy_path(tmp_path):
    path = _save(tmp_path)
    got = ckpt_io.restore_prefix(path, _prefix_template())
    want = _tree()
    np.testing.assert_array_equal(np.asarray(got["b"]), want["b"])
    np.testing.assert_array_equal(np.asarray(got["extra"]), want["extra"])


def test_restore_prefix_requires_manifest(tmp_path):
    path = _save(tmp_path)
    os.remove(path + ".json")
    with pytest.raises(ValueError, match="no manifest"):
        ckpt_io.restore_prefix(path, _prefix_template())


def test_restore_prefix_requires_n_leaves_field(tmp_path):
    path = _save(tmp_path)
    m = ckpt_io.load_manifest(path)
    del m["n_leaves"]
    with open(path + ".json", "w") as f:
        json.dump(m, f)
    with pytest.raises(ValueError, match="n_leaves"):
        ckpt_io.restore_prefix(path, _prefix_template())


def test_restore_prefix_template_larger_than_capsule(tmp_path):
    path = _save(tmp_path)
    big = dict(_tree(), more=np.zeros(2, np.float32))
    with pytest.raises(ValueError, match="needs"):
        ckpt_io.restore_prefix(path, big)


def test_restore_prefix_shape_mismatch(tmp_path):
    path = _save(tmp_path)
    bad = dict(_prefix_template(), b=np.zeros(7, np.float32))
    with pytest.raises(ValueError, match="prefix leaf"):
        ckpt_io.restore_prefix(path, bad)


def test_restore_prefix_dtype_mismatch(tmp_path):
    path = _save(tmp_path)
    bad = {k: v.astype(np.float64)
           for k, v in _prefix_template().items()}
    with pytest.raises(ValueError, match="dtype"):
        ckpt_io.restore_prefix(path, bad)


def test_restore_prefix_corrupt_leaf_is_checkpoint_corrupt(tmp_path):
    path = _save(tmp_path)
    os.remove(path + ".npz")
    with pytest.raises(ckpt_io.CheckpointCorrupt, match="torn"):
        ckpt_io.restore_prefix(path, _prefix_template())
