"""The declarative surface (repro.api): spec round-trip bit-exactness,
build-time validation, the streaming observer hook, and the grep gate
keeping examples/ on the api.

The load-bearing claim: a spec that survives ``loads(dumps(spec))``
builds and runs BIT-IDENTICALLY to the hand-wired construction it
replaced — for every (host|mesh|sharded) x (a2c|ppo) cell — so moving a
surface onto the api can never move a golden (tests/test_goldens.py
holds the committed digests).
"""
import json
import os
import re

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api, envs, models, optim
from repro.core import engine
from repro.core.engine import HTSConfig

INTERVALS = 3
RUNTIMES = ("host", "mesh", "sharded")
ALGOS = ("a2c", "ppo")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bench_spec(runtime, algorithm="a2c"):
    return api.ExperimentSpec(
        env="catch", policy="mlp",
        optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4, "eps": 1e-5}},
        algorithm=algorithm, runtime=runtime,
        hts={"alpha": 4, "n_envs": 4, "seed": 3}, intervals=INTERVALS)


def _overrides(runtime):
    if runtime == "sharded":
        # 1-device mesh pin: bit-exactness must not depend on the
        # machine's device count (CI runs a 2-forced-device leg)
        from jax.sharding import Mesh
        return {"mesh": Mesh(np.array(jax.devices()[:1]), ("data",))}
    return {}


def _bitequal(a, b):
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------- round-trip bit-exact
@pytest.mark.parametrize("algorithm", ALGOS)
@pytest.mark.parametrize("runtime", RUNTIMES)
def test_spec_roundtrip_matches_handwired(runtime, algorithm):
    """build(loads(dumps(spec))).run() == the pre-api hand-wired
    construction, bit for bit (params AND trajectory streams)."""
    spec = api.loads(api.dumps(_bench_spec(runtime, algorithm)))
    out = api.build(spec, **_overrides(runtime)).run()

    # the hand-wired path this spec replaced, verbatim
    from repro.envs import catch
    from repro.models.cnn_policy import apply_mlp_policy, init_mlp_policy
    from repro.optim import rmsprop
    env1 = catch.make()
    cfg = HTSConfig(alpha=4, n_envs=4, seed=3, algorithm=algorithm)
    params = init_mlp_policy(jax.random.key(0),
                             int(np.prod(env1.obs_shape)), env1.n_actions)
    papply = lambda p, o: apply_mlp_policy(p, o.reshape(o.shape[0], -1))
    ref = engine.make_runtime(runtime, env1, papply, params,
                              rmsprop(7e-4, eps=1e-5), cfg,
                              **_overrides(runtime)).run(INTERVALS)

    assert _bitequal(out.params, ref.params), (runtime, algorithm)
    np.testing.assert_array_equal(out.rewards, ref.rewards)
    np.testing.assert_array_equal(out.dones, ref.dones)


def test_dumps_is_canonical_and_stable():
    spec = _bench_spec("mesh")
    s = api.dumps(spec)
    assert s == api.dumps(api.loads(s))
    # every axis explicit in the canonical form
    d = json.loads(s)
    assert set(d) == {"env", "policy", "optimizer", "algorithm",
                      "runtime", "hts", "params_seed", "intervals",
                      "checkpoint", "serve", "faults", "batch",
                      "tenancy"}


def test_committed_spec_files_are_canonical():
    """examples/specs/*.json parse, validate, and ARE their own
    canonical serialization (api.save output) — no drift."""
    spec_dir = os.path.join(ROOT, "examples", "specs")
    files = sorted(os.listdir(spec_dir))
    assert files, "no committed spec files"
    for name in files:
        path = os.path.join(spec_dir, name)
        spec = api.load(path)
        with open(path) as f:
            assert f.read() == api.dumps(spec, indent=2) + "\n", (
                f"{name} is not canonical; regenerate with "
                f"api.save(api.load({name!r}), ...)")


# ------------------------------------------------------------ validation
def test_validation_rejects_bad_specs():
    with pytest.raises(ValueError, match="staleness must be >= 1"):
        api.ExperimentSpec(env="catch", hts={"staleness": 0})
    with pytest.raises(ValueError, match="alpha must be >= 1"):
        api.ExperimentSpec(env="catch", hts={"alpha": 0})
    with pytest.raises(ValueError, match="n_envs must be >= 1"):
        api.ExperimentSpec(env="catch", hts={"n_envs": 0})
    with pytest.raises(ValueError, match="spec.algorithm"):
        api.ExperimentSpec(env="catch", hts={"algorithm": "ppo"})
    with pytest.raises(ValueError, match="unknown HTSConfig knob"):
        api.ExperimentSpec(env="catch", hts={"aplha": 4})
    with pytest.raises(ValueError, match="unknown spec field"):
        api.from_dict({"environment": "catch"})
    with pytest.raises(TypeError, match="not JSON-serializable"):
        api.dumps(api.ExperimentSpec(
            env={"name": "catch", "kwargs": {"fn": lambda: None}}))


def test_build_rejects_unknown_registry_names():
    for field, msg in [
            (dict(env="nope"), "unknown env"),
            (dict(policy="nope"), "unknown policy"),
            (dict(optimizer="nope"), "unknown optimizer"),
            (dict(runtime="nope"), "unknown runtime"),
            (dict(algorithm="nope"), "unknown algorithm")]:
        with pytest.raises(KeyError, match=msg):
            api.build(api.ExperimentSpec(**{"env": "catch", **field}))
    # the error must LIST what is registered
    with pytest.raises(KeyError, match="registered:.*'mesh'"):
        api.build(api.ExperimentSpec(env="catch", runtime="nope"))


def test_build_rejects_mismatched_workload_pairs():
    with pytest.raises(ValueError, match="consumes an Env workload"):
        api.build(api.ExperimentSpec(
            env={"name": "token_stream",
                 "kwargs": {"vocab": 8, "batch": 2, "seq": 4}},
            runtime="mesh"))
    with pytest.raises(ValueError, match="TokenStream workload"):
        api.build(api.ExperimentSpec(env="catch", runtime="stream"))
    with pytest.raises(ValueError, match="could not be sized"):
        api.build(api.ExperimentSpec(
            env={"name": "token_stream",
                 "kwargs": {"vocab": 8, "batch": 2, "seq": 4}},
            policy="mlp", runtime="stream"))


def test_registries_list_names():
    assert "catch" in envs.env_names()
    assert "token_stream" in envs.env_names()
    assert {"mlp", "cnn", "token", "backbone"} <= set(models.policy_names())
    assert {"adam", "rmsprop", "sgd"} <= set(optim.optimizer_names())
    assert "stream" in api.runtime_names()
    assert set(engine.runtime_names()) <= set(api.runtime_names())


# -------------------------------------------------------------- observer
def test_observer_streams_match_result(tmp_path):
    """on_interval observers see one metrics dict per interval — same
    sequence from the live host coordinator and the post-hoc fused
    dispatch — and reporting stays reporting: results are bit-identical
    with and without observers."""
    outs, streams = {}, {}
    for runtime in ("host", "mesh"):
        session = api.build(_bench_spec(runtime))
        base = session.run()                      # no observers
        seen = []
        session.on_interval(lambda m: seen.append(m))
        out = session.run()
        assert _bitequal(base.params, out.params)
        assert [m["interval"] for m in seen] == list(range(INTERVALS))
        for i, m in enumerate(seen):
            np.testing.assert_array_equal(m["rewards"], out.rewards[i])
            np.testing.assert_array_equal(m["dones"], out.dones[i])
        outs[runtime], streams[runtime] = out, seen
    assert _bitequal(outs["host"].params, outs["mesh"].params)

    # run_from continues the global interval numbering
    session = api.build(_bench_spec("mesh"))
    session.run(2)
    state = session.state()
    seen = []
    session.on_interval(lambda m: seen.append(m["interval"]))
    session.run_from(state, 2)
    assert seen == [2, 3]


def test_fit_threads_observer_through_trainer(tmp_path):
    spec = _bench_spec("mesh").replace(
        checkpoint={"dir": str(tmp_path / "ck"), "every": 2},
        intervals=4)
    session = api.build(spec)
    seen = []
    session.on_interval(lambda m: seen.append(m["interval"]))
    report = session.fit()
    assert report.intervals == 4
    assert seen == [0, 1, 2, 3]
    # resumed fit continues the numbering where the checkpoint left off
    session2 = api.build(spec)
    seen2 = []
    session2.on_interval(lambda m: seen2.append(m["interval"]))
    report2 = session2.fit(6, resume=True)
    assert report2.resumed_from == 4
    assert seen2 == [4, 5]


def test_observer_self_removal_does_not_skip_successor():
    """The one-shot-observer pattern: an observer that calls
    remove_observer(itself) mid-dispatch must not shift its successor
    out of THIS interval's iteration (dispatch iterates a snapshot)."""
    session = api.build(_bench_spec("host"))
    fired = []

    def one_shot(m):
        fired.append(("one_shot", m["interval"]))
        session.remove_observer(one_shot)

    session.on_interval(one_shot)
    session.on_interval(lambda m: fired.append(("tail", m["interval"])))
    session.run()
    # one_shot fires exactly once; tail sees EVERY interval including
    # interval 0, the dispatch one_shot removed itself during
    assert fired.count(("one_shot", 0)) == 1
    assert [i for tag, i in fired if tag == "tail"] == list(range(INTERVALS))


# ------------------------------------------------------- stream runtime
def _stream_spec():
    return api.ExperimentSpec(
        env={"name": "token_stream",
             "kwargs": {"vocab": 64, "batch": 2, "seq": 8}},
        policy={"name": "backbone",
                "kwargs": {"arch": "starcoder2-3b", "reduced": True,
                           "vocab_size": 64, "n_layers": 2,
                           "d_model": 64, "d_ff": 128}},
        optimizer={"name": "adam", "kwargs": {"lr": 1e-3}},
        algorithm="a2c", runtime="stream", intervals=4)


def test_stream_runtime_contract(tmp_path):
    """The LLM learner through the engine contract: spec JSON
    round-trip, run(a+b) == run(a)+run_from(b) with a checkpoint
    round-trip at the boundary, and per-interval loss metrics."""
    from repro.checkpoint import io as ckpt_io
    full = api.build(_stream_spec()).run()
    assert set(full.metrics) == {"loss", "pg", "value", "entropy"}
    assert full.metrics["loss"].shape == (4,)

    session = api.build(api.loads(api.dumps(_stream_spec())))
    seen = []
    session.on_interval(lambda m: seen.append(m))
    a = session.run(2)
    state = session.state()
    ckpt_io.save(str(tmp_path / "cap"), state)
    restored = ckpt_io.restore(str(tmp_path / "cap"), session.state())
    b = session.run_from(restored, 2)
    assert _bitequal(full.params, b.params)
    np.testing.assert_array_equal(
        full.metrics["loss"],
        np.concatenate([a.metrics["loss"], b.metrics["loss"]]))
    # live observer: loss floats per interval, continuous numbering
    assert [m["interval"] for m in seen] == [0, 1, 2, 3]
    np.testing.assert_allclose([m["loss"] for m in seen],
                               full.metrics["loss"], rtol=0, atol=0)


def test_stream_rejects_vocab_mismatch():
    spec = _stream_spec()
    bad = spec.replace(env={"name": "token_stream",
                            "kwargs": {"vocab": 32, "batch": 2,
                                       "seq": 8}})
    with pytest.raises(ValueError, match="vocab"):
        api.build(bad)


# ------------------------------------------------ fingerprint + bench
def test_bench_fingerprint_is_spec_canonical():
    from benchmarks.engine_sps import bench_spec, config_fingerprint
    fp = config_fingerprint()
    expect = api.workload_fingerprint(bench_spec())
    expect.pop("runtime")
    assert fp == expect
    # the fingerprint tracks workload knobs field-for-field
    assert config_fingerprint(staleness=2) != fp
    assert api.diff_canonical(fp, config_fingerprint(staleness=2)) == \
        ["hts.staleness: 1 != 2"]


def test_check_sps_prints_field_level_diff():
    from benchmarks.check_sps import check
    from benchmarks.engine_sps import config_fingerprint
    base = {"ts": "t0", "intervals": 12, "host": "h",
            "config": config_fingerprint(staleness=2),
            "sps": {"engine_sps_mesh": 100.0}}
    cur = {"ts": "t1", "intervals": 12, "host": "h",
           "config": config_fingerprint(),
           "sps": {"engine_sps_mesh": 100.0}}
    ok, msg = check([base, cur], "engine_sps_mesh", 0.3)
    assert ok
    assert "hts.staleness: 1 != 2" in msg, msg


# ------------------------------------------------------------ grep gate
def test_examples_import_no_runtime_factories():
    """Every example goes through repro.api: no direct imports of the
    engine registry or any runtime module (the wiring the api
    replaced)."""
    forbidden = re.compile(
        r"repro\.core\.(engine|host_runtime|mesh_runtime|"
        r"sharded_runtime|baselines|stream_runtime)\b"
        r"|\bmake_runtime\b|\bget_runtime\b")
    ex_dir = os.path.join(ROOT, "examples")
    offenders = []
    for name in sorted(os.listdir(ex_dir)):
        if not name.endswith(".py"):
            continue
        with open(os.path.join(ex_dir, name)) as f:
            for lineno, line in enumerate(f, 1):
                if forbidden.search(line):
                    offenders.append(f"{name}:{lineno}: {line.strip()}")
    assert not offenders, (
        "examples must construct through repro.api, not runtime "
        "factories:\n" + "\n".join(offenders))
