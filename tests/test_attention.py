"""Blocked flash-style attention (jnp) vs naive, forward + backward."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import blocked_attention, decode_attention


def naive(q, k, v, causal=True, window=0, cap=0.0):
    B, S, H, D = q.shape
    KV = k.shape[2]
    R = H // KV
    kr = jnp.repeat(k, R, axis=2)
    vr = jnp.repeat(v, R, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * D ** -0.5
    if cap:
        s = cap * jnp.tanh(s / cap)
    qp, kp = jnp.arange(S), jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp[None] <= qp[:, None]
    if window:
        mask &= kp[None] > qp[:, None] - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))


CASES = [
    dict(S=64, H=4, KV=2, D=16, causal=True, window=0, cap=0.0),
    dict(S=96, H=4, KV=1, D=8, causal=True, window=32, cap=0.0),
    dict(S=64, H=2, KV=2, D=16, causal=False, window=0, cap=0.0),
    dict(S=80, H=4, KV=2, D=8, causal=True, window=0, cap=30.0),
    dict(S=50, H=2, KV=1, D=16, causal=True, window=0, cap=0.0),  # ragged
]


@pytest.mark.parametrize("case", CASES)
def test_forward_matches_naive(case):
    S, H, KV, D = case["S"], case["H"], case["KV"], case["D"]
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (2, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (2, S, KV, D), jnp.float32)
    o1 = blocked_attention(q, k, v, causal=case["causal"],
                           window=case["window"], cap=case["cap"],
                           q_block=16, k_block=32)
    o2 = naive(q, k, v, case["causal"], case["window"], case["cap"])
    assert jnp.max(jnp.abs(o1.astype(jnp.float32) - o2)) < 1e-4


@pytest.mark.parametrize("case", CASES[:4])
def test_gradients_match_naive(case):
    S, H, KV, D = case["S"], case["H"], case["KV"], case["D"]
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (2, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (2, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (2, S, KV, D), jnp.float32)
    f1 = lambda *a: blocked_attention(
        *a, causal=case["causal"], window=case["window"], cap=case["cap"],
        q_block=16, k_block=32).astype(jnp.float32).sum()
    f2 = lambda *a: naive(*a, case["causal"], case["window"],
                          case["cap"]).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert jnp.max(jnp.abs(a - b)) < 1e-4


def test_flash_backward_is_tile_free_under_scan():
    """The regression that motivated the custom_vjp: no O(S^2) stacked
    residuals when attention sits inside scan(checkpoint(block))."""
    import re
    k0 = jax.random.key(0)

    def blk(x, w):
        q = jnp.einsum("bsd,dk->bsk", x, w).reshape(1, 64, 4, 4)
        o = blocked_attention(q, q[:, :, :2], q[:, :, :2],
                              q_block=16, k_block=32)
        return x + o.reshape(1, 64, 16)

    def model(x, ws):
        def body(c, w):
            return jax.checkpoint(blk)(c, w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jax.random.normal(k0, (1, 64, 16))
    ws = jax.random.normal(k0, (3, 16, 16))
    sg = str(jax.make_jaxpr(jax.grad(model))(x, ws))
    # catastrophic = per-tile stacks that still carry batch/head dims
    # (B=1, G=2, R=2 here). The data-independent (1,1,1,qb,kb) penalty
    # stack is allowed — it has no B*H factor and is CSE'd across layers.
    stacked = re.findall(r"(?:f32|bool)\[4,2,1,2,2,16,32\]", sg)
    assert not stacked, f"O(S^2 * B * H) residuals leaked: {set(stacked)}"


def test_decode_matches_full_forward_row():
    ks = jax.random.split(jax.random.key(2), 3)
    S, H, KV, D = 32, 4, 2, 16
    q = jax.random.normal(ks[0], (2, S, H, D))
    k = jax.random.normal(ks[1], (2, S, KV, D))
    v = jax.random.normal(ks[2], (2, S, KV, D))
    full = naive(q, k, v, causal=True)
    one = decode_attention(q[:, -1:], k, v, pos=S - 1)
    assert jnp.max(jnp.abs(one[:, 0] - full[:, -1])) < 1e-4


def test_ring_cache_decode_matches_full():
    """Local-attention ring cache (length == window < S): incremental
    decode must match the full forward."""
    import dataclasses
    import jax
    from repro.configs.base import get_config
    from repro.models import backbone

    cfg = dataclasses.replace(get_config("h2o-danube-3-4b").reduced(),
                              window=8)
    params = backbone.init_params(cfg, jax.random.key(0))
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                cfg.vocab_size)
    h, _, _ = backbone.forward(params, cfg, tokens)
    lf, _ = backbone.logits_and_value(params, cfg, h)
    # prefill S-8 (multiple of window) then decode the rest one by one
    p_len = 16
    _, _, cache = backbone.prefill(params, cfg, tokens[:, :p_len],
                                   max_len=S)
    assert cache["blocks"]["l0"]["k"].shape[2] == 8  # ring length = window
    for i in range(p_len, S):
        ld, _, cache = backbone.decode_step(params, cfg, tokens[:, i:i + 1],
                                            cache, jnp.int32(i))
    err = float(jnp.max(jnp.abs(lf[:, -1] - ld)))
    scale = float(jnp.max(jnp.abs(lf[:, -1]))) + 1e-9
    assert err / scale < 0.05, err / scale
