"""Performance regression guards for the zero-redispatch hot path.

Two families:

* **Compile-count guards** — a warm second ``run(n)`` must not retrace
  or recompile any jitted program, on the host runtime (whose hot path
  is a fixed set of fixed-shape jitted functions) and on every scan
  runtime (one cached program per interval count). A retrace here means
  some argument leaked a fresh Python object/shape into the hot path —
  the exact bug class that silently multiplies dispatch cost.

* **Batched-stepper equivalence under skew** — the host runtime groups
  whatever env-step requests are ready into one padded dispatch, so
  simulated ``step_time`` skew makes envs finish out of order and the
  group compositions racy. The determinism contract (keys are pure
  functions of ``(seed, env_id, step)``; the batched step is a vmapped
  row-independent program) says composition cannot matter: trajectories
  and parameters must stay bit-identical to the fused mesh runtime and
  to an unskewed host run.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine
from repro.core.engine import HTSConfig
from repro.core.host_runtime import HostConfig
from repro import models
from repro.envs import catch
from repro.envs.steptime import StepTimeModel
from repro.optim import rmsprop


def _setup():
    env1 = catch.make()
    cfg = HTSConfig(alpha=5, n_envs=4, seed=3)
    policy = models.get_policy("mlp", env1)   # the obs-flattening MLP
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    return env1, cfg, policy.apply, params, opt


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _make(name, **kwargs):
    env1, cfg, papply, params, opt = _setup()
    return engine.make_runtime(name, env1, papply, params, opt, cfg,
                               **kwargs)


# ------------------------------------------------------- compile counts
def test_host_warm_run_does_not_recompile():
    rt = _make("host")
    rt.run(3)
    jitted = {
        "actor_fwd": rt._actor_fwd,
        "step_batch": rt._step_batch,
        "tables": rt._tables_fn,
        "grad": rt._grad_fn,
        "apply": rt._apply_fn,
        "final_drain": rt._final_fn.one_pass,
        "env_reset": rt._env_reset_v,
    }
    sizes = {k: f._cache_size() for k, f in jitted.items()}
    assert all(v == 1 for v in sizes.values()), sizes
    rt.run(3)
    warm = {k: f._cache_size() for k, f in jitted.items()}
    assert warm == sizes, f"warm rerun retraced: {sizes} -> {warm}"


def test_host_interval_count_is_not_a_trace_axis():
    """The interval index is a traced device scalar, so neither more
    intervals nor a later starting interval (run_from) retraces."""
    rt = _make("host")
    rt.run(2)
    s = rt.state()
    rt.run_from(s, 3)
    rt.run(5)
    assert rt._tables_fn._cache_size() == 1
    assert rt._actor_fwd._cache_size() == 1
    assert rt._step_batch._cache_size() == 1


@pytest.mark.parametrize("name", ["mesh", "sharded", "sync", "async"])
def test_scan_runtime_warm_run_does_not_recompile(name):
    kwargs = {}
    if name == "sharded":
        from jax.sharding import Mesh
        kwargs["mesh"] = Mesh(np.array(jax.devices()[:1]), ("data",))
    rt = _make(name, **kwargs)
    rt.run(3)
    assert set(rt._programs) == {3}
    assert rt._programs[3]._cache_size() == 1
    rt.run(3)
    assert set(rt._programs) == {3}
    assert rt._programs[3]._cache_size() == 1, "warm rerun recompiled"


# ------------------------------------- batched stepping under steptime skew
SKEW = StepTimeModel(shape=0.25, rate=0.25)   # mean 1, var 4 (paper HIGH_VAR)


def test_batched_stepping_bitexact_under_skew():
    """Envs finishing out of order (high-variance simulated step times)
    change the stepper's group compositions but not one bit of the
    result: skewed host == unskewed host == fused mesh."""
    skewed = _make("host",
                   host=HostConfig(n_actors=2, step_time=SKEW,
                                   time_scale=2e-3)).run(3)
    plain = _make("host").run(3)
    fused = _make("mesh").run(3)
    for other in (plain, fused):
        assert _maxdiff(skewed.params, other.params) == 0.0
        np.testing.assert_array_equal(skewed.rewards, other.rewards)
        np.testing.assert_array_equal(skewed.dones, other.dones)


def test_skewed_host_continuation_bitexact(tmp_path):
    """Skew composes with the continuation contract: a mid-run capsule
    from a skewed host run resumes (on mesh, even) bit-exactly."""
    from repro.checkpoint import io as ckpt_io
    straight = _make("mesh").run(4)
    a = _make("host", host=HostConfig(n_actors=2, step_time=SKEW,
                                      time_scale=2e-3))
    a.run(2)
    path = str(tmp_path / "skewed")
    ckpt_io.save(path, a.state())
    b = _make("mesh")
    out = b.run_from(ckpt_io.restore(path, b.state()), 2)
    assert _maxdiff(straight.params, out.params) == 0.0


# ------------------------------------------------------- donation safety
def test_donated_buffers_never_leak_into_caller_state():
    """The donated carries/learner inputs are runtime-private: the
    caller's params survive any number of runs, and a captured capsule
    stays readable after further (donating) segments."""
    env1, cfg, papply, params, opt = _setup()
    leaves_before = [np.array(x) for x in jax.tree.leaves(params)]
    for name in ("host", "mesh", "sync", "async"):
        rt = engine.make_runtime(name, env1, papply, params, opt, cfg)
        rt.run(2)
        s = rt.state()
        snapshot = [np.array(x) for x in jax.tree.leaves(s)]
        rt.run_from(s, 1)
        rt.run(2)
        # capsule bit-unchanged after two donating segments: a missing
        # copy-on-capture would leave s aliasing slab/donated memory the
        # later segments overwrite (or delete) in place
        for before, leaf in zip(snapshot, jax.tree.leaves(s)):
            np.testing.assert_array_equal(before, np.asarray(leaf),
                                          err_msg=name)
    for before, leaf in zip(leaves_before,
                            jax.tree.leaves(params)):
        np.testing.assert_array_equal(before, np.asarray(leaf))
