"""Config registry + reduced-variant constraints."""
import pytest

from repro.configs.base import get_config, list_configs

ASSIGNED = [
    "llama4-scout-17b-a16e", "recurrentgemma-9b", "h2o-danube-3-4b",
    "granite-moe-1b-a400m", "rwkv6-7b", "whisper-medium", "qwen2-vl-72b",
    "starcoder2-3b", "stablelm-12b", "gemma2-27b",
]


def test_all_assigned_registered():
    assert set(ASSIGNED) <= set(list_configs())


@pytest.mark.parametrize("name", ASSIGNED)
def test_exact_assignment_numbers(name):
    cfg = get_config(name)
    expected = {
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


@pytest.mark.parametrize("name", ASSIGNED)
def test_reduced_constraints(name):
    r = get_config(name).reduced()
    assert r.n_layers <= 4 and r.d_model <= 512
    assert (r.n_experts or 0) <= 4
    assert r.layer_kinds  # tiles cleanly


def test_moe_configs():
    l4 = get_config("llama4-scout-17b-a16e")
    assert l4.n_experts == 16 and l4.top_k == 1 and l4.shared_expert
    gr = get_config("granite-moe-1b-a400m")
    assert gr.n_experts == 32 and gr.top_k == 8


def test_long_context_support_flags():
    sub_quadratic = {"recurrentgemma-9b", "rwkv6-7b", "h2o-danube-3-4b",
                     "gemma2-27b"}
    for name in ASSIGNED:
        assert get_config(name).sub_quadratic == (name in sub_quadratic)
