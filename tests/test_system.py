"""End-to-end behaviour: HTS-RL actually learns, matches sync sample
efficiency, and beats stale-async sample efficiency (paper Fig. 5)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import mesh_runtime
from repro.core.baselines import (AsyncConfig, async_init_carry,
                                  make_async_step, make_sync_step,
                                  sync_init_carry)
from repro.core.mesh_runtime import HTSConfig
from repro.envs import token_env
from repro.envs.interfaces import vectorize
from repro.models.cnn_policy import apply_token_policy, init_token_policy
from repro.optim import rmsprop

VOCAB = 32
N_INTERVALS = 120


def _mean_reward_tail(metrics, frac=0.25):
    r = np.asarray(metrics["rewards"])
    n = max(1, int(r.shape[0] * frac))
    return float(r[-n:].mean())


@pytest.fixture(scope="module")
def setup():
    env1 = token_env.make(vocab=VOCAB, seed=1)
    venv = vectorize(env1, 8)
    cfg = HTSConfig(alpha=8, n_envs=8, seed=0, entropy_coef=0.003)
    params = init_token_policy(jax.random.key(0), VOCAB, hidden=64)
    opt = rmsprop(5e-3, eps=1e-5)
    return env1, venv, cfg, params, opt


def test_hts_learns(setup):
    _, venv, cfg, params, opt = setup
    carry, metrics = mesh_runtime.train(params, apply_token_policy, venv,
                                        opt, cfg, N_INTERVALS)
    early = float(np.asarray(metrics["rewards"])[:5].mean())
    late = _mean_reward_tail(metrics)
    assert late > early + 0.05, (early, late)
    assert late > 0.15


def test_hts_matches_sync_sample_efficiency(setup):
    """Fig. 5 top row: HTS-RL has ~the same data efficiency as sync A2C."""
    _, venv, cfg, params, opt = setup
    _, m_hts = mesh_runtime.train(params, apply_token_policy, venv, opt,
                                  cfg, N_INTERVALS)
    sstep = make_sync_step(apply_token_policy, venv, opt, cfg)
    sc = sync_init_carry(params, opt, venv, cfg)

    @jax.jit
    def run(c):
        return jax.lax.scan(sstep, c, None, length=N_INTERVALS)

    _, m_sync = run(sc)
    hts = _mean_reward_tail(m_hts)
    sync = _mean_reward_tail(m_sync)
    # one-step delay costs a little data efficiency at tiny scale; the
    # paper's claim is "similar", which we bound at >=60% of sync here
    # (single seed, 120 intervals — Fig. 5 parity emerges at larger
    # budgets; see benchmarks/tab1 for the time-budgeted comparison)
    assert hts > 0.6 * sync, (hts, sync)


def test_stale_async_hurts_sample_efficiency(setup):
    """Fig. 5 / Sec. 3: heavy staleness without correction degrades
    final reward vs HTS-RL at equal environment steps."""
    _, venv, cfg, params, opt = setup
    _, m_hts = mesh_runtime.train(params, apply_token_policy, venv, opt,
                                  cfg, N_INTERVALS)
    acfg = AsyncConfig(staleness=16, correction="none")
    astep = make_async_step(apply_token_policy, venv, opt, cfg, acfg)
    ac = async_init_carry(params, opt, venv, cfg, acfg)

    @jax.jit
    def run(c):
        return jax.lax.scan(astep, c, None, length=N_INTERVALS)

    _, m_async = run(ac)
    hts = _mean_reward_tail(m_hts)
    stale = _mean_reward_tail(m_async)
    assert hts >= stale - 0.05, (hts, stale)
