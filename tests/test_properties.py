"""Hypothesis property-based tests on system invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import determinism, losses
from repro.core.runtime_model import expected_runtime
from repro.core.stale_sim import expected_latency
from repro.kernels.lru_scan.ref import lru_scan_ref

SET = dict(max_examples=25, deadline=None)


@given(st.integers(0, 2**30), st.integers(0, 1000), st.integers(0, 1000))
@settings(**SET)
def test_obs_key_order_independence(seed, env_id, step):
    """Determinism core: the key depends only on (seed, env, step), never
    on actor batching/order -> same key computed twice is identical."""
    m = determinism.master_key(seed)
    k1 = determinism.obs_key(m, env_id, step)
    k2 = determinism.obs_key(m, env_id, step)
    assert jnp.array_equal(jax.random.key_data(k1),
                           jax.random.key_data(k2))
    if env_id != step:
        k3 = determinism.obs_key(m, step, env_id)
        assert not jnp.array_equal(jax.random.key_data(k1),
                                   jax.random.key_data(k3))


@given(st.lists(st.floats(-5, 5), min_size=2, max_size=12),
       st.floats(0.1, 0.99))
@settings(**SET)
def test_returns_satisfy_bellman_recursion(rs, gamma):
    r = jnp.array(rs)[:, None]
    d = jnp.zeros_like(r)
    bv = jnp.array([1.5])
    rets = losses.n_step_returns(r, d, bv, gamma)
    nxt = jnp.concatenate([rets[1:, 0], bv])
    np.testing.assert_allclose(np.asarray(rets[:, 0]),
                               np.asarray(r[:, 0] + gamma * nxt),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(8, 64), st.integers(1, 32), st.floats(0.5, 4.0))
@settings(**SET)
def test_runtime_model_alpha_monotone(n, alpha, beta):
    """More batching never (materially) increases the expected runtime
    (Claim 1). Eq. (7) is an extreme-value *approximation*, so allow a
    few percent slack — the exact system is monotone, the approximation
    is only asymptotically so."""
    K = n * alpha * 8
    t1 = expected_runtime(K, n, alpha, beta)
    t2 = expected_runtime(K, n, alpha * 2, beta)
    assert t2 <= t1 * 1.05


@given(st.integers(1, 30))
@settings(**SET)
def test_latency_monotone_in_actors(n):
    """Claim 2: stale-policy latency grows with actor count; HTS stays 1."""
    l1 = expected_latency(n, 100.0, 4000.0)
    l2 = expected_latency(n + 1, 100.0, 4000.0)
    assert l2 >= l1
    from repro.core.stale_sim import hts_latency
    assert hts_latency(n) == 1


@given(st.integers(1, 4), st.integers(2, 24), st.integers(1, 16),
       st.integers(0, 2**20))
@settings(**SET)
def test_lru_scan_linearity(b, s, d, seed):
    """h(a, b1 + b2) = h(a, b1) + h(a, b2): the recurrence is linear in
    its input stream (core RG-LRU invariant)."""
    ks = jax.random.split(jax.random.key(seed), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, d)))
    b1 = jax.random.normal(ks[1], (b, s, d))
    b2 = jax.random.normal(ks[2], (b, s, d))
    y12, _ = lru_scan_ref(a, b1 + b2)
    y1, _ = lru_scan_ref(a, b1)
    y2, _ = lru_scan_ref(a, b2)
    np.testing.assert_allclose(np.asarray(y12), np.asarray(y1 + y2),
                               atol=1e-4, rtol=1e-3)


@given(st.integers(0, 2**20))
@settings(max_examples=10, deadline=None)
def test_entropy_nonnegative_and_bounded(seed):
    logits = jax.random.normal(jax.random.key(seed), (4, 16)) * 3
    st_ = losses.a2c_loss(logits, jnp.zeros(4),
                          jnp.zeros(4, jnp.int32), jnp.zeros(4),
                          jnp.zeros(4))
    assert 0.0 <= float(st_.entropy) <= float(jnp.log(16)) + 1e-5


@given(st.integers(1, 6), st.integers(1, 10), st.integers(0, 2**20))
@settings(**SET)
def test_moe_capacity_never_nan(e_pow, g, seed):
    """MoE output finite for random routers/capacities."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.models import moe
    cfg = dataclasses.replace(
        get_config("granite-moe-1b-a400m").reduced(),
        moe_group_size=4 * g)
    params = moe.init_moe(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1),
                          (2, 8, cfg.d_model)).astype(jnp.bfloat16)
    y, aux = moe.apply_moe(params, x, cfg)
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@given(st.integers(0, 2**20), st.permutations(list(range(6))))
@settings(**SET)
def test_actor_batch_order_independence(seed, perm):
    """The asynchronous-actor determinism mechanism: actions depend only
    on (key_i, obs_i), so ANY batching/order gives identical per-env
    actions."""
    m = determinism.master_key(seed)
    keys = determinism.obs_keys(m, jnp.arange(6), 3)
    logits = jax.random.normal(jax.random.key(seed ^ 1), (6, 5))
    a1 = jax.vmap(determinism.sample_action)(keys, logits)
    p = jnp.array(perm)
    a2 = jax.vmap(determinism.sample_action)(keys[p], logits[p])
    assert jnp.array_equal(a1[p], a2)


@given(st.integers(1, 2), st.integers(1, 3), st.integers(1, 2),
       st.integers(2, 5), st.booleans(), st.integers(0, 40),
       st.integers(0, 2**20))
@settings(max_examples=12, deadline=None)
def test_flash_attention_random_shapes(b, g, r, dh8, causal, window, seed):
    """Flash fwd+bwd equals naive attention for random shapes / masks."""
    from repro.models.attention import blocked_attention
    S = 48
    H, KV, Dh = g * r, g, dh8 * 8
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, S, H, Dh))
    k = jax.random.normal(ks[1], (b, S, KV, Dh))
    v = jax.random.normal(ks[2], (b, S, KV, Dh))

    def naive(q, k, v):
        kr = jnp.repeat(k, r, axis=2)
        vr = jnp.repeat(v, r, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * Dh ** -0.5
        qp = jnp.arange(S)
        mask = jnp.ones((S, S), bool)
        if causal:
            mask &= qp[None] <= qp[:, None]
        if window:
            mask &= qp[None] > qp[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, -1)
        # fully-masked rows (window=tiny non-causal): normalize like flash
        return jnp.einsum("bhqk,bkhd->bqhd", p, vr)

    o1 = blocked_attention(q, k, v, causal=causal, window=window,
                           q_block=16, k_block=16)
    o2 = naive(q, k, v)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-3
    g1 = jax.grad(lambda a: blocked_attention(
        a, k, v, causal=causal, window=window, q_block=16,
        k_block=16).sum())(q)
    g2 = jax.grad(lambda a: naive(a, k, v).sum())(q)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-3


# ------------------------------------------------- evaluate vectorization
_REWARD_STREAMS = st.integers(0, 8).flatmap(lambda T: st.integers(1, 5).flatmap(
    lambda N: st.tuples(
        st.lists(st.lists(st.integers(-10, 10).map(float),
                          min_size=N, max_size=N),
                 min_size=T, max_size=T),
        st.lists(st.lists(st.booleans(), min_size=N, max_size=N),
                 min_size=T, max_size=T))))


def _as_arrays(stream):
    T = len(stream[0])
    r = np.asarray(stream[0], np.float64).reshape(T, -1)
    d = np.asarray(stream[1], bool).reshape(T, -1)
    return r, d


@given(_REWARD_STREAMS)
@settings(**SET)
def test_vectorized_episode_returns_match_loop(stream):
    """The vectorized episode_returns_from_stream is bit-equal to the
    O(T*N) loop reference on integer-valued rewards (exactly
    representable, so the cumsum-difference introduces no rounding)."""
    from repro.core import evaluate
    r, d = _as_arrays(stream)
    np.testing.assert_array_equal(
        evaluate.episode_returns_from_stream(r, d),
        evaluate._episode_returns_loop(r, d))


@given(_REWARD_STREAMS, st.lists(st.integers(0, 8), max_size=4))
@settings(**SET)
def test_return_stream_any_chunking_equals_one_shot(stream, cuts):
    """ReturnStream invariance: any chunking of the stream (any
    checkpoint cadence) yields exactly the one-shot returns."""
    from repro.core import evaluate
    r, d = _as_arrays(stream)
    T, N = r.shape
    bounds = sorted({min(int(c), T) for c in cuts} | {0, T})
    rs = evaluate.ReturnStream(N)
    for lo, hi in zip(bounds, bounds[1:]):
        rs.extend(r[lo:hi], d[lo:hi])
    np.testing.assert_array_equal(
        rs.returns, evaluate.episode_returns_from_stream(r, d))
