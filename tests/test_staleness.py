"""Staleness-K slab ring: the generalized determinism contract.

``HTSConfig.staleness`` bounds how many intervals of rollout may run
ahead of the learner (DESIGN.md §4). The contract this suite pins:

* K=1 is the paper's double buffer — covered by the committed goldens
  (tests/test_goldens.py runs the default config, which must stay
  bit-identical across this refactor).
* At every K, host/mesh/sharded are one program under three concurrency
  models: bit-identical parameters AND streams (the determinism
  contract §3 is untouched — keys are still pure functions of
  ``(seed, env_id, step)``, so the rollout data cannot depend on K; only
  the update schedule does).
* The continuation contract survives the ring: ``run(n)`` ≡ any
  partition into ``run_from`` segments with a checkpoint round-trip at
  every boundary, for K ∈ {1, 2, 4} — the capsule carries the ring
  occupancy (TrainState.buffer gains a leading K axis) and the behavior
  history (DelayedGradState.params_prev ring).
* ``behavior_lag`` is structural: read off the history leaves, never a
  config scalar that could drift from the stored state.

The 2-device subprocess test is the K>1 cell of the CI matrix: every
push exercises staleness=2 on a real 2-shard data mesh.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.checkpoint import io as ckpt_io
from repro.core import delayed_grad, engine
from repro.core.engine import HTSConfig
from repro.envs import catch
from repro.optim import rmsprop


def _setup(staleness, algorithm="a2c", alpha=4, n_envs=4):
    env1 = catch.make()
    cfg = HTSConfig(alpha=alpha, n_envs=n_envs, seed=3,
                    algorithm=algorithm, staleness=staleness)
    policy = models.get_policy("mlp", env1)   # the obs-flattening MLP
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    return env1, cfg, policy.apply, params, opt


def _make(name, staleness, algorithm="a2c"):
    env1, cfg, papply, params, opt = _setup(staleness, algorithm)
    kwargs = {}
    if name == "sharded":
        from jax.sharding import Mesh
        kwargs["mesh"] = Mesh(np.array(jax.devices()[:1]), ("data",))
    return engine.make_runtime(name, env1, papply, params, opt, cfg,
                               **kwargs)


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ----------------------------------------------------- cross-runtime K>1
@pytest.mark.parametrize("staleness", [2, 4])
def test_runtimes_bit_identical_at_staleness(staleness):
    """host/mesh/sharded at K>1: same params, same streams, bit-exact —
    the ring changes the schedule, not one floating-point operation."""
    outs = {name: _make(name, staleness).run(6)
            for name in ("host", "mesh", "sharded")}
    ref = outs["mesh"]
    for name, out in outs.items():
        assert _maxdiff(ref.params, out.params) == 0.0, name
        np.testing.assert_array_equal(ref.rewards, out.rewards,
                                      err_msg=name)
        np.testing.assert_array_equal(ref.dones, out.dones, err_msg=name)


@pytest.mark.parametrize("algorithm", ["ppo", "vtrace"])
def test_staleness2_across_algorithms(algorithm):
    """The delay-K schedule is algorithm-independent: PPO clipping and
    V-trace corrections see the same (theta_{j-K}, D_{j-K}) pairs on
    every runtime."""
    a = _make("host", 2, algorithm).run(5)
    b = _make("mesh", 2, algorithm).run(5)
    assert _maxdiff(a.params, b.params) == 0.0


def test_staleness_changes_training_but_not_data():
    """K is a real knob: the delay changes the parameter trajectory (the
    gradients are applied K updates late) while the FIRST K intervals'
    rollouts — collected at theta_0 either way — stay identical."""
    o1 = _make("mesh", 1).run(6)
    o2 = _make("mesh", 2).run(6)
    assert _maxdiff(o1.params, o2.params) > 0.0
    np.testing.assert_array_equal(o1.rewards[:1], o2.rewards[:1])


def test_update_counts_match_across_staleness():
    """run(n) reflects exactly n updates at every K: the in-stream
    applies plus the K-pass reporting drain."""
    for K in (1, 2, 4):
        out = _make("mesh", K).run(5)
        assert int(out.state.step) == 5, K
        # mid-stream state is K updates behind the reported params
        rt = _make("host", K)
        rt.run(5)
        assert int(rt.state().algo.step) == 5 - K


def test_run_shorter_than_staleness():
    """n < K edge: only n real updates exist; the drain skips the
    never-filled ring slots, and host/mesh still agree bit-exactly."""
    a = _make("host", 4).run(2)
    b = _make("mesh", 4).run(2)
    assert _maxdiff(a.params, b.params) == 0.0
    assert int(a.state.step) == 2


# ------------------------------------------------------- continuation
@pytest.mark.parametrize("staleness", [1, 2, 4])
@pytest.mark.parametrize("name", ["host", "mesh", "sharded"])
def test_partition_with_checkpoint_roundtrip(name, staleness, tmp_path):
    """run(5) ≡ run_from segments with a disk checkpoint round-trip at
    every boundary, at every K — the capsule's ring occupancy (buffer
    slots + behavior history) restores the exact pipeline state."""
    straight = _make(name, staleness).run(5)
    rt = _make(name, staleness)
    template = rt.state()
    state, rewards = template, []
    for i, n in enumerate((2, 3)):
        out = rt.run_from(state, n)
        rewards.append(out.rewards)
        path = str(tmp_path / f"boundary_{i}")
        ckpt_io.save(path, rt.state())
        state = ckpt_io.restore(path, template)
    assert _maxdiff(straight.params, out.params) == 0.0
    np.testing.assert_array_equal(straight.rewards,
                                  np.concatenate(rewards))


def test_capsule_is_cross_runtime_at_staleness2(tmp_path):
    """A K=2 host checkpoint resumes on mesh (and back): the stacked
    ring is one structure for the whole HTS family."""
    straight = _make("mesh", 2).run(6)
    a = _make("host", 2)
    a.run(3)
    path = str(tmp_path / "xfer")
    ckpt_io.save(path, a.state())
    b = _make("mesh", 2)
    out = b.run_from(ckpt_io.restore(path, b.state()), 3)
    assert _maxdiff(straight.params, out.params) == 0.0


def test_staleness_mismatch_checkpoint_refused(tmp_path):
    """A K=2 capsule cannot silently restore into a K=1 runtime: the
    ring shapes differ, and checkpoint/io fails with the staleness hint
    instead of unflattening mismatched leaves."""
    a = _make("mesh", 2)
    a.run(3)
    path = str(tmp_path / "k2")
    ckpt_io.save(path, a.state())
    b = _make("mesh", 1)
    with pytest.raises(ValueError, match="staleness|leaves|shape"):
        ckpt_io.restore(path, b.state())


# ------------------------------------------------- analytic pipeline model
def test_pipeline_model_hand_example():
    """Worked example of the staleness-K schedule recursion: alternating
    fast/slow rollouts against a constant learner — K=2 hides the slow
    learner behind the fast intervals, K=1 pays max() every interval."""
    from repro.core.runtime_model import staleness_pipeline_runtime
    R, L = [1.0, 3.0, 1.0, 3.0], [2.0, 2.0, 2.0, 2.0]
    assert staleness_pipeline_runtime(R, L, 1) == 11.0
    assert staleness_pipeline_runtime(R, L, 2) == 10.0


def test_pipeline_model_monotone_in_staleness():
    """A larger staleness budget never predicts a slower schedule on the
    same traces (the ring constraint set only shrinks), and a saturated
    serial learner is rate-bound at EVERY K (no schedule beats it)."""
    from repro.core.runtime_model import staleness_pipeline_runtime
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(1, 30))
        R = rng.gamma(0.5, 2.0, size=n)
        L = rng.gamma(0.5, 2.0, size=n)
        totals = [staleness_pipeline_runtime(R, L, K)
                  for K in (1, 2, 4, 8, n + 1)]
        assert all(a >= b - 1e-12 for a, b in zip(totals, totals[1:]))
        # full drain is always paid: the learner backlog bounds below
        assert totals[-1] >= float(np.sum(L))
    slow = staleness_pipeline_runtime([1.0] * 8, [5.0] * 8, 1)
    for K in (2, 4, 8):
        assert staleness_pipeline_runtime([1.0] * 8, [5.0] * 8, K) == slow


# ------------------------------------------------------- structural lag
def test_behavior_lag_is_structural():
    opt = rmsprop(1e-3)
    params = {"w": jnp.ones((3, 2))}
    assert delayed_grad.behavior_lag(delayed_grad.init(params, opt)) == 1
    dg3 = delayed_grad.init(params, opt, staleness=3)
    assert delayed_grad.behavior_lag(dg3) == 3
    assert jax.tree.leaves(dg3.params_prev)[0].shape == (3, 3, 2)
    # the gradient point is the OLDEST slot, and updates roll the ring
    dg3 = delayed_grad.update(dg3, {"w": jnp.ones((3, 2))}, opt)
    assert delayed_grad.behavior_lag(dg3) == 3
    np.testing.assert_array_equal(
        np.asarray(delayed_grad.behavior_params(dg3)["w"]), np.ones((3, 2)))


def test_staleness_validation():
    env1, cfg, papply, params, opt = _setup(0)
    for name in ("host", "mesh", "sharded"):
        with pytest.raises(ValueError, match="staleness"):
            engine.make_runtime(name, env1, papply, params, opt, cfg)
    # baselines refuse the knob entirely rather than silently ignore it
    env1, cfg2, papply, params, opt = _setup(2)
    for name in ("sync", "async"):
        with pytest.raises(ValueError, match="staleness"):
            engine.make_runtime(name, env1, papply, params, opt, cfg2)


# --------------------------------------------------- 2-device sharded
_MULTIDEV_SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp, tempfile
    assert len(jax.devices()) == 2, jax.devices()
    from repro import models
    from repro.checkpoint import io as ckpt_io
    from repro.core import engine
    from repro.core.engine import HTSConfig
    from repro.envs import catch
    from repro.optim import rmsprop
    env1 = catch.make()
    cfg = HTSConfig(alpha=4, n_envs=4, seed=3, staleness=2)
    policy = models.get_policy("mlp", env1)
    papply = policy.apply
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    mk = lambda: engine.make_runtime("sharded", env1, papply, params, opt,
                                     cfg)
    straight = mk().run(6)
    # trajectories are device-count independent at K>1 too: compare the
    # reward stream against the single-device host runtime
    host = engine.make_runtime("host", env1, papply, params, opt,
                               cfg).run(6)
    np.testing.assert_array_equal(straight.rewards, host.rewards)
    a = mk()
    a.run(3)
    d = tempfile.mkdtemp()
    ckpt_io.save(f"{d}/step_00000003", a.state())
    b = mk()   # fresh instance: restore crosses process-lifetime state
    state = ckpt_io.restore(f"{d}/step_00000003", b.state())
    out = b.run_from(state, 3)
    md = max(float(jnp.max(jnp.abs(x - y))) for x, y in
             zip(jax.tree.leaves(straight.params),
                 jax.tree.leaves(out.params)))
    assert md == 0.0, md
    print("OK", md)
""")


def test_sharded_two_device_staleness2():
    """The K>1 cell of the CI matrix: on a real 2-device 'data' mesh
    (subprocess — the device count locks at first jax init), staleness=2
    trajectories match the host runtime bit-exactly and a mid-run
    checkpoint (ring occupancy gathered via device_get) restores into a
    fresh runtime and continues bit-exactly."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.startswith("OK")
