"""Checkpointed continuation: the strongest determinism oracle.

The engine contract (core/engine.py, DESIGN.md §1): ``run(n)`` is
bit-identical to ANY partition of n into ``run_from`` segments with a
checkpoint save/restore round-trip at each boundary — on every
registered runtime, for every algorithm, at every split point. The
capsule (``TrainState``) is also cross-runtime: a host checkpoint
resumed by the fused mesh runtime (or vice versa) continues the exact
same trajectory.

Also covered: the 2-device sharded path (subprocess, because the device
count locks at first jax init) and the trainer's kill-and-resume
(preemption) recovery.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.checkpoint import io as ckpt_io
from repro.core import engine
from repro.core.engine import HTSConfig
from repro.core.trainer import Trainer
from repro.envs import catch
from repro.optim import rmsprop

TOTAL = 4
SPLITS = [(1, 3), (2, 2)]


def _setup(algorithm="a2c"):
    env1 = catch.make()
    cfg = HTSConfig(alpha=4, n_envs=4, seed=3, algorithm=algorithm)
    policy = models.get_policy("mlp", env1)   # the obs-flattening MLP
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    return env1, cfg, policy.apply, params, opt


def _make(name, algorithm="a2c"):
    env1, cfg, papply, params, opt = _setup(algorithm)
    kwargs = {}
    if name == "sharded":
        # pin to a 1-device mesh so the in-process bit-exactness claims
        # hold regardless of the machine's device count (the CI matrix
        # runs this suite under 2 forced host devices); real 2-device
        # continuation is covered by the subprocess test below
        from jax.sharding import Mesh
        kwargs["mesh"] = Mesh(np.array(jax.devices()[:1]), ("data",))
    return engine.make_runtime(name, env1, papply, params, opt, cfg,
                               **kwargs)


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _run_split(rt, split, tmp_path, template_rt=None):
    """Run ``split`` as run_from segments with a DISK checkpoint
    round-trip at every boundary (including the initial state). Returns
    (last RunResult, concatenated rewards)."""
    template = (template_rt or rt).state()
    state = template
    rewards = []
    for i, n in enumerate(split):
        out = rt.run_from(state, n)
        rewards.append(out.rewards)
        path = str(tmp_path / f"boundary_{i}")
        ckpt_io.save(path, rt.state(), {"intervals": int(sum(split[:i + 1]))})
        state = ckpt_io.restore(path, template)
    return out, np.concatenate(rewards)


@pytest.mark.parametrize("split", SPLITS, ids=lambda s: f"{s[0]}+{s[1]}")
@pytest.mark.parametrize("name", engine.training_runtime_names())
def test_partition_with_checkpoint_roundtrip(name, split, tmp_path):
    """For every registered training runtime: run(4) ≡ run_from segments
    with a save/restore round-trip at each boundary, bit-exactly."""
    straight = _make(name).run(TOTAL)
    out, rewards = _run_split(_make(name), split, tmp_path)
    assert _maxdiff(straight.params, out.params) == 0.0
    np.testing.assert_array_equal(straight.rewards, rewards)


@pytest.mark.parametrize("split", SPLITS, ids=lambda s: f"{s[0]}+{s[1]}")
@pytest.mark.parametrize("K", [1, 2], ids=lambda k: f"K{k}")
def test_device_backend_partition_with_roundtrip(K, split, tmp_path):
    """The contract holds with env stepping on the device backend too:
    the capsule carries the same stacked state pytree, so staleness-K
    ring drain + checkpoint round-trips are backend-independent."""
    env1, cfg, papply, params, opt = _setup()
    cfg = cfg._replace(staleness=K, env_backend="device")
    mk = lambda: engine.make_runtime("mesh", env1, papply, params, opt,
                                     cfg)
    straight = mk().run(TOTAL)
    out, rewards = _run_split(mk(), split, tmp_path)
    assert _maxdiff(straight.params, out.params) == 0.0
    np.testing.assert_array_equal(straight.rewards, rewards)


@pytest.mark.parametrize("algorithm", ["ppo", "vtrace"])
@pytest.mark.parametrize("name", ["host", "mesh"])
def test_partition_across_algorithms(name, algorithm, tmp_path):
    """The contract is algorithm-independent: the capsule carries the
    full update-rule state, so PPO clipping and V-trace corrections
    resume exactly too."""
    straight = _make(name, algorithm).run(TOTAL)
    out, _ = _run_split(_make(name, algorithm), (1, 3), tmp_path)
    assert _maxdiff(straight.params, out.params) == 0.0


@pytest.mark.parametrize("src,dst", [("host", "mesh"), ("mesh", "host"),
                                     ("sharded", "host")])
def test_capsule_is_cross_runtime(src, dst, tmp_path):
    """A checkpoint from one runtime resumes on another: TrainState is
    one structure for the whole HTS family (threads, fused XLA,
    shard_map), so continuation is scheduler-independent."""
    straight = _make(dst).run(TOTAL)
    a = _make(src)
    a.run(2)
    path = str(tmp_path / "xfer")
    ckpt_io.save(path, a.state())
    b = _make(dst)
    state = ckpt_io.restore(path, b.state())
    out = b.run_from(state, 2)
    assert _maxdiff(straight.params, out.params) == 0.0


def test_state_capture_is_idempotent():
    """state() is an observation, not a mutation: capturing and
    re-capturing, or resuming twice from one capsule, changes nothing."""
    rt = _make("mesh")
    rt.run(2)
    s1 = rt.state()
    s2 = rt.state()
    assert _maxdiff(s1, s2) == 0.0
    o1 = rt.run_from(s1, 2)
    o2 = rt.run_from(s2, 2)
    assert _maxdiff(o1.params, o2.params) == 0.0


def test_run_from_zero_reports_run_params():
    """run_from(state_of(a), 0) reports exactly run(a)'s params: the
    reporting-only trailing pass consumes the buffered interval without
    touching the continuation stream."""
    straight = _make("mesh").run(2)
    rt = _make("mesh")
    rt.run(2)
    out = rt.run_from(rt.state(), 0)
    assert _maxdiff(straight.params, out.params) == 0.0
    assert out.rewards.shape[0] == 0


# ------------------------------------------------------------- trainer
@pytest.mark.parametrize("name", ["mesh", "host"])
def test_trainer_kill_and_resume(name, tmp_path):
    """Preemption: the trainer dies (exception after the 2nd segment's
    checkpoint is durable); a FRESH runtime + trainer with resume=True
    recovers the exact straight-run parameters AND the exact episode
    -return stream (episodes spanning the kill boundary counted once)."""
    straight = _make(name).run(5)

    class Preempted(Exception):
        pass

    def bomb(done, out):
        if done >= 2:
            raise Preempted

    ckpt_dir = str(tmp_path / "ck")
    with pytest.raises(Preempted):
        Trainer(_make(name), checkpoint_dir=ckpt_dir, ckpt_every=1,
                on_segment=bomb).fit(5)
    report = Trainer(_make(name), checkpoint_dir=ckpt_dir,
                     ckpt_every=1).fit(5, resume=True)
    assert report.resumed_from == 2 and report.intervals == 5
    assert _maxdiff(straight.params, report.params) == 0.0
    from repro.core import evaluate
    one_shot = evaluate.episode_returns_from_stream(
        straight.rewards.reshape(-1, 4), straight.dones.reshape(-1, 4))
    np.testing.assert_array_equal(one_shot, report.episode_returns)


def test_trainer_resume_recovers_from_torn_checkpoint(tmp_path):
    """A kill between a capsule's two file writes leaves a manifest
    without its npz. Resume must fall back to the previous COMPLETE
    checkpoint and still reach the exact straight-run parameters —
    not crash loading the torn one."""
    straight = _make("mesh").run(5)
    ckpt_dir = str(tmp_path / "ck")
    Trainer(_make("mesh"), checkpoint_dir=ckpt_dir, ckpt_every=1).fit(3)
    os.remove(os.path.join(ckpt_dir, "step_00000003.npz"))   # tear newest
    report = Trainer(_make("mesh"), checkpoint_dir=ckpt_dir,
                     ckpt_every=1).fit(5, resume=True)
    assert report.resumed_from == 2
    assert _maxdiff(straight.params, report.params) == 0.0


def test_run_from_without_finalize_stays_midstream(tmp_path):
    """finalize=False (trainer mid-run segments) skips the reporting
    pass: returned params equal the capsule's, and the continuation is
    unchanged."""
    rt = _make("mesh")
    straight = _make("mesh").run(4)
    s0 = rt.state()
    o1 = rt.run_from(s0, 2, finalize=False)
    assert _maxdiff(o1.params, rt.state().algo.params) == 0.0
    o2 = rt.run_from(rt.state(), 2)     # final segment: finalized
    assert _maxdiff(straight.params, o2.params) == 0.0


def test_trainer_fresh_fit_refuses_dirty_dir(tmp_path):
    """Without resume=True, a checkpoint_dir holding an earlier run's
    checkpoints is refused — otherwise keep-k pruning could delete the
    new run's checkpoints and a later resume would silently continue
    the abandoned one."""
    ckpt_dir = str(tmp_path / "ck")
    Trainer(_make("mesh"), checkpoint_dir=ckpt_dir, ckpt_every=1).fit(2)
    with pytest.raises(ValueError, match="already holds"):
        Trainer(_make("mesh"), checkpoint_dir=ckpt_dir).fit(1)


def test_trainer_resume_config_mismatch_raises(tmp_path):
    ckpt_dir = str(tmp_path / "ck")
    Trainer(_make("mesh"), checkpoint_dir=ckpt_dir, ckpt_every=1).fit(1)
    env1, cfg, papply, params, opt = _setup()
    other = engine.make_runtime("mesh", env1, papply, params, opt,
                                cfg._replace(seed=4))
    with pytest.raises(ValueError, match="seed"):
        Trainer(other, checkpoint_dir=ckpt_dir).fit(2, resume=True)


def test_trainer_keeps_last_k_checkpoints(tmp_path):
    ckpt_dir = str(tmp_path / "ck")
    Trainer(_make("mesh"), checkpoint_dir=ckpt_dir, ckpt_every=1,
            keep=2).fit(4)
    import glob
    names = sorted(os.path.basename(p)
                   for p in glob.glob(os.path.join(ckpt_dir, "*.json")))
    assert names == ["step_00000003.json", "step_00000004.json"]


# --------------------------------------------------- 2-device sharded
_MULTIDEV_SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp, tempfile
    assert len(jax.devices()) == 2, jax.devices()
    from repro import models
    from repro.checkpoint import io as ckpt_io
    from repro.core import engine
    from repro.core.engine import HTSConfig
    from repro.envs import catch
    from repro.optim import rmsprop
    env1 = catch.make()
    cfg = HTSConfig(alpha=4, n_envs=4, seed=3)
    policy = models.get_policy("mlp", env1)
    papply = policy.apply
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    mk = lambda: engine.make_runtime("sharded", env1, papply, params, opt,
                                     cfg)
    straight = mk().run(4)
    a = mk()
    a.run(2)
    d = tempfile.mkdtemp()
    ckpt_io.save(f"{d}/step_00000002", a.state())
    b = mk()   # fresh instance: restore crosses process-lifetime state
    state = ckpt_io.restore(f"{d}/step_00000002", b.state())
    out = b.run_from(state, 2)
    md = max(float(jnp.max(jnp.abs(x - y))) for x, y in
             zip(jax.tree.leaves(straight.params),
                 jax.tree.leaves(out.params)))
    assert md == 0.0, md
    print("OK", md)
""")


def test_sharded_two_device_continuation():
    """Real data parallelism: on a 2-device 'data' mesh (subprocess — the
    device count locks at first jax init), a sharded checkpoint taken
    mid-run (device_get-gathered) restores into a fresh runtime and
    continues bit-exactly."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.startswith("OK")
