"""Divisibility-aware sharding rules."""
import types

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import rules


class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = types.SimpleNamespace(shape=shape,
                                             size=int(__import__("numpy").prod(shape)))


POD = FakeMesh((16, 16), ("data", "model"))
MULTI = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_resolve_basic():
    spec = rules.resolve(("embed", "ffn"), (4096, 16384), POD)
    assert spec == P("data", "model")


def test_resolve_divisibility_fallback():
    # 40 heads not divisible by model=16 -> head_dim takes it
    spec = rules.resolve(("embed", "heads", "head_dim"), (5120, 40, 128),
                         POD)
    assert spec == P("data", None, "model")


def test_resolve_batch_multipod():
    assert rules.batch_pspec(MULTI, 256) == ("pod", "data")
    assert rules.batch_pspec(MULTI, 16) is None or \
        rules.batch_pspec(MULTI, 16) == "data"
    assert rules.batch_pspec(MULTI, 1) is None


def test_resolve_no_axis_reuse():
    spec = rules.resolve(("ffn", "vocab"), (16384, 256000), POD)
    # both want "model"; only one gets it
    assert list(spec).count("model") == 1


def test_cache_seq_sharding_when_batch_one():
    spec = rules.resolve(("batch", "seq_data", "kv_heads", "head_dim"),
                         (1, 524288, 16, 128), POD)
    assert spec == P(None, "data", "model")


def test_param_pspecs_shapes():
    from repro.configs.base import get_config
    from repro.models import backbone
    cfg = get_config("gemma2-27b")
    ap = backbone.abstract_params(cfg)
    specs = rules.param_pspecs(ap, POD)
    flat_p = jax.tree.leaves(ap)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    # every spec fits its array rank
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= p.ndim
