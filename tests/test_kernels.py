"""Pallas kernels (interpret=True) vs pure-jnp oracles: forward
shape/dtype sweeps plus gradient coverage — ``jax.grad`` through every
ops.py wrapper, pinned against the ref.py oracle's gradients (and, for
the LRU scan's analytic kernel-reusing backward, against numerical
differences via check_grads)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.kernels.flash_attention.ops import attend
from repro.kernels.lru_scan.ops import scan as lru_op
from repro.kernels.wkv6.ops import mix as wkv_op
from repro.models.rwkv6 import wkv6_ref

FLASH_CASES = [
    # (B, S, H, KV, Dh, causal, window, cap, bq, bk, dtype)
    (1, 64, 2, 2, 32, True, 0, 0.0, 32, 32, jnp.float32),
    (2, 128, 4, 2, 64, True, 0, 0.0, 64, 64, jnp.float32),
    (1, 128, 4, 1, 32, True, 64, 0.0, 32, 64, jnp.float32),
    (2, 64, 2, 2, 16, False, 0, 0.0, 32, 32, jnp.float32),
    (1, 96, 4, 4, 32, True, 0, 50.0, 32, 32, jnp.float32),
    (2, 128, 4, 2, 64, True, 0, 0.0, 64, 64, jnp.bfloat16),
    (1, 80, 2, 1, 16, True, 32, 0.0, 16, 16, jnp.bfloat16),  # padded
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_sweep(case):
    B, S, H, KV, Dh, causal, window, cap, bq, bk, dt = case
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh)).astype(dt)
    k = jax.random.normal(ks[1], (B, S, KV, Dh)).astype(dt)
    v = jax.random.normal(ks[2], (B, S, KV, Dh)).astype(dt)
    o1 = attend(q, k, v, causal=causal, window=window, cap=cap,
                bq=bq, bk=bk, use_pallas=True)
    o2 = attend(q, k, v, causal=causal, window=window, cap=cap,
                use_pallas=False)
    tol = 1e-5 if dt == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               atol=tol, rtol=tol)


LRU_CASES = [
    (1, 32, 16, 16, 16, jnp.float32),
    (2, 64, 32, 16, 32, jnp.float32),
    (2, 128, 64, 32, 64, jnp.float32),
    (1, 64, 48, 32, 16, jnp.float32),
    (2, 64, 32, 16, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("case", LRU_CASES)
def test_lru_scan_sweep(case):
    B, S, D, chunk, bd, dt = case
    ks = jax.random.split(jax.random.key(1), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, D))).astype(dt)
    b = jax.random.normal(ks[1], (B, S, D)).astype(dt)
    h0 = jax.random.normal(ks[2], (B, D))
    y1, hl1 = lru_op(a, b, h0, use_pallas=True, chunk=chunk, bd=bd)
    y2, hl2 = lru_op(a, b, h0, use_pallas=False)
    tol = 1e-5 if dt == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(hl1), np.asarray(hl2),
                               atol=tol, rtol=tol)


WKV_CASES = [
    (1, 16, 1, 8, 8, jnp.float32),
    (2, 32, 2, 8, 16, jnp.float32),
    (2, 64, 4, 16, 32, jnp.float32),
    (1, 32, 2, 16, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_sweep(case):
    B, T, H, N, chunk, dt = case
    ks = jax.random.split(jax.random.key(2), 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)).astype(dt)
               for i in range(3))
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N))) * 0.5
         + 0.49).astype(jnp.float32)
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.1
    o1, s1 = wkv_op(r, k, v, w, u, s0, use_pallas=True, chunk=chunk)
    o2, s2 = wkv6_ref(r, k, v, w, u, s0)
    tol = 1e-5 if dt == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=tol, rtol=tol)


# ------------------------------------------------------------- gradients
def _grad_maxdiff(g1, g2):
    return max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                     b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))


def test_flash_attention_grads_match_oracle():
    """jax.grad through the Pallas attend (incl. the wrapper's padding +
    transposes) vs through the pure oracle path, all inputs."""
    ks = jax.random.split(jax.random.key(5), 4)
    q = jax.random.normal(ks[0], (2, 48, 4, 16))   # 48 pads to bq=32
    k = jax.random.normal(ks[1], (2, 48, 2, 16))   # GQA KV=2
    v = jax.random.normal(ks[2], (2, 48, 2, 16))
    w = jax.random.normal(ks[3], (2, 48, 4, 16))

    def loss(use_pallas):
        def f(q_, k_, v_):
            o = attend(q_, k_, v_, causal=True, window=16, bq=32, bk=32,
                       use_pallas=use_pallas)
            return jnp.sum(o * w)
        return f

    gp = jax.grad(loss(True), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss(False), argnums=(0, 1, 2))(q, k, v)
    assert _grad_maxdiff(gp, gr) < 1e-4


def test_lru_scan_grads_match_oracle():
    """The analytic kernel-reusing backward (reversed-time scan) vs
    jax.grad of the associative-scan oracle, plus numerical check."""
    ks = jax.random.split(jax.random.key(6), 5)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 32, 8)))
    b = jax.random.normal(ks[1], (2, 32, 8))
    h0 = jax.random.normal(ks[2], (2, 8))
    gy = jax.random.normal(ks[3], (2, 32, 8))
    ghl = jax.random.normal(ks[4], (2, 8))

    def loss(use_pallas):
        def f(a_, b_, h_):
            y, hl = lru_op(a_, b_, h_, use_pallas=use_pallas, chunk=8,
                           bd=8)
            return jnp.sum(y * gy) + jnp.sum(hl * ghl)
        return f

    gp = jax.grad(loss(True), argnums=(0, 1, 2))(a, b, h0)
    gr = jax.grad(loss(False), argnums=(0, 1, 2))(a, b, h0)
    assert _grad_maxdiff(gp, gr) < 1e-4
    check_grads(loss(True), (a, b, h0), order=1, modes=["rev"],
                atol=2e-2, rtol=2e-2)


def test_lru_scan_grads_default_h0():
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(7), (1, 16, 4)))
    b = jax.random.normal(jax.random.key(8), (1, 16, 4))
    f = lambda up: lambda b_: jnp.sum(
        lru_op(a, b_, use_pallas=up, chunk=4, bd=4)[0])
    assert _grad_maxdiff(jax.grad(f(True))(b), jax.grad(f(False))(b)) < 1e-5


def test_wkv6_grads_match_oracle():
    B, T, H, N = 1, 16, 2, 8
    ks = jax.random.split(jax.random.key(9), 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N))) * 0.5 + 0.49
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.1

    def loss(use_pallas):
        def f(r_, k_, v_, w_, u_, s_):
            o, sT = wkv_op(r_, k_, v_, w_, u_, s_, use_pallas=use_pallas,
                           chunk=8)
            return jnp.sum(o) + jnp.sum(sT * 0.1)
        return f

    args = (r, k, v, w, u, s0)
    gp = jax.grad(loss(True), argnums=tuple(range(6)))(*args)
    gr = jax.grad(loss(False), argnums=tuple(range(6)))(*args)
    assert _grad_maxdiff(gp, gr) < 1e-4


def test_pallas_attention_in_model_path():
    """use_pallas_attention=True swaps the kernel into the backbone
    forward; outputs must match the jnp flash path (bf16 tolerance)."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.models import backbone
    cfg0 = get_config("gemma2-27b").reduced()
    cfg1 = dataclasses.replace(cfg0, use_pallas_attention=True)
    params = backbone.init_params(cfg0, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                cfg0.vocab_size)
    h0, _, _ = backbone.forward(params, cfg0, tokens)
    h1, _, _ = backbone.forward(params, cfg1, tokens)
    scale = float(jnp.max(jnp.abs(h0.astype(jnp.float32)))) + 1e-9
    err = float(jnp.max(jnp.abs(h0.astype(jnp.float32) -
                                h1.astype(jnp.float32))))
    assert err / scale < 0.05
