"""Paper Sec. 5 evaluation protocol module."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import evaluate
from repro.envs import catch


def test_episode_returns_from_stream():
    r = np.array([[1.0, 0.5], [2.0, 0.5], [3.0, 0.5]])
    d = np.array([[0, 1], [1, 0], [0, 1]])
    eps = evaluate.episode_returns_from_stream(r, d)
    np.testing.assert_allclose(eps, [0.5, 3.0, 1.0])


def test_final_time_metric_truncates():
    r = np.array([[1.0], [0.0], [100.0]])
    d = np.array([[1], [1], [1]])
    times = [1.0, 1.0, 1.0]
    # budget 2.0 -> only first two episodes counted
    assert evaluate.final_time_metric(r, d, times, 2.0) == 0.5
    assert evaluate.final_time_metric(r, d, times, 10.0) > 30


def test_required_time_metric():
    r = np.array([[0.0], [0.0], [1.0], [1.0]])
    d = np.ones((4, 1))
    t = evaluate.required_time_metric(r, d, [1.0] * 4, target=0.5,
                                      window=2)
    assert t == 3.0
    assert evaluate.required_time_metric(r, d, [1.0] * 4, target=2.0) \
        == float("inf")


def test_bootstrap_ci_contains_mean():
    x = np.random.default_rng(0).normal(3.0, 1.0, size=200)
    mean, lo, hi = evaluate.bootstrap_ci(x, n_boot=2000)
    assert lo < mean < hi
    assert lo < 3.0 < hi


def test_evaluate_policy_runs():
    env = catch.make()

    def policy(params, obs):
        B = obs.shape[0]
        return jnp.zeros((B, env.n_actions)), jnp.zeros(B)

    rets = evaluate.evaluate_policy(policy, None, env, n_episodes=3,
                                    max_steps=20, noop_max=2)
    assert rets.shape == (3,)
    assert np.isfinite(rets).all()
