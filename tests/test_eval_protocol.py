"""Paper Sec. 5 evaluation protocol module."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import evaluate
from repro.envs import catch

def test_episode_returns_from_stream():
    r = np.array([[1.0, 0.5], [2.0, 0.5], [3.0, 0.5]])
    d = np.array([[0, 1], [1, 0], [0, 1]])
    eps = evaluate.episode_returns_from_stream(r, d)
    np.testing.assert_allclose(eps, [0.5, 3.0, 1.0])


def _random_stream(rng, T, N, integers):
    if integers:
        r = rng.integers(-10, 10, size=(T, N)).astype(np.float64)
    else:
        r = rng.normal(size=(T, N)) * 50
    d = rng.random((T, N)) < 0.3
    return r, d


def test_vectorized_episode_returns_match_loop_fuzz():
    """Fixed-seed fuzz of the vectorized implementation against the
    Python-loop oracle (the open-ended hypothesis version lives in
    tests/test_properties.py): bit-exact on integer-valued rewards,
    rounding-tolerance on arbitrary floats, every (T, N) shape incl.
    T=0 and no-done streams."""
    rng = np.random.default_rng(0)
    for case in range(200):
        T, N = int(rng.integers(0, 9)), int(rng.integers(1, 6))
        integers = bool(case % 2)
        r, d = _random_stream(rng, T, N, integers)
        got = evaluate.episode_returns_from_stream(r, d)
        want = evaluate._episode_returns_loop(r, d)
        if integers:
            np.testing.assert_array_equal(got, want)
        else:
            np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_return_stream_chunking_invariant_fuzz():
    """Feeding a stream through ReturnStream in ANY chunking produces
    exactly the one-shot result — episodes spanning chunk (checkpoint)
    boundaries are counted once, with the right return."""
    rng = np.random.default_rng(1)
    for _ in range(100):
        T, N = int(rng.integers(0, 12)), int(rng.integers(1, 5))
        r, d = _random_stream(rng, T, N, integers=True)
        cuts = rng.integers(0, T + 1, size=rng.integers(0, 4))
        bounds = sorted({int(c) for c in cuts} | {0, T})
        rs = evaluate.ReturnStream(N)
        for lo, hi in zip(bounds, bounds[1:]):
            rs.extend(r[lo:hi], d[lo:hi])
        np.testing.assert_array_equal(
            rs.returns, evaluate.episode_returns_from_stream(r, d))


def test_return_stream_float_boundary_drift_is_ulp_scale():
    """The DESIGN.md §1.1 "~1 ulp" claim, pinned with numbers: for
    arbitrary FLOAT rewards, chunking a stream across episode-spanning
    boundaries re-associates each env's partial-episode accumulator sum,
    so chunked returns may differ from the one-shot computation — but
    only by rounding, bounded by a few spacings of the cumulative-sum
    magnitude, never by a misattributed step. Integer-valued rewards
    stay bit-exact (exact f64 cumsums)."""
    rng = np.random.default_rng(7)
    worst_rel = 0.0
    for _ in range(300):
        T, N = int(rng.integers(2, 40)), int(rng.integers(1, 5))
        r = rng.normal(size=(T, N)) * rng.choice([1e-3, 1.0, 1e6])
        # sparse dones so most episodes span several chunks
        d = rng.random((T, N)) < 0.08
        d[-1] = True                       # close every episode
        cuts = sorted({0, T} | {int(c) for c in
                                rng.integers(1, T, size=3)})
        rs = evaluate.ReturnStream(N)
        for lo, hi in zip(cuts, cuts[1:]):
            rs.extend(r[lo:hi], d[lo:hi])
        one_shot = evaluate.episode_returns_from_stream(r, d)
        chunked = rs.returns
        # same episodes, same order — drift can only live in the values
        assert chunked.shape == one_shot.shape
        # scale of one rounding step at the accumulator's magnitude: the
        # cumulative env sums are what actually get re-associated
        scale = np.abs(np.cumsum(r, axis=0)).max() + 1.0
        drift = np.abs(chunked - one_shot)
        assert drift.max() <= 4 * np.spacing(scale), (
            drift.max(), np.spacing(scale))
        if one_shot.size:
            denom = np.maximum(np.abs(one_shot), scale * 1e-12)
            worst_rel = max(worst_rel, float((drift / denom).max()))
    # the headline number: across 300 adversarial streams the worst
    # relative drift stays at double-precision noise level
    assert worst_rel < 1e-9, worst_rel


def test_return_stream_float_integer_valued_still_bitexact():
    """Integer-valued float rewards (every env in this repo) hit the
    exact-f64-cumsum path: ANY chunking is bit-equal to one-shot."""
    rng = np.random.default_rng(8)
    for _ in range(100):
        T, N = int(rng.integers(1, 30)), int(rng.integers(1, 4))
        r = rng.integers(-1000, 1000, size=(T, N)).astype(np.float64)
        d = rng.random((T, N)) < 0.15
        rs = evaluate.ReturnStream(N)
        for t in range(T):                 # worst case: 1-row chunks
            rs.extend(r[t:t + 1], d[t:t + 1])
        np.testing.assert_array_equal(
            rs.returns, evaluate.episode_returns_from_stream(r, d))


def test_return_stream_state_roundtrip():
    rs = evaluate.ReturnStream(2)
    rs.extend(np.array([[1.0, 2.0], [3.0, 4.0]]),
              np.array([[0, 1], [0, 0]]))
    rs2 = evaluate.ReturnStream(2).load_state_dict(
        __import__("json").loads(__import__("json").dumps(rs.state_dict())))
    rs.extend(np.array([[5.0, 6.0]]), np.array([[1, 1]]))
    rs2.extend(np.array([[5.0, 6.0]]), np.array([[1, 1]]))
    np.testing.assert_array_equal(rs.returns, rs2.returns)
    with pytest.raises(ValueError):
        evaluate.ReturnStream(3).load_state_dict(rs.state_dict())


def test_final_time_metric_truncates():
    r = np.array([[1.0], [0.0], [100.0]])
    d = np.array([[1], [1], [1]])
    times = [1.0, 1.0, 1.0]
    # budget 2.0 -> only first two episodes counted
    assert evaluate.final_time_metric(r, d, times, 2.0) == 0.5
    assert evaluate.final_time_metric(r, d, times, 10.0) > 30


def test_required_time_metric():
    r = np.array([[0.0], [0.0], [1.0], [1.0]])
    d = np.ones((4, 1))
    t = evaluate.required_time_metric(r, d, [1.0] * 4, target=0.5,
                                      window=2)
    assert t == 3.0
    assert evaluate.required_time_metric(r, d, [1.0] * 4, target=2.0) \
        == float("inf")


def test_bootstrap_ci_contains_mean():
    x = np.random.default_rng(0).normal(3.0, 1.0, size=200)
    mean, lo, hi = evaluate.bootstrap_ci(x, n_boot=2000)
    assert lo < mean < hi
    assert lo < 3.0 < hi


def test_evaluate_policy_runs():
    env = catch.make()

    def policy(params, obs):
        B = obs.shape[0]
        return jnp.zeros((B, env.n_actions)), jnp.zeros(B)

    rets = evaluate.evaluate_policy(policy, None, env, n_episodes=3,
                                    max_steps=20, noop_max=2)
    assert rets.shape == (3,)
    assert np.isfinite(rets).all()
