"""Checkpointing, data pipeline, determinism utilities."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import io as ckpt
from repro.core import delayed_grad
from repro.data.pipeline import TokenStream, traj_to_batch
from repro.optim import adam


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
              "b": [jnp.ones(4), {"c": jnp.zeros((), jnp.int32)}]}
    dg = delayed_grad.init(params, adam(1e-3))
    path = str(tmp_path / "step_00000001")
    ckpt.save(path, dg, {"note": "test"})
    restored = ckpt.restore(path, jax.eval_shape(lambda: dg))
    for a, b in zip(jax.tree.leaves(dg), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert ckpt.latest(str(tmp_path)) == path


def test_latest_skips_torn_capsule(tmp_path):
    """A manifest whose .npz half is missing (kill between the two file
    writes, or a partial copy) must not be selected by latest() —
    resume falls back to the previous COMPLETE checkpoint."""
    import os
    tree = {"w": jnp.arange(4.0)}
    ckpt.save(str(tmp_path / "step_00000001"), tree, {})
    ckpt.save(str(tmp_path / "step_00000002"), tree, {})
    os.remove(tmp_path / "step_00000002.npz")      # tear the newest
    assert ckpt.latest(str(tmp_path)) == str(tmp_path / "step_00000001")


def test_latest_returns_none_when_only_torn(tmp_path):
    import os
    ckpt.save(str(tmp_path / "step_00000001"), {"w": jnp.ones(2)}, {})
    os.remove(tmp_path / "step_00000001.npz")
    assert ckpt.latest(str(tmp_path)) is None


def test_restore_prefix_reads_leading_leaves(tmp_path):
    """restore_prefix pulls the FIRST len(like) leaves of a larger
    capsule — the params-only read serving relies on — and fails loudly
    when the leading leaves do not match the template's shapes."""
    params = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)}
    dg = delayed_grad.init(params, adam(1e-3))
    path = str(tmp_path / "step_00000001")
    ckpt.save(path, dg, {})
    got = ckpt.restore_prefix(path, jax.eval_shape(lambda: params))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="leading leaves"):
        ckpt.restore_prefix(path, {"w": jnp.zeros((5, 5))})
    with pytest.raises(ValueError, match="prefix template needs"):
        ckpt.restore_prefix(path, dict(dg_extra=jnp.zeros(1),
                                       **{f"x{i}": jnp.zeros(1)
                                          for i in range(40)}))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "step_00000001")
    ckpt.save(path, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(path, {"w": jax.ShapeDtypeStruct((3, 2), jnp.float32)})


def test_checkpoint_leaf_count_mismatch_raises(tmp_path):
    """Historical bug: restore silently zipped mismatched leaf counts in
    flatten order. Now both directions fail with a clear error."""
    path = str(tmp_path / "step_00000001")
    ckpt.save(path, {"w": jnp.ones(2), "b": jnp.zeros(2)})
    with pytest.raises(ValueError, match="leaves"):
        ckpt.restore(path, {"w": jnp.ones(2)})
    with pytest.raises(ValueError, match="leaves"):
        ckpt.restore(path, {"w": jnp.ones(2), "b": jnp.zeros(2),
                            "extra": jnp.zeros(2)})


def test_checkpoint_treedef_mismatch_raises(tmp_path):
    """Same leaf count, different structure (renamed key): the treedef
    recorded in the manifest catches it."""
    path = str(tmp_path / "step_00000001")
    ckpt.save(path, {"w": jnp.ones(2), "b": jnp.zeros(3)})
    with pytest.raises(ValueError, match="structure"):
        ckpt.restore(path, {"w": jnp.ones(2), "bias": jnp.zeros(3)})


def test_checkpoint_dtype_mismatch_raises(tmp_path):
    path = str(tmp_path / "step_00000001")
    ckpt.save(path, {"w": jnp.ones(2, jnp.float32)})
    with pytest.raises(ValueError, match="dtype"):
        ckpt.restore(path, {"w": jnp.ones(2, jnp.int32)})


def test_checkpoint_manifest_and_metadata(tmp_path):
    path = str(tmp_path / "step_00000007")
    ckpt.save(path, {"w": jnp.ones((2, 2), jnp.bfloat16)},
              {"intervals": 7, "runtime": "mesh"})
    m = ckpt.load_manifest(path)
    assert m["version"] == ckpt.FORMAT_VERSION
    assert m["dtypes"] == ["bfloat16"] and m["shapes"] == [[2, 2]]
    assert ckpt.load_metadata(path) == {"intervals": 7, "runtime": "mesh"}
    assert ckpt.load_manifest(str(tmp_path / "nope")) is None


def test_token_stream_deterministic_and_learnable():
    s1 = TokenStream(64, 4, 16, seed=3)
    s2 = TokenStream(64, 4, 16, seed=3)
    b1, b2 = s1.next_batch(), s2.next_batch()
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # targets really are the table successor of tokens
    nxt = s1.table[b1["tokens"]]
    np.testing.assert_array_equal(np.asarray(nxt),
                                  np.asarray(b1["actions"]))


def test_traj_to_batch_layout():
    T, N = 5, 3
    traj = {
        "obs": jnp.arange(T * N).reshape(T, N),
        "actions": jnp.zeros((T, N), jnp.int32),
        "rewards": jnp.ones((T, N)),
        "dones": jnp.zeros((T, N)),
        "behavior_logprob": jnp.zeros((T, N)),
    }
    values = jnp.zeros((T, N))
    batch = traj_to_batch(traj, values, jnp.zeros(N), gamma=0.9)
    assert batch["tokens"].shape == (N, T)     # envs-as-batch
    assert batch["returns"].shape == (N, T)
    # returns grow toward the past under constant reward
    assert float(batch["returns"][0, 0]) > float(batch["returns"][0, -1])


def test_microbatch_equivalence():
    import dataclasses
    from repro.configs.base import get_config
    from repro.core import learner
    from repro.models import backbone
    from repro.optim import sgd
    cfg = get_config("h2o-danube-3-4b").reduced()
    params = backbone.init_params(cfg, jax.random.key(0))
    opt = sgd(0.05)
    B, S = 4, 16
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                     cfg.vocab_size),
        "actions": jax.random.randint(jax.random.key(2), (B, S), 0,
                                      cfg.vocab_size),
        "advantages": jax.random.normal(jax.random.key(3), (B, S)),
        "returns": jnp.ones((B, S)),
        "behavior_logprob": -jnp.ones((B, S)),
        "loss_mask": jnp.ones((B, S)),
    }
    dg = delayed_grad.init(params, opt)
    d1, _ = jax.jit(learner.make_train_step(cfg, opt, n_microbatches=1))(
        dg, batch)
    d2, _ = jax.jit(learner.make_train_step(cfg, opt, n_microbatches=2))(
        dg, batch)
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                     b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(d1.params),
                               jax.tree.leaves(d2.params)))
    assert diff < 5e-3
