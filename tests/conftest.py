import os
import sys

# smoke tests and benches must see 1 CPU device (the 512-device override
# lives ONLY in repro.launch.dryrun)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_addoption(parser):
    parser.addoption(
        "--update-goldens", action="store_true", default=False,
        help="rewrite tests/goldens/*.json from the current run instead "
             "of asserting against them (tests/test_goldens.py)")
