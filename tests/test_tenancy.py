"""Multi-tenant pool (repro.tenancy): the fair-share determinism
contract of DESIGN.md §13.

The load-bearing claims, in suite order:

* the stride schedule is a pure function of (admission order, weights,
  quanta, interval budgets) — two pools over the same inputs produce
  the SAME grant trace, and grants split proportionally to weights;
* multiplexing is invisible: every tenant of a heterogeneous pool
  (different envs, algorithms, staleness, runtimes, weights) finishes
  with params and reward/episode streams BIT-IDENTICAL to its solo
  ``run(n)`` — including across a mid-pool evict + readmit and through
  one tenant's injected fault storm (per-tenant fault domains);
* ``max_concurrency`` changes wall-clock only, never results;
* multi-model serving answers each (model, obs, seed) request
  bit-identically to a single-model server of that tenant, regardless
  of cross-model batch composition;
* the isolation baseline underneath it all: sequential ``build(spec)``
  Sessions in one process share nothing (no observer, fault-injector,
  or parameter leakage).
"""
import dataclasses
from fractions import Fraction

import numpy as np
import jax
import pytest

from repro import api
from repro.core import evaluate
from repro.faults import FaultPlan
from repro.serve import PolicyServer, ServeConfig
from repro.tenancy import TenancyConfig, TenantPool, capsule_params


# ------------------------------------------------------------- helpers
def _spec(env="catch", algorithm="a2c", seed=3, intervals=3, runtime="host",
          weight=1, quantum=1, name=None, staleness=1, env_kwargs=None,
          faults=None):
    """A tiny tenant spec: alpha 3 x 4 envs keeps every slice cheap."""
    d = {
        "env": {"name": env, "kwargs": env_kwargs or {}},
        "algorithm": algorithm,
        "runtime": runtime,
        "hts": {"alpha": 3, "n_envs": 4, "seed": seed,
                "staleness": staleness},
        "intervals": intervals,
        "tenancy": {"weight": weight, "quantum": quantum, "name": name},
    }
    if faults is not None:
        d["faults"] = faults
    return api.from_dict(d)


def _solo(spec):
    """The oracle: a fresh solo run of the tenant's workload (faults
    stripped — the recovery guarantee says supervised results equal the
    fault-free run, and solo ``Session.run`` has no supervisor)."""
    out = api.build(dataclasses.replace(spec, faults=FaultPlan())) \
             .run(spec.intervals)
    stream = evaluate.ReturnStream(spec.hts.get("n_envs", 4))
    stream.extend(out.rewards, out.dones)
    return out, stream.returns


def _assert_tenant_equals_solo(res, spec):
    out, solo_returns = _solo(spec)
    assert res.status == "done"
    assert res.intervals == spec.intervals
    for a, b in zip(jax.tree.leaves(res.params),
                    jax.tree.leaves(out.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(res.rewards, np.asarray(out.rewards))
    np.testing.assert_array_equal(res.dones, np.asarray(out.dones))
    np.testing.assert_array_equal(res.episode_returns, solo_returns)


# -------------------------------------------------------------- config
def test_tenancy_config_validation():
    assert TenancyConfig().is_default
    assert TenancyConfig.of(None).is_default
    assert TenancyConfig.of({"weight": 3}).weight == 3
    assert TenancyConfig.of({"weight": 2, "quantum": 4, "name": "x"}) \
        .canonical() == {"weight": 2, "quantum": 4, "name": "x"}
    for bad in ({"weight": 0}, {"quantum": 0}, {"weight": -1},
                {"nope": 1}, {"name": ""}):
        with pytest.raises((ValueError, TypeError)):
            TenancyConfig.of(bad)


def test_spec_carries_tenancy_but_fingerprint_ignores_it():
    """The tenancy block is pool policy, not workload: two specs that
    differ only in tenancy are the SAME experiment (their results are
    bit-identical by the multiplexing-invisibility contract), so the
    fingerprint must not fork benchmark baselines over it."""
    a = _spec(weight=1, quantum=1)
    b = _spec(weight=5, quantum=2, name="vip")
    assert a.tenancy.weight == 1 and b.tenancy.name == "vip"
    assert api.loads(api.dumps(b)).tenancy == b.tenancy
    assert api.workload_fingerprint(a) == api.workload_fingerprint(b)


# ----------------------------------------------------------- scheduler
def test_stride_schedule_is_deterministic_and_weighted():
    """Schedule-side purity: the grant trace is a function of scheduler
    inputs alone. Two pools over the same specs emit identical traces,
    and granted intervals split 3:2:1 with weights 3:2:1."""
    def make_pool():
        return TenantPool([
            _spec(seed=3, intervals=6, weight=3, quantum=1, name="w3"),
            _spec(seed=4, intervals=6, weight=2, quantum=1, name="w2"),
            _spec(seed=5, intervals=6, weight=1, quantum=1, name="w1"),
        ])

    def schedule_only(pool):
        # drive _next/_grant without executing: the schedule never
        # consults execution results, so this IS the run's grant order
        while True:
            t = pool._next()
            if t is None:
                return list(pool.trace)
            pool._grant(t)

    p1, p2 = make_pool(), make_pool()
    tr1, tr2 = schedule_only(p1), schedule_only(p2)
    assert tr1 == tr2
    # first grants follow admission order (all passes start equal) ...
    assert [n for n, _, _ in tr1[:3]] == ["w3", "w2", "w1"]
    # ... and over the first 6 grants shares track weights 3:2:1
    counts = {"w3": 0, "w2": 0, "w1": 0}
    for name, _, n in tr1[:6]:
        counts[name] += n
    assert counts == {"w3": 3, "w2": 2, "w1": 1}
    # every tenant reaches exactly its budget, in quantum-sized slices
    assert p1.schedule_counts() == {"w3": 6, "w2": 6, "w1": 6}
    # pass accounting is exact rationals, not floats
    assert all(isinstance(t.passv, Fraction)
               for t in p1._tenants.values())


def test_quantum_slices_and_tail_grant():
    """quantum=4 against a budget of 6: one full slice then the 2-
    interval tail — never a grant past the budget."""
    pool = TenantPool([_spec(intervals=6, quantum=4, name="t")])
    while pool._next() is not None:
        pool._grant(pool._next())
    assert pool.trace == [("t", 0, 4), ("t", 4, 2)]


# ----------------------------------------------- pool vs solo (flagship)
def test_heterogeneous_pool_bit_exact_to_solo_with_chaos():
    """The acceptance pool: three heterogeneous tenants (catch/a2c/mesh
    vs seeded-gridmaze/ppo/K=2/mesh vs catch/a2c/host), distinct
    weights and quanta, overlapped execution — PLUS a mid-pool evict +
    readmit of the maze tenant and a 2-event fault storm confined to
    the host tenant. Every tenant's final params and full streams must
    equal its solo run bit-exactly; the storm must actually fire
    (restarts recorded) and stay inside its fault domain."""
    spec_a = _spec(env="catch", algorithm="a2c", runtime="mesh", seed=5,
                   intervals=4, weight=3, quantum=2, name="catch-mesh")
    spec_b = _spec(env="gridmaze", env_kwargs={"scenario_seed": 7},
                   algorithm="ppo", runtime="mesh", seed=9, staleness=2,
                   intervals=3, weight=1, quantum=1, name="maze")
    spec_c = _spec(env="catch", algorithm="a2c", runtime="host", seed=2,
                   intervals=4, weight=2, quantum=2, name="stormy",
                   faults={"events": [["stepper", 1], ["executor", 2]],
                           "max_restarts": 3, "backoff": 0.01})

    phase = {"evicted": False, "readmitted": False}

    def chaos(name, done, _out):
        # evict the maze tenant at its first boundary; readmit it at
        # the next OTHER tenant's boundary — both at commit points, the
        # only places lifecycle ops are legal
        if name == "maze" and done == 1 and not phase["evicted"]:
            partial = pool.evict("maze")
            assert partial.status == "evicted"
            assert partial.intervals >= 1
            phase["evicted"] = True
        elif phase["evicted"] and not phase["readmitted"] \
                and name != "maze":
            pool.readmit("maze")
            phase["readmitted"] = True

    pool = TenantPool([spec_a, spec_b, spec_c], max_concurrency=2,
                      on_slice=chaos)
    results = pool.run()

    assert phase == {"evicted": True, "readmitted": True}
    assert set(results) == {"catch-mesh", "maze", "stormy"}
    # the storm fired and was absorbed by the tenant's own supervisor
    assert results["stormy"].restarts >= 2
    assert results["catch-mesh"].restarts == 0
    assert results["maze"].restarts == 0
    for spec in (spec_a, spec_b, spec_c):
        _assert_tenant_equals_solo(results[spec.tenancy.name], spec)


def test_max_concurrency_changes_wallclock_only():
    """mc=1 (strict time-slicing) and mc=3 (overlapped) produce the
    same grant trace and bit-identical results."""
    specs = lambda: [_spec(seed=11, intervals=3, name="p"),
                     _spec(seed=12, intervals=3, weight=2, name="q")]
    seq = TenantPool(specs(), max_concurrency=1)
    ovl = TenantPool(specs(), max_concurrency=3)
    r1, r2 = seq.run(), ovl.run()
    assert seq.trace == ovl.trace
    for name in ("p", "q"):
        for a, b in zip(jax.tree.leaves(r1[name].params),
                        jax.tree.leaves(r2[name].params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(r1[name].rewards, r2[name].rewards)
        np.testing.assert_array_equal(r1[name].episode_returns,
                                      r2[name].episode_returns)


def test_pool_step_microscope_and_late_admission():
    """step() drives one grant at a time; a tenant admitted mid-run
    starts at the minimum active pass (shares from NOW) and still
    finishes bit-exact to solo."""
    pool = TenantPool([_spec(seed=21, intervals=2, name="early")])
    assert pool.step()                      # early: interval 0
    late_spec = _spec(seed=22, intervals=2, name="late")
    pool.admit(late_spec)
    # the late arrival joins at the current minimum active pass — it
    # shares from NOW instead of bursting to repay the pool's history
    assert pool._get("late").passv == pool._get("early").passv
    assert isinstance(pool._get("late").passv, Fraction)
    while pool.step():
        pass
    results = pool.results()
    assert results["early"].status == "done"
    _assert_tenant_equals_solo(results["late"], late_spec)


# ------------------------------------------------------------ lifecycle
def test_lifecycle_state_machine_is_loud():
    pool = TenantPool([_spec(name="a"), _spec(seed=4, name="b")])
    with pytest.raises(ValueError, match="already admitted"):
        pool.admit(_spec(seed=5, name="a"))
    with pytest.raises(KeyError, match="no tenant"):
        pool.pause("ghost")
    pool.pause("a")
    with pytest.raises(ValueError, match="cannot pause"):
        pool.pause("a")                     # already paused
    with pytest.raises(ValueError, match="cannot readmit"):
        pool.readmit("a")                   # paused, not evicted
    pool.resume("a")
    with pytest.raises(ValueError, match="cannot resume"):
        pool.resume("a")                    # already active
    pool.evict("b")
    assert pool.status("b") == "evicted"
    pool.readmit("b")
    results = pool.run()
    assert all(r.status == "done" for r in results.values())
    with pytest.raises(ValueError, match="already completed"):
        pool.evict("a")


def test_paused_tenant_gets_no_grants_and_reports_partial():
    pool = TenantPool([_spec(seed=6, intervals=2, name="run"),
                       _spec(seed=7, intervals=2, name="hold")],
                      max_concurrency=1)
    pool.pause("hold")
    results = pool.run()
    assert results["run"].status == "done"
    assert results["hold"].status == "paused"
    assert results["hold"].intervals == 0
    assert results["hold"].params is None
    assert pool.schedule_counts() == {"run": 2, "hold": 0}


def test_pool_constructor_validation():
    with pytest.raises(ValueError, match="max_concurrency"):
        TenantPool([], max_concurrency=0)
    with pytest.raises(ValueError, match="align"):
        TenantPool([_spec()], weights=[1, 2])


# ------------------------------------------------------- multi-model serve
def _probe_obs(session, n, seed=0):
    _, obs = jax.vmap(session.env.reset)(
        jax.random.split(jax.random.key(seed), n))
    return np.asarray(obs)


def test_multi_model_answers_match_single_model_servers():
    """The serving acceptance claim: a (model, obs, seed) request to
    the multi-model server answers bit-identically to that model's own
    single-model server, even when its dispatch batch is packed with
    the OTHER model's requests (different obs shape and all)."""
    sa = api.build(_spec(env="catch", seed=5, name="ma"))
    sb = api.build(_spec(env="gridmaze", seed=9, name="mb",
                         env_kwargs={"scenario_seed": 7}))
    cfg = ServeConfig(max_batch=8, timeout_ms=20.0)
    obs_a, obs_b = _probe_obs(sa, 4), _probe_obs(sb, 4, seed=1)

    def single(session, obs, seed):
        srv = PolicyServer(session.policy.apply, session.params,
                           obs_like=obs[0], serve=cfg,
                           seed=session.cfg.seed).start()
        try:
            return srv.act(obs[0], seed=seed)
        finally:
            srv.stop()

    ref_a = single(sa, obs_a, seed=7)
    ref_b = single(sb, obs_b, seed=13)

    multi = PolicyServer(sa.policy.apply, sa.params, obs_like=obs_a[0],
                         serve=cfg, seed=sa.cfg.seed, model="ma")
    multi.add_model("mb", sb.policy.apply, sb.params,
                    obs_like=obs_b[0], seed=sb.cfg.seed)
    # stage a mixed batch: both probes plus fillers of BOTH models
    # queue before the dispatcher starts, so one gather drains them all
    fa = multi.submit(obs_a[0], seed=7, model="ma")
    fb = multi.submit(obs_b[0], seed=13, model="mb")
    fillers = [multi.submit(obs_a[i], seed=100 + i, model="ma")
               for i in range(1, 4)]
    fillers += [multi.submit(obs_b[i], seed=200 + i, model="mb")
                for i in range(1, 4)]
    multi.start()
    got_a, got_b = fa.result(timeout=30), fb.result(timeout=30)
    for f in fillers:
        f.result(timeout=30)
    multi.stop()

    assert got_a.action == ref_a.action
    assert got_a.logprob == ref_a.logprob
    assert got_b.action == ref_b.action
    assert got_b.logprob == ref_b.logprob
    stats = multi.stats()
    assert set(stats["models"]) == {"ma", "mb"}
    assert stats["models"]["ma"]["n_requests"] == 4
    assert stats["models"]["mb"]["n_requests"] == 4


def test_multi_model_unknown_model_and_shape_are_loud():
    sa = api.build(_spec(env="catch", seed=5))
    obs = _probe_obs(sa, 1)
    srv = PolicyServer(sa.policy.apply, sa.params, obs_like=obs[0],
                       serve=ServeConfig(max_batch=4), seed=3,
                       model="only")
    with pytest.raises(KeyError, match="only"):
        srv.submit(obs[0], model="ghost")
    with pytest.raises(ValueError, match="already"):
        srv.add_model("only", sa.policy.apply, sa.params,
                      obs_like=obs[0])
    with pytest.raises(ValueError):
        srv.submit(np.zeros((3, 3), np.float32), model="only")


def test_pool_serve_routes_every_tenant():
    """pool.serve(): one server, one dispatcher, every tenant's policy
    behind its name — serving each tenant's CURRENT capsule params
    (== final params after run()), answers equal to a single-model
    server over the same params."""
    pool = TenantPool([_spec(env="catch", seed=5, name="ta"),
                       _spec(env="gridmaze", seed=9, name="tb",
                             env_kwargs={"scenario_seed": 7})],
                      max_concurrency=1)
    results = pool.run()
    server = pool.serve()
    try:
        sa = pool._get("ta").session
        obs = _probe_obs(sa, 1)
        got = server.act(obs[0], seed=17, model="ta")
        solo = PolicyServer(sa.policy.apply, results["ta"].params,
                            obs_like=obs[0], serve=sa.spec.serve,
                            seed=sa.cfg.seed).start()
        try:
            ref = solo.act(obs[0], seed=17)
        finally:
            solo.stop()
        assert got.action == ref.action
        assert got.logprob == ref.logprob
        assert sorted(server.models()) == ["ta", "tb"]
    finally:
        server.stop()
    with pytest.raises(ValueError, match="empty pool"):
        TenantPool().serve()


def test_capsule_params_prefix_and_shape_check():
    s = api.build(_spec(seed=5))
    state = s.state()
    p = capsule_params(state, s.params)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(s.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    bad = jax.tree.map(lambda x: np.zeros(x.shape + (2,), x.dtype),
                       s.params)
    with pytest.raises(ValueError, match="shape"):
        capsule_params(state, bad)


# -------------------------------------------- isolation baseline (solo)
def test_sequential_sessions_share_nothing():
    """The baseline under the pool: building and running Sessions
    back-to-back in ONE process leaks nothing between them — a rebuild
    of the first spec reproduces its results bit-exactly, and an
    observer registered on one session never hears another's run."""
    spec_a = _spec(env="catch", seed=31, intervals=2)
    spec_b = _spec(env="gridmaze", algorithm="ppo", seed=32, intervals=2,
                   env_kwargs={"scenario_seed": 7})

    first = api.build(spec_a)
    heard_a = []
    first.on_interval(lambda m: heard_a.append(m["interval"]))
    out_a1 = first.run(2)
    assert heard_a == [0, 1]

    other = api.build(spec_b)
    out_b = other.run(2)
    assert heard_a == [0, 1]        # A's observer never heard B
    assert other._observers == []   # B inherited no observers

    # a session whose spec carries a fault plan builds its OWN
    # injector; merely building it must not arm anything process-wide
    api.build(_spec(seed=33, faults={"events": [["stepper", 0]],
                                     "max_restarts": 1}))

    again = api.build(spec_a)
    out_a2 = again.run(2)           # would raise if the injector leaked
    for a, b in zip(jax.tree.leaves(out_a1.params),
                    jax.tree.leaves(out_a2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(out_a1.rewards),
                                  np.asarray(out_a2.rewards))
    assert np.asarray(out_a1.rewards).shape != \
        np.asarray(out_b.rewards).shape or \
        not np.array_equal(np.asarray(out_a1.rewards),
                           np.asarray(out_b.rewards))


def test_pool_checkpoints_are_trainer_compatible(tmp_path):
    """A pool tenant's periodic checkpoints use the trainer's capsule
    format: Session.serve() (and --resume) consume them unchanged."""
    spec = dataclasses.replace(
        _spec(seed=41, intervals=2, name="ck"),
        checkpoint={"dir": str(tmp_path / "ck"), "every": 1, "keep": 2})
    pool = TenantPool([spec], max_concurrency=1)
    results = pool.run()
    from repro.checkpoint import io as ckpt_io
    latest = ckpt_io.latest(str(tmp_path / "ck"))
    assert latest is not None and latest.endswith("step_00000002")
    session = api.build(spec)
    # the checkpoint holds the continuation CAPSULE (like a solo
    # Trainer's), not the post-finalize reporting params
    restored = ckpt_io.restore_prefix(latest, session.params)
    expect = capsule_params(results["ck"].state, session.params)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(expect)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
