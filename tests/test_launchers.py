"""CLI launcher smoke tests (subprocess, reduced configs)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}


def _run(args, timeout=280):
    return subprocess.run([sys.executable, "-m", *args], env=ENV,
                          cwd=ROOT, capture_output=True, text=True,
                          timeout=timeout)


def test_train_cli():
    r = _run(["repro.launch.train", "--arch", "starcoder2-3b", "--reduced",
              "--steps", "3", "--batch", "2", "--seq", "16"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss=" in r.stdout


def test_serve_cli():
    r = _run(["repro.launch.serve", "--arch", "rwkv6-7b", "--reduced",
              "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decode" in r.stdout


def test_dryrun_cli_single():
    r = _run(["repro.launch.dryrun", "--arch", "rwkv6-7b", "--shape",
              "decode_32k", "--mesh", "pod", "--out",
              "/tmp/dryrun_test", "--tag", "citest"], timeout=400)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[OK]" in r.stdout


# ---------------------------------------------- launch.run --set edits
def _edited(spec_dict, *assignments):
    from repro.launch.run import _apply_set
    canon = spec_dict
    for a in assignments:
        _apply_set(canon, a)
    return canon


def test_set_edits_existing_field():
    from repro import api
    canon = api.ExperimentSpec().canonical()
    _edited(canon, "hts.staleness=2", "intervals=7")
    spec = api.from_dict(canon)
    assert spec.hts["staleness"] == 2 and spec.intervals == 7


def test_set_constructs_missing_optional_block():
    """A hand-written partial spec without a tenancy/serve block:
    ``--set tenancy.weight=2`` must mean 'default block, weight 2',
    not KeyError (the dotted-path walk consults a default spec's
    canonical form for known-but-absent names)."""
    from repro import api
    partial = {"env": {"name": "catch", "kwargs": {}}}
    _edited(partial, "tenancy.weight=2", "serve.max_batch=16",
            "checkpoint.every=3")
    spec = api.from_dict(partial)
    assert spec.tenancy.weight == 2
    assert spec.tenancy.quantum == 1          # rest of block defaulted
    assert spec.serve.max_batch == 16
    assert spec.checkpoint.every == 3


def test_set_missing_leaf_of_known_block():
    """The leaf may be absent from the edited dict too, as long as the
    default canonical form knows it."""
    partial = {"env": {"name": "catch", "kwargs": {}},
               "tenancy": {"weight": 3}}       # no quantum key
    _edited(partial, "tenancy.quantum=4")
    assert partial["tenancy"] == {"weight": 3, "quantum": 4}


def test_set_unknown_names_fail_loudly():
    from repro import api
    canon = api.ExperimentSpec().canonical()
    with pytest.raises(SystemExit, match="tennancy"):
        _edited(canon, "tennancy.weight=2")    # typo'd block
    with pytest.raises(SystemExit, match="wieght"):
        _edited(canon, "tenancy.wieght=2")     # typo'd leaf
    with pytest.raises(SystemExit):
        _edited(canon, "no_equals_sign")


def test_set_still_allows_new_hts_and_kwargs_keys():
    from repro import api
    canon = api.ExperimentSpec().canonical()
    _edited(canon, "hts.staleness=3", "env.kwargs.scenario_seed=7",
            "env.name=\"gridmaze\"")
    spec = api.from_dict(canon)
    assert spec.env.name == "gridmaze"
    assert spec.env.kwargs == {"scenario_seed": 7}
    assert spec.hts["staleness"] == 3
