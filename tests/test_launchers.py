"""CLI launcher smoke tests (subprocess, reduced configs)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}


def _run(args, timeout=280):
    return subprocess.run([sys.executable, "-m", *args], env=ENV,
                          cwd=ROOT, capture_output=True, text=True,
                          timeout=timeout)


def test_train_cli():
    r = _run(["repro.launch.train", "--arch", "starcoder2-3b", "--reduced",
              "--steps", "3", "--batch", "2", "--seq", "16"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss=" in r.stdout


def test_serve_cli():
    r = _run(["repro.launch.serve", "--arch", "rwkv6-7b", "--reduced",
              "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decode" in r.stdout


def test_dryrun_cli_single():
    r = _run(["repro.launch.dryrun", "--arch", "rwkv6-7b", "--shape",
              "decode_32k", "--mesh", "pod", "--out",
              "/tmp/dryrun_test", "--tag", "citest"], timeout=400)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[OK]" in r.stdout
