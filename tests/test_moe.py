"""MoE: capacity vs dropless equivalence, determinism, load-balance."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models import moe
from repro.models.moe_dropless import apply_moe_dropless


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-moe-1b-a400m").reduced()   # cf=4: no drops
    params = moe.init_moe(jax.random.key(0), cfg)
    x = (jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
         * 0.5).astype(jnp.bfloat16)
    return cfg, params, x


def test_dropless_equals_capacity_when_no_drops(setup):
    cfg, params, x = setup
    y1, a1 = moe.apply_moe(params, x, cfg)
    y2, a2 = apply_moe_dropless(params, x, cfg)
    err = float(jnp.max(jnp.abs(y1.astype(jnp.float32) -
                                y2.astype(jnp.float32))))
    assert err < 2e-2
    assert abs(float(a1 - a2)) < 1e-6


def test_dropless_handles_drop_regime(setup):
    """Where capacity drops tokens, dropless must still route all of them
    (outputs finite, and generally different from the dropping version)."""
    cfg, params, x = setup
    tight = dataclasses.replace(cfg, capacity_factor=0.5)
    y_cap, _ = moe.apply_moe(params, x, tight)
    y_drp, _ = apply_moe_dropless(params, x, tight)
    assert bool(jnp.isfinite(y_drp.astype(jnp.float32)).all())
    # the dropless result is the no-drop reference
    y_ref, _ = apply_moe_dropless(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_drp, np.float32),
                               np.asarray(y_ref, np.float32), atol=1e-3)


def test_router_deterministic_tiebreak(setup):
    cfg, params, x = setup
    y1, _ = moe.apply_moe(params, x, cfg)
    y2, _ = moe.apply_moe(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(y1, np.float32),
                                  np.asarray(y2, np.float32))


def test_dropless_grads_finite(setup):
    cfg, params, x = setup

    def loss(p):
        y, aux = apply_moe_dropless(p, x, cfg)
        return jnp.sum(y.astype(jnp.float32) ** 2) + aux

    g = jax.grad(loss)(params)
    assert all(bool(jnp.isfinite(l.astype(jnp.float32)).all())
               for l in jax.tree.leaves(g))
