"""HTS-RL core invariants: delayed gradient, buffers, losses, V-trace."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import delayed_grad, losses, vtrace
from repro.core.buffers import SlabRing
from repro.optim import sgd, rmsprop, adam, apply_updates


def test_delayed_gradient_update_rule():
    """theta_{j+1} = theta_j - eta * g(theta_{j-1}) exactly (SGD)."""
    opt = sgd(0.1)
    params = {"w": jnp.array([1.0, 2.0])}
    dg = delayed_grad.init(params, opt)
    g1 = {"w": jnp.array([1.0, 1.0])}
    dg = delayed_grad.update(dg, g1, opt)
    assert jnp.allclose(dg.params["w"], jnp.array([0.9, 1.9]))
    assert jnp.allclose(dg.params_prev["w"], jnp.array([1.0, 2.0]))
    g2 = {"w": jnp.array([0.5, 0.5])}
    dg = delayed_grad.update(dg, g2, opt)
    assert jnp.allclose(dg.params["w"], jnp.array([0.85, 1.85]))
    # structural lag is exactly one update
    assert jnp.allclose(dg.params_prev["w"], jnp.array([0.9, 1.9]))
    assert delayed_grad.behavior_lag(dg) == 1


def test_delayed_gradient_skip():
    opt = rmsprop(0.1)
    params = {"w": jnp.ones(3)}
    dg = delayed_grad.init(params, opt)
    dg2 = delayed_grad.update(dg, {"w": jnp.ones(3)}, opt,
                              skip=jnp.bool_(True))
    assert jnp.allclose(dg2.params["w"], params["w"])
    assert jnp.allclose(dg2.opt_state["sq"]["w"],
                        jnp.zeros(3))
    # skipped updates don't count: step == number of updates applied
    assert int(dg2.step) == 0
    dg3 = delayed_grad.update(dg2, {"w": jnp.ones(3)}, opt,
                              skip=jnp.bool_(False))
    assert int(dg3.step) == 1


def test_slab_ring_rotation_discipline():
    """Roles rotate with the interval index; slab j % n_slots is the
    SAME memory at intervals j and j + n_slots (preallocated, no
    per-interval allocation); the learner hand-off is by reference, not
    by copy. n_slots=2 is the paper's parity-swap double buffer."""
    spec = {"obs": ((2,), np.float32), "rewards": ((), np.float32)}
    sp = SlabRing(3, 4, spec)               # default: double buffer
    s0, b0 = sp.write_view(0)
    s1, b1 = sp.write_view(1)
    assert s0 is not s1 and b0 is not b1
    assert sp.write_view(2)[0] is s0       # parity reuse, same memory
    assert s0["obs"].shape == (3, 4, 2)
    assert b0.shape == (4, 2)
    s0["rewards"][1, 2] = 7.0
    traj = sp.as_traj(0)
    assert set(traj) == {"obs", "rewards", "bootstrap_obs"}
    assert float(traj["rewards"][1, 2]) == 7.0
    # by-reference hand-off: later slab writes are visible through a
    # traj taken BEFORE them (the coordinator's ring barrier, not a
    # copy, is what protects the learner)
    s0["rewards"][0, 0] = 3.0
    assert float(sp.as_traj(0)["rewards"][0, 0]) == 3.0


def test_slab_ring_staleness_depth():
    """A staleness-K ring holds K+1 distinct slabs: interval j's slab is
    reused exactly at j + K + 1, and the K intermediate intervals write
    K other slabs (what lets rollout run K intervals ahead)."""
    spec = {"obs": ((2,), np.float32)}
    ring = SlabRing(3, 4, spec, n_slots=4)          # K = 3
    slabs = [ring.write_view(j)[0] for j in range(4)]
    assert len({id(s) for s in slabs}) == 4
    assert ring.write_view(4)[0] is slabs[0]
    with pytest.raises(ValueError):
        SlabRing(3, 4, spec, n_slots=1)


def test_n_step_returns_manual():
    r = jnp.array([[1.0], [0.0], [2.0]])
    d = jnp.zeros((3, 1))
    bv = jnp.array([10.0])
    rets = losses.n_step_returns(r, d, bv, gamma=0.5)
    # R2 = 2 + .5*10 = 7; R1 = 0 + .5*7 = 3.5; R0 = 1 + .5*3.5 = 2.75
    np.testing.assert_allclose(np.asarray(rets[:, 0]), [2.75, 3.5, 7.0])


def test_n_step_returns_done_cuts_bootstrap():
    r = jnp.array([[1.0], [1.0]])
    d = jnp.array([[0.0], [1.0]])
    rets = losses.n_step_returns(r, d, jnp.array([100.0]), gamma=0.9)
    np.testing.assert_allclose(np.asarray(rets[:, 0]), [1.9, 1.0])


def test_gae_reduces_to_nstep_when_lambda_1():
    key = jax.random.key(0)
    r = jax.random.normal(key, (5, 3))
    d = jnp.zeros((5, 3))
    v = jax.random.normal(jax.random.key(1), (5, 3))
    bv = jax.random.normal(jax.random.key(2), (3,))
    adv, rets = losses.gae(r, d, v, bv, gamma=0.9, lam=1.0)
    rets2 = losses.n_step_returns(r, d, bv, gamma=0.9)
    np.testing.assert_allclose(np.asarray(rets), np.asarray(rets2),
                               atol=1e-5)


def test_vtrace_on_policy_equals_nstep_targets():
    """With behavior == target, V-trace vs = n-step returns (rho=c=1)."""
    T, B = 6, 2
    key = jax.random.key(3)
    lp = jax.random.normal(key, (T, B)) * 0.1
    r = jax.random.normal(jax.random.key(4), (T, B))
    d = jnp.zeros((T, B))
    v = jnp.zeros((T, B))
    bv = jnp.zeros((B,))
    out = vtrace.vtrace(lp, lp, r, d, v, bv, gamma=0.9)
    rets = losses.n_step_returns(r, d, bv, gamma=0.9)
    np.testing.assert_allclose(np.asarray(out.vs), np.asarray(rets),
                               atol=1e-4, rtol=1e-4)


def test_a2c_loss_zero_advantage_no_pg():
    logits = jax.random.normal(jax.random.key(5), (4, 8))
    values = jnp.zeros(4)
    actions = jnp.zeros(4, jnp.int32)
    st = losses.a2c_loss(logits, values, actions, jnp.zeros(4),
                         jnp.zeros(4))
    assert abs(float(st.pg)) < 1e-6


def test_optimizers_descend_quadratic():
    for opt in (sgd(0.1), rmsprop(0.05), adam(0.1)):
        p = {"w": jnp.array([3.0])}
        state = opt.init(p)
        for _ in range(60):
            g = {"w": 2 * p["w"]}
            upd, state = opt.update(g, state, p)
            p = apply_updates(p, upd)
        assert abs(float(p["w"][0])) < 0.5


def test_schedules():
    from repro.optim import schedules, sgd, apply_updates
    ws = schedules.warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(ws(0)) == 0.0
    assert abs(float(ws(10)) - 1.0) < 1e-6
    assert float(ws(100)) < float(ws(50)) < float(ws(10))
    assert abs(float(ws(100)) - 0.1) < 1e-6     # floor_ratio

    opt = schedules.scheduled(lambda lr: sgd(lr),
                              schedules.linear_decay(0.1, 10))
    p = {"w": jnp.array([1.0])}
    st = opt.init(p)
    upd, st = opt.update({"w": jnp.array([1.0])}, st, p)
    assert abs(float(upd["w"][0]) + 0.1) < 1e-6  # full lr at step 0
    assert int(st["step"]) == 1


def test_pg_dot_grads_match_einsum():
    from repro.models.layers import pg_dot
    x = jax.random.normal(jax.random.key(0), (4, 8)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.key(1), (8, 16)).astype(jnp.bfloat16)
    g0 = jax.grad(lambda w: pg_dot(x, w, enable=False).astype(
        jnp.float32).sum())(w)
    g1 = jax.grad(lambda w: pg_dot(x, w, enable=True).astype(
        jnp.float32).sum())(w)
    np.testing.assert_array_equal(np.asarray(g0, np.float32),
                                  np.asarray(g1, np.float32))
