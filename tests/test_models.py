"""Per-architecture smoke tests (reduced variants): forward + one train
step on CPU, output shapes, no NaNs; incremental decode == full forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_configs
from repro.core import delayed_grad, learner
from repro.models import backbone
from repro.optim import adam

ARCHS = list(list_configs())


def _inputs(cfg, B, S, key):
    kw = {}
    if cfg.is_encoder_decoder:
        kw["audio_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.enc_seq, cfg.d_model)
        ).astype(jnp.bfloat16) * 0.1
    if cfg.vision_prefix:
        kw["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.vision_prefix, cfg.d_model)
        ).astype(jnp.bfloat16) * 0.1
    if cfg.mrope:
        kw["mrope_positions"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))
    return kw


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    params = backbone.init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    key = jax.random.key(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = _inputs(cfg, B, S, key)
    hidden, _, aux = backbone.forward(params, cfg, tokens, **kw)
    assert hidden.shape == (B, S, cfg.d_model)
    logits, value = backbone.logits_and_value(params, cfg, hidden)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert value.shape == (B, S)
    assert not bool(jnp.isnan(logits).any())

    opt = adam(1e-4)
    dg = delayed_grad.init(params, opt)
    step = learner.make_train_step(cfg, opt)
    batch = {
        "tokens": tokens,
        "actions": jax.random.randint(jax.random.key(2), (B, S), 0,
                                      cfg.vocab_size),
        "advantages": jnp.ones((B, S)),
        "returns": jnp.ones((B, S)),
        "behavior_logprob": -jnp.ones((B, S)),
        "loss_mask": jnp.ones((B, S)),
    }
    if cfg.mrope:
        batch["mrope_positions"] = kw["mrope_positions"]
    if cfg.vision_prefix:
        batch["patch_embeds"] = kw["patch_embeds"]
    if cfg.is_encoder_decoder:
        batch["audio_embeds"] = kw["audio_embeds"]
    dg2, stats = jax.jit(step)(dg, batch)
    assert not bool(jnp.isnan(stats["loss"]))
    # params actually moved and behavior snapshot advanced
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(dg2.params),
                        jax.tree.leaves(dg.params)))
    assert moved
    assert int(dg2.step) == 1


@pytest.mark.parametrize("name", ARCHS)
def test_decode_consistency(name):
    cfg = get_config(name).reduced()
    params = backbone.init_params(cfg, jax.random.key(0))
    B, S = 2, 12
    key = jax.random.key(3)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = _inputs(cfg, B, S, key)
    h, _, _ = backbone.forward(params, cfg, tokens, **kw)
    lf, _ = backbone.logits_and_value(params, cfg, h)
    kwp = dict(kw)
    if cfg.mrope:
        kwp["mrope_positions"] = kw["mrope_positions"][:, :, :S - 1]
    _, _, cache = backbone.prefill(params, cfg, tokens[:, :S - 1],
                                   max_len=S, **kwp)
    dkw = {}
    if cfg.mrope:
        dkw["mrope_positions"] = jnp.full((3, B, 1), S - 1)
    if cfg.is_encoder_decoder:
        dkw["audio_embeds"] = kw["audio_embeds"]
    ld, _, _ = backbone.decode_step(params, cfg, tokens[:, S - 1:], cache,
                                    jnp.int32(S - 1), **dkw)
    err = float(jnp.max(jnp.abs(lf[:, -1] - ld)))
    scale = float(jnp.max(jnp.abs(lf[:, -1]))) + 1e-9
    assert err / scale < 0.05, f"{name}: rel err {err / scale}"


def test_chunked_loss_matches_full():
    cfg = get_config("starcoder2-3b").reduced()
    params = backbone.init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                     cfg.vocab_size),
        "actions": jax.random.randint(jax.random.key(2), (B, S), 0,
                                      cfg.vocab_size),
        "advantages": jax.random.normal(jax.random.key(3), (B, S)),
        "returns": jax.random.normal(jax.random.key(4), (B, S)),
        "behavior_logprob": -jnp.ones((B, S)),
        "loss_mask": jnp.ones((B, S)),
    }
    from repro.core.losses import a2c_loss
    total_chunked, st = learner.rl_loss(params, cfg, batch, loss_chunk=4)
    logits, values, aux = learner.policy_outputs(params, cfg, batch)
    st_full = a2c_loss(logits, values, batch["actions"],
                       batch["advantages"], batch["returns"],
                       mask=batch["loss_mask"])
    assert abs(float(st.total - st_full.total)) < 2e-2


def test_remainder_layers_path():
    """Layer counts not divisible by the mixer cycle (recurrentgemma's
    38 = 12*3 + 2) run the unrolled remainder path; verify with a toy
    4-layer cycle-3 config, including decode-cache handling."""
    import dataclasses
    cfg = dataclasses.replace(get_config("recurrentgemma-9b").reduced(),
                              n_layers=4)
    assert cfg.n_layers % cfg.cycle_len == 1
    params = backbone.init_params(cfg, jax.random.key(0))
    assert "rem" in params and len(params["rem"]) == 1
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                cfg.vocab_size)
    h, _, _ = backbone.forward(params, cfg, tokens)
    lf, _ = backbone.logits_and_value(params, cfg, h)
    _, _, cache = backbone.prefill(params, cfg, tokens[:, :S - 1],
                                   max_len=S)
    assert "rem" in cache
    ld, _, _ = backbone.decode_step(params, cfg, tokens[:, S - 1:], cache,
                                    jnp.int32(S - 1))
    err = float(jnp.max(jnp.abs(lf[:, -1] - ld)))
    scale = float(jnp.max(jnp.abs(lf[:, -1]))) + 1e-9
    assert err / scale < 0.05
