"""The SPS regression gate's comparability rules (benchmarks/check_sps).

A baseline is only valid when it measured the SAME code-independent
context: sweep shape (intervals), hardware (host fingerprint), and
workload (config fingerprint — alpha/n_envs/env/algorithm/staleness).
Records written before config fingerprinting are skipped as baselines,
loudly, rather than guessed about: a record produced with a different
HTSConfig silently becoming the gate's baseline is exactly the bug this
pins down.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import check_sps  # noqa: E402

KEY = "engine_sps_mesh"
CFG_A = {"env": "catch", "alpha": 8, "n_envs": 8, "staleness": 1}
CFG_B = {"env": "catch", "alpha": 8, "n_envs": 8, "staleness": 4}


def _rec(sps, cfg=CFG_A, host="h1", intervals=12, **extra):
    r = {"intervals": intervals, "host": host, "sps": {KEY: sps}}
    if cfg is not None:
        r["config"] = cfg
    r.update(extra)
    return r


def test_gate_compares_matching_config():
    ok, msg = check_sps.check([_rec(100.0), _rec(95.0)], KEY, 0.30)
    assert ok and msg.startswith("OK")
    ok, msg = check_sps.check([_rec(100.0), _rec(60.0)], KEY, 0.30)
    assert not ok and "REGRESSION" in msg


def test_different_config_is_not_a_baseline():
    """A K=4 sweep (different workload, naturally different SPS) must
    never gate a K=1 run — with no matching record the gate skips."""
    ok, msg = check_sps.check([_rec(1000.0, cfg=CFG_B), _rec(60.0)],
                              KEY, 0.30)
    assert ok and msg.startswith("skip")


def test_unfingerprinted_record_skips_loudly():
    """Pre-fingerprint records are skipped as baselines AND the skip
    message says so — a silently-vacuous gate is the failure mode."""
    ok, msg = check_sps.check([_rec(1000.0, cfg=None), _rec(60.0)],
                              KEY, 0.30)
    assert ok
    assert "no config fingerprint" in msg


def test_matching_config_found_behind_mismatches():
    """The baseline search walks past non-comparable records (other
    configs, other hosts, replays) to the most recent comparable one."""
    records = [
        _rec(100.0),                              # the true baseline
        _rec(1000.0, cfg=CFG_B),                  # different workload
        _rec(1000.0, host="h2"),                  # different hardware
        _rec(1000.0, cfg=None),                   # unfingerprinted
        _rec(1000.0, restored_runtimes=["mesh"]),  # replayed, not measured
        _rec(95.0),                               # current run
    ]
    ok, msg = check_sps.check(records, KEY, 0.30)
    assert ok and "baseline=100.0" in msg


def test_noisy_window_widens_its_own_tolerance():
    """The committed host entry swings 1330 -> 454 sps run to run; with
    the single-latest-record gate, a normal-for-this-key 600 sps run
    after a lucky 1330 would fail. The MAD-scaled floor of the window
    absorbs exactly the noise the window itself exhibits."""
    window = [1330.0, 454.0, 1200.0, 500.0, 1100.0]
    records = [_rec(v) for v in window] + [_rec(600.0)]
    ok, msg = check_sps.check(records, KEY, 0.30)
    assert ok, msg
    assert "median of 5" in msg


def test_quiet_window_keeps_ratio_floor():
    """A stable key (MAD ~ 0) gets no extra slack: the floor stays the
    plain (1 - max_regression) ratio."""
    window = [100.0, 101.0, 99.0, 100.0, 100.0]
    ok, msg = check_sps.check([_rec(v) for v in window] + [_rec(95.0)],
                              KEY, 0.30)
    assert ok, msg
    ok, msg = check_sps.check([_rec(v) for v in window] + [_rec(60.0)],
                              KEY, 0.30)
    assert not ok and "REGRESSION" in msg


def test_baseline_is_window_median_not_latest():
    """One outlier run must not become the whole baseline: the median of
    the window gates, not the most recent record."""
    records = [_rec(100.0), _rec(101.0), _rec(20.0), _rec(99.0)]
    ok, msg = check_sps.check(records, KEY, 0.30, window=3)
    assert ok, msg
    assert "baseline=100.0" in msg


def test_window_limits_lookback():
    """Only the newest ``window`` comparable records form the baseline:
    ancient faster runs age out instead of gating forever."""
    records = [_rec(1000.0)] + [_rec(100.0)] * 5 + [_rec(95.0)]
    ok, msg = check_sps.check(records, KEY, 0.30, window=5)
    assert ok, msg
    assert "baseline=100.0" in msg


def test_single_record_window_degenerates_to_ratio_gate():
    """window=1 (or only one comparable record) is the old behavior
    exactly: current vs latest at the ratio floor."""
    ok, _ = check_sps.check([_rec(100.0), _rec(71.0)], KEY, 0.30,
                            window=1)
    assert ok
    ok, msg = check_sps.check([_rec(100.0), _rec(69.0)], KEY, 0.30,
                              window=1)
    assert not ok and "REGRESSION" in msg


def test_device_rows_gate_independently():
    """Host and device rows are separate sps keys in one record; gating
    the device key never reads host numbers."""
    dkey = "engine_sps_mesh_device"
    recs = []
    for host_v, dev_v in [(100.0, 900.0), (100.0, 880.0)]:
        r = _rec(host_v)
        r["sps"][dkey] = dev_v
        recs.append(r)
    ok, msg = check_sps.check(recs, dkey, 0.30)
    assert ok and "baseline=900.0" in msg
    recs[-1]["sps"][dkey] = 100.0      # device regressed to host speed
    ok, msg = check_sps.check(recs, dkey, 0.30)
    assert not ok and "REGRESSION" in msg


def test_host_mismatch_skip_names_the_axis():
    """The 1cpu-vs-2cpu drift bug: when every candidate baseline is
    rejected because the runner's host fingerprint changed, the skip
    message must NAME that axis with both values — not print a generic
    "no comparable record" while the gate silently stops gating."""
    records = [_rec(100.0, host="linux-x86_64-2cpu")] * 3 + \
              [_rec(95.0, host="linux-x86_64-1cpu")]
    ok, msg = check_sps.check(records, KEY, 0.30)
    assert ok and msg.startswith("skip")
    assert "host fingerprint" in msg
    assert "'linux-x86_64-1cpu' != 'linux-x86_64-2cpu'" in msg


def test_intervals_mismatch_skip_names_the_axis():
    records = [_rec(100.0, intervals=48), _rec(95.0, intervals=12)]
    ok, msg = check_sps.check(records, KEY, 0.30)
    assert ok and msg.startswith("skip")
    assert "intervals" in msg and "12 != 48" in msg


def test_gate_anchors_on_newest_record_with_key():
    """BENCH_sps.json interleaves benches (engine sweep, serve bench):
    the gated measurement is the newest record CARRYING the key, not
    records[-1] — a serve record appended after the sweep must not turn
    the engine gate into a silent skip."""
    serve_rec = {"intervals": None, "host": "h1", "bench": "serve",
                 "config": {"load": {"rate": 2000.0}},
                 "sps": {"serve_qps": 2500.0}}
    records = [_rec(100.0), _rec(60.0), serve_rec]
    ok, msg = check_sps.check(records, KEY, 0.30)
    assert not ok and "REGRESSION" in msg          # 60 still gated
    ok, msg = check_sps.check([_rec(100.0), _rec(95.0), serve_rec],
                              KEY, 0.30)
    assert ok and "baseline=100.0" in msg
    # and the serve key gates against serve records only
    ok, msg = check_sps.check(records + [dict(serve_rec,
                                              sps={"serve_qps": 2400.0})],
                              "serve_qps", 0.30)
    assert ok and "baseline=2500.0" in msg


def test_malformed_lines_skip_loudly_with_line_number(tmp_path, capsys):
    """A truncated/hand-edited JSON line is ignored but NAMED (path and
    line number on stderr): a silently-shrinking baseline window is the
    same silently-vacuous-gate failure mode as an unfingerprinted
    baseline."""
    import json
    path = tmp_path / "bench.json"
    path.write_text(json.dumps(_rec(100.0)) + "\n"
                    + '{"truncated mid-wri\n'
                    + json.dumps(_rec(95.0)) + "\n")
    records = check_sps.load_records(str(path))
    assert len(records) == 2          # the good lines both survive
    err = capsys.readouterr().err
    assert f"{path}:2" in err and "not valid JSON" in err


def test_live_bench_file_parses_and_gate_runs():
    """The committed BENCH_sps.json stays loadable end-to-end."""
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_sps.json")
    if not os.path.exists(path):
        pytest.skip("no committed BENCH_sps.json")
    records = check_sps.load_records(path)
    assert records
    ok, _ = check_sps.check(records, KEY, max_regression=1.0)
    assert ok in (True, False)
