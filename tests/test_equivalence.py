"""Cross-runtime equivalence through the engine registry.

The engine contract (core/engine.py): ``run(n)`` executes n
synchronization intervals AND consumes all produced data, applying
exactly n updates. For the HTS family — threaded host, fused mesh,
sharded data-parallel — the schedulers differ but the math, the seeds,
and the update count are identical, so parameters must agree BIT-EXACTLY.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.core import engine
from repro.core.engine import HTSConfig, RunResult
from repro.envs import catch
from repro.optim import rmsprop


def _setup():
    env1 = catch.make()
    cfg = HTSConfig(alpha=5, n_envs=4, seed=3)
    policy = models.get_policy("mlp", env1)   # the obs-flattening MLP
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    return env1, cfg, policy.apply, params, opt


def _maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_host_mesh_sharded_bitexact():
    """Host (threads), mesh (fused XLA), sharded (shard_map, 1-device
    'data' mesh): bit-identical params and trajectories after 4
    intervals. (Since PR 9 multi-device meshes are bit-exact too — the
    canonical tree-sum gradient, see the 2-device subprocess test.)"""
    from jax.sharding import Mesh
    env1, cfg, papply, params, opt = _setup()
    mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    outs = {
        name: engine.make_runtime(name, env1, papply, params, opt, cfg,
                                  **({"mesh": mesh1} if name == "sharded"
                                     else {})).run(4)
        for name in ("host", "mesh", "sharded")
    }
    for name in ("mesh", "sharded"):
        assert _maxdiff(outs["host"].params, outs[name].params) == 0.0, name
        np.testing.assert_array_equal(outs["host"].rewards,
                                      outs[name].rewards, err_msg=name)
        np.testing.assert_array_equal(outs["host"].dones,
                                      outs[name].dones, err_msg=name)


@pytest.mark.parametrize("name", engine.training_runtime_names())
def test_registry_executes_every_runtime(name):
    """Every registered training runtime constructs from the same factory
    signature and satisfies the Runtime protocol + RunResult contract.
    (The "serve" entry shares the factory contract but answers requests
    instead of running intervals — covered by tests/test_serve.py.)"""
    env1, cfg, papply, params, opt = _setup()
    rt = engine.make_runtime(name, env1, papply, params, opt, cfg)
    assert isinstance(rt, engine.Runtime)
    out = rt.run(2)
    assert isinstance(out, RunResult)
    assert out.rewards.shape == (2, cfg.alpha, cfg.n_envs)
    assert out.steps == 2 * cfg.alpha * cfg.n_envs
    assert out.sps > 0
    # mapping-style access was removed after its PR-5 deprecation; the
    # TypeError still names the attribute to reach for
    with pytest.raises(TypeError, match="RunResult.params"):
        out["params"]
    with pytest.raises(TypeError, match="RunResult.state"):
        out["dg"]


def test_rerun_determinism_through_registry():
    env1, cfg, papply, params, opt = _setup()
    a = engine.make_runtime("sharded", env1, papply, params, opt, cfg).run(3)
    b = engine.make_runtime("sharded", env1, papply, params, opt, cfg).run(3)
    assert _maxdiff(a.params, b.params) == 0.0


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    assert len(jax.devices()) == 2, jax.devices()
    from repro import models
    from repro.core import engine
    from repro.core.engine import HTSConfig
    from repro.envs import catch
    from repro.optim import rmsprop
    env1 = catch.make()
    cfg = HTSConfig(alpha=5, n_envs=4, seed=3)
    policy = models.get_policy("mlp", env1)
    papply = policy.apply
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    m = engine.make_runtime("mesh", env1, papply, params, opt, cfg).run(4)
    s = engine.make_runtime("sharded", env1, papply, params, opt, cfg).run(4)
    md = max(float(jnp.max(jnp.abs(x - y))) for x, y in
             zip(jax.tree.leaves(m.params), jax.tree.leaves(s.params)))
    assert np.array_equal(m.rewards, s.rewards)   # trajectories bit-exact
    assert md == 0.0, md       # params too: canonical tree-sum gradient
    print("OK", md)
""")


def test_sharded_two_devices_matches_mesh():
    """Real data parallelism (2 forced host devices, subprocess because
    the device count locks at first jax init): trajectories AND params
    bit-exact — the determinism contract crosses shards via env-id
    offsets, and the canonical tree-sum gradient (repro.core.batch)
    makes the cross-replica reduction order identical to the
    single-device one (DESIGN.md §12)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.startswith("OK")
