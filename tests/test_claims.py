"""Paper Claims 1 & 2: analytic models vs discrete-event simulation."""
import numpy as np
import pytest

from repro.core import runtime_model, stale_sim


def test_claim1_analytic_matches_simulation():
    """Fig. 3(a,b): Eq. (7) tracks the simulated makespan within ~5%."""
    K = 64000
    for n, alpha, beta in [(16, 4, 2.0), (16, 16, 2.0), (8, 4, 1.0),
                           (16, 4, 0.5)]:
        pred = runtime_model.expected_runtime(K, n, alpha, beta)
        sims = [runtime_model.simulate_runtime(K, n, alpha, beta, seed=s)
                for s in range(3)]
        sim = float(np.mean(sims))
        assert abs(pred - sim) / sim < 0.08, (n, alpha, beta, pred, sim)


def test_claim1_monotonicity():
    """Runtime decreases with alpha, increases with variance (1/beta^2)."""
    K = 32000
    ts = [runtime_model.expected_runtime(K, 16, a, 2.0)
          for a in (1, 4, 16, 64)]
    assert all(x > y for x, y in zip(ts, ts[1:]))
    # per-step variance at fixed mean 1: Gamma(k, rate=k), var = 1/k
    tv = [runtime_model.expected_runtime(K, 16, 4, beta=k, step_shape=k)
          for k in (16.0, 4.0, 1.0, 0.25)]   # increasing variance
    assert all(x < y for x, y in zip(tv, tv[1:]))


def test_claim2_mm1_latency():
    """E[L] = n rho / (1 - n rho) matches the event-driven queue sim."""
    lam0, mu = 100.0, 4000.0
    for n in (4, 8, 16, 32):
        pred = stale_sim.expected_latency(n, lam0, mu)
        sim = stale_sim.simulate_latency(n, lam0, mu, horizon=3000.0)
        assert abs(pred - sim) < max(0.3, 0.25 * pred), (n, pred, sim)


def test_claim2_hts_latency_constant():
    for n in (1, 4, 16, 64):
        assert stale_sim.hts_latency(n) == 1


def test_gamma_fit():
    rng = np.random.default_rng(0)
    samples = rng.gamma(4.0, 0.5, size=2000)
    assert runtime_model.gamma_fit_pvalue(samples) > 0.05
