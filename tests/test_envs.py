"""Environment contract tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import catch, football, gridmaze, token_env
from repro.envs.interfaces import vectorize

ENVS = {
    "catch": catch.make,
    "gridmaze": gridmaze.make,
    "football": football.make,
    "token": token_env.make,
}


@pytest.mark.parametrize("name", list(ENVS))
def test_env_contract(name):
    env = ENVS[name]()
    key = jax.random.key(0)
    state, obs = env.reset(key)
    assert obs.shape == env.obs_shape
    total_done = 0
    for t in range(150):
        a = jnp.int32(t % env.n_actions)
        state, obs, r, d = env.step(state, a, jax.random.fold_in(key, t))
        assert obs.shape == env.obs_shape
        assert jnp.isfinite(r)
        total_done += int(d)
    assert total_done >= 1, "episode should terminate within 150 steps"


@pytest.mark.parametrize("name", list(ENVS))
def test_env_determinism(name):
    env = ENVS[name]()
    key = jax.random.key(1)

    def run():
        state, obs = env.reset(key)
        out = []
        for t in range(40):
            a = jnp.int32((t * 7) % env.n_actions)
            state, obs, r, d = env.step(state, a,
                                        jax.random.fold_in(key, t))
            out.append((float(r), float(d)))
        return out

    assert run() == run()


def test_vectorize():
    env = vectorize(catch.make(), 3)
    keys = jax.random.split(jax.random.key(0), 3)
    state, obs = env.reset(keys)
    assert obs.shape == (3,) + catch.make().obs_shape
    a = jnp.zeros(3, jnp.int32)
    state, obs, r, d = env.step(state, a, keys)
    assert r.shape == (3,)


def test_autoreset():
    env = catch.make()
    key = jax.random.key(2)
    state, obs = env.reset(key)
    for t in range(9):   # catch terminates after ROWS-1 = 9 steps
        state, obs, r, d = env.step(state, jnp.int32(1),
                                    jax.random.fold_in(key, t))
    assert d == 1.0
    # obs must already be a fresh episode (ball back at row 0)
    assert float(obs[0].sum()) > 0     # ball visible in top row


def test_multiplayer_football_contract():
    env = football.make_multi(2)
    assert env.n_actions == 81
    key = jax.random.key(0)
    state, obs = env.reset(key)
    assert obs.shape == env.obs_shape
    done_seen = False
    for t in range(120):
        a = jnp.int32((t * 13) % env.n_actions)
        state, obs, r, d = env.step(state, a, jax.random.fold_in(key, t))
        assert jnp.isfinite(r) and obs.shape == env.obs_shape
        done_seen = done_seen or bool(d)
    assert done_seen


# --------------------------------------------- gridmaze scenario sampler
def test_gridmaze_scenario_sampler_deterministic_and_solvable():
    """sample_scenario(seed) is a pure function: same seed, same board
    and goal, bit-for-bit; every sampled board keeps the start free and
    the goal reachable (BFS) and distinct from the start."""
    for seed in (0, 1, 7, 12345):
        w1, g1 = gridmaze.sample_scenario(seed)
        w2, g2 = gridmaze.sample_scenario(seed)
        assert (w1 == w2).all() and g1 == g2
        assert w1[0, 0] == 0 and w1[g1] == 0
        assert g1 != (0, 0)
        dist = gridmaze._bfs_dist(w1)
        assert dist[g1] > 0                    # reachable, not the start
    boards = [gridmaze.sample_scenario(s)[0] for s in range(6)]
    assert any(not (boards[0] == b).all() for b in boards[1:])


def test_gridmaze_seeded_env_differs_from_default():
    """scenario_seed=None is the hand-authored board (goldens depend on
    it); a seeded env plays a different maze and records its
    construction kwargs for backend re-resolution."""
    import jax
    default = gridmaze.make()
    seeded = gridmaze.make(scenario_seed=3)
    assert default.make_kwargs is None
    assert seeded.make_kwargs == {"scenario_seed": 3}
    _, obs_d = default.reset(jax.random.key(0))
    _, obs_s = seeded.reset(jax.random.key(0))
    assert not (np.asarray(obs_d) == np.asarray(obs_s)).all()
