"""Environment contract tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import catch, football, gridmaze, token_env
from repro.envs.interfaces import vectorize

ENVS = {
    "catch": catch.make,
    "gridmaze": gridmaze.make,
    "football": football.make,
    "token": token_env.make,
}


@pytest.mark.parametrize("name", list(ENVS))
def test_env_contract(name):
    env = ENVS[name]()
    key = jax.random.key(0)
    state, obs = env.reset(key)
    assert obs.shape == env.obs_shape
    total_done = 0
    for t in range(150):
        a = jnp.int32(t % env.n_actions)
        state, obs, r, d = env.step(state, a, jax.random.fold_in(key, t))
        assert obs.shape == env.obs_shape
        assert jnp.isfinite(r)
        total_done += int(d)
    assert total_done >= 1, "episode should terminate within 150 steps"


@pytest.mark.parametrize("name", list(ENVS))
def test_env_determinism(name):
    env = ENVS[name]()
    key = jax.random.key(1)

    def run():
        state, obs = env.reset(key)
        out = []
        for t in range(40):
            a = jnp.int32((t * 7) % env.n_actions)
            state, obs, r, d = env.step(state, a,
                                        jax.random.fold_in(key, t))
            out.append((float(r), float(d)))
        return out

    assert run() == run()


def test_vectorize():
    env = vectorize(catch.make(), 3)
    keys = jax.random.split(jax.random.key(0), 3)
    state, obs = env.reset(keys)
    assert obs.shape == (3,) + catch.make().obs_shape
    a = jnp.zeros(3, jnp.int32)
    state, obs, r, d = env.step(state, a, keys)
    assert r.shape == (3,)


def test_autoreset():
    env = catch.make()
    key = jax.random.key(2)
    state, obs = env.reset(key)
    for t in range(9):   # catch terminates after ROWS-1 = 9 steps
        state, obs, r, d = env.step(state, jnp.int32(1),
                                    jax.random.fold_in(key, t))
    assert d == 1.0
    # obs must already be a fresh episode (ball back at row 0)
    assert float(obs[0].sum()) > 0     # ball visible in top row


def test_multiplayer_football_contract():
    env = football.make_multi(2)
    assert env.n_actions == 81
    key = jax.random.key(0)
    state, obs = env.reset(key)
    assert obs.shape == env.obs_shape
    done_seen = False
    for t in range(120):
        a = jnp.int32((t * 13) % env.n_actions)
        state, obs, r, d = env.step(state, a, jax.random.fold_in(key, t))
        assert jnp.isfinite(r) and obs.shape == env.obs_shape
        done_seen = done_seen or bool(d)
    assert done_seen
