"""Device-resident env ports (repro.envs.device): registry wiring, the
host-oracle bit-exactness contract, and the env_backend selection axis.

The contract (DESIGN.md §2.2): for every env with a device port, the
natively-batched ``reset``/``step`` produce bit-identical obs, rewards,
dones, AND state pytrees to ``vectorize(host_env, n)`` under the same
PRNG keys — the host path stays the oracle, the device path is pure
speed. Training on either backend is therefore the same trajectory,
which the runtime-level cells below pin for (a2c|ppo) x K in {1,2} on
both ported envs (the acceptance matrix), plus a cross-backend
checkpoint resume.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api, models
from repro.core import engine
from repro.core.engine import HTSConfig
from repro.envs import get_env
from repro.envs import device as device_envs
from repro.envs.device import DeviceEnv, batched_env
from repro.envs.interfaces import vectorize
from repro.optim import rmsprop

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # container skips; CI installs hypothesis
    HAVE_HYPOTHESIS = False

PORTED = ["catch", "gridmaze"]


# ------------------------------------------------------------- registry
def test_ported_envs_are_registered():
    assert sorted(device_envs.device_port_names()) == PORTED
    for name in PORTED:
        assert device_envs.has_device_port(name)
    assert not device_envs.has_device_port("football")
    assert not device_envs.has_device_port("token_stream")


def test_get_device_env_unported_raises():
    with pytest.raises(ValueError, match="no device-resident port"):
        device_envs.get_device_env("football")


def test_get_env_exposes_device_ports():
    for name in PORTED:
        port = get_env(f"{name}_device")
        assert isinstance(port, DeviceEnv)
        assert port.host_name == name
        host = get_env(name)
        assert port.obs_shape == host.obs_shape
        assert port.n_actions == host.n_actions


def test_batched_env_backend_selection():
    env = get_env("catch")
    host = batched_env(env, 4, "host")
    dev = batched_env(env, 4, "device")
    assert isinstance(dev, DeviceEnv)
    assert not isinstance(host, DeviceEnv)
    with pytest.raises(ValueError, match="unknown env_backend"):
        batched_env(env, 4, "tpu")


def test_device_reset_leaves_are_distinct_buffers():
    """The engine donates carries; XLA refuses one buffer donated under
    two leaves, so constant-valued state fields (gridmaze's r/c/t zeros)
    must not share the eager constant cache."""
    for name in PORTED:
        venv = batched_env(get_env(name), 6, "device")
        keys = jax.random.split(jax.random.key(3), 6)
        state, obs = venv.reset(keys)
        ptrs = [leaf.unsafe_buffer_pointer()
                for leaf in jax.tree.leaves((state, obs))]
        assert len(ptrs) == len(set(ptrs)), name


# ------------------------------------------------- env-level bit-exactness
def _compare_rollout(name, n_envs, seed, steps=40):
    """Step the vmapped host env and the device port in lockstep under
    identical keys; everything must agree bit-exactly, crossing
    autoreset boundaries."""
    env = get_env(name)
    hv = vectorize(env, n_envs)
    dv = batched_env(env, n_envs, "device")
    master = jax.random.key(seed)
    keys0 = jax.random.split(jax.random.fold_in(master, 0), n_envs)
    hs, ho = hv.reset(keys0)
    ds, do = dv.reset(keys0)
    np.testing.assert_array_equal(np.asarray(ho), np.asarray(do))
    for t in range(steps):
        k = jax.random.fold_in(master, t + 1)
        actions = jax.random.randint(k, (n_envs,), 0, env.n_actions)
        keys = jax.random.split(k, n_envs)
        hs, ho, hr, hd = hv.step(hs, actions, keys)
        ds, do, dr, dd = dv.step(ds, actions, keys)
        np.testing.assert_array_equal(np.asarray(ho), np.asarray(do))
        np.testing.assert_array_equal(np.asarray(hr), np.asarray(dr))
        np.testing.assert_array_equal(np.asarray(hd), np.asarray(dd))
        for hx, dx in zip(jax.tree.leaves(hs), jax.tree.leaves(ds)):
            np.testing.assert_array_equal(np.asarray(hx), np.asarray(dx))


@pytest.mark.parametrize("name", PORTED)
def test_device_port_matches_host_oracle(name):
    _compare_rollout(name, n_envs=5, seed=0)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=8)
    @given(name=st.sampled_from(PORTED),
           n_envs=st.integers(min_value=1, max_value=9),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_fuzz_device_port_matches_host_oracle(name, n_envs, seed):
        """Property form of the oracle contract: any seed, any batch
        width — the device port never drifts from the host env."""
        _compare_rollout(name, n_envs=n_envs, seed=seed, steps=25)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fuzz_device_port_matches_host_oracle():
        pass


# --------------------------------------------- runtime-level bit-exactness
def _run(env_name, backend, algorithm="a2c", staleness=1, runtime="mesh",
         alpha=4, n_envs=4, intervals=4):
    env = get_env(env_name)
    cfg = HTSConfig(alpha=alpha, n_envs=n_envs, seed=3,
                    algorithm=algorithm, staleness=staleness,
                    env_backend=backend)
    policy = models.get_policy("mlp", env)
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    rt = engine.make_runtime(runtime, env, policy.apply, params, opt, cfg)
    return rt.run(intervals)


def _assert_same(a, b):
    md = max(float(jnp.max(jnp.abs(x - y)))
             for x, y in zip(jax.tree.leaves(a.params),
                             jax.tree.leaves(b.params)))
    assert md == 0.0
    np.testing.assert_array_equal(np.asarray(a.rewards),
                                  np.asarray(b.rewards))
    np.testing.assert_array_equal(np.asarray(a.dones),
                                  np.asarray(b.dones))


@pytest.mark.parametrize("staleness", [1, 2], ids=lambda k: f"K{k}")
@pytest.mark.parametrize("algorithm", ["a2c", "ppo"])
@pytest.mark.parametrize("env_name", PORTED)
def test_mesh_backends_bit_exact(env_name, algorithm, staleness):
    """The acceptance matrix: host and device trajectories identical for
    (a2c|ppo) x K in {1,2} on both ported envs under the fused runtime."""
    _assert_same(_run(env_name, "host", algorithm, staleness),
                 _run(env_name, "device", algorithm, staleness))


def test_host_runtime_backends_bit_exact():
    """The threaded host runtime accepts the device port as a drop-in
    for its batched reset/step programs — same dispatch cadence, same
    trajectory."""
    _assert_same(_run("catch", "host", runtime="host"),
                 _run("catch", "device", runtime="host"))


def test_capsule_resumes_across_backends(tmp_path):
    """TrainState is backend-agnostic: a host-backend checkpoint resumed
    under the device backend (and vice versa) continues the exact
    straight-run trajectory — the stacked state pytrees are the same
    structure either way."""
    from repro.checkpoint import io as ckpt_io
    env = get_env("catch")
    policy = models.get_policy("mlp", env)
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4, eps=1e-5)
    cfg = HTSConfig(alpha=4, n_envs=4, seed=3)
    mk = lambda be: engine.make_runtime(
        "mesh", env, policy.apply, params, opt,
        cfg._replace(env_backend=be))
    straight = mk("host").run(4)
    for src, dst in [("host", "device"), ("device", "host")]:
        a = mk(src)
        a.run(2)
        path = str(tmp_path / f"xfer_{src}")
        ckpt_io.save(path, a.state())
        b = mk(dst)
        out = b.run_from(ckpt_io.restore(path, b.state()), 2)
        md = max(float(jnp.max(jnp.abs(x - y)))
                 for x, y in zip(jax.tree.leaves(straight.params),
                                 jax.tree.leaves(out.params)))
        assert md == 0.0, (src, dst)


# --------------------------------------------------------- spec surface
def test_spec_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown env_backend"):
        api.ExperimentSpec(hts={"env_backend": "tpu"})


def test_spec_rejects_device_backend_without_port():
    """Spec construction time, not trace time: the error names the envs
    that DO have ports."""
    with pytest.raises(ValueError) as e:
        api.ExperimentSpec(env="football",
                           hts={"env_backend": "device"})
    assert "no device-resident port" in str(e.value)
    for name in PORTED:
        assert name in str(e.value)


def test_build_rejects_device_port_as_workload():
    """Naming "catch_device" as the spec env is a category error — the
    message points at the hts knob instead of a shape failure later."""
    with pytest.raises(ValueError, match="env_backend"):
        api.build(api.ExperimentSpec(env="catch_device"))


def test_spec_device_backend_builds_and_runs():
    spec = api.ExperimentSpec(
        env="gridmaze", runtime="mesh",
        hts={"alpha": 4, "n_envs": 4, "seed": 0,
             "env_backend": "device"},
        intervals=2)
    out = api.build(spec).run()
    assert out.steps == 2 * 4 * 4
    # the knob round-trips through canonical JSON like any other
    assert api.loads(api.dumps(spec)) == spec


def test_host_default_fingerprint_unchanged():
    """Leaving env_backend unset must serialize identically to the
    pre-backend-axis spec form — committed BENCH_sps.json baselines stay
    comparable."""
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.engine_sps import bench_spec, config_fingerprint
    fp = api.workload_fingerprint(bench_spec())
    assert "env_backend" not in fp["hts"]
    assert "env_backend" not in config_fingerprint()["hts"]


# ------------------------------------------- seeded-scenario equivalence
@pytest.mark.parametrize("scenario_seed", [3, 7])
def test_seeded_gridmaze_device_port_matches_host(scenario_seed):
    """Satellite of the tenancy PR: procedurally-sampled gridmaze
    layouts honor the same oracle contract as the default board — the
    device port steps the SAME sampled world bit-exactly, because both
    factories share one ``resolve_board`` and ``batched_env`` forwards
    the host env's ``make_kwargs``."""
    env = get_env("gridmaze", scenario_seed=scenario_seed)
    assert env.make_kwargs == {"scenario_seed": scenario_seed}
    hv = vectorize(env, 4)
    dv = batched_env(env, 4, "device")
    master = jax.random.key(11)
    keys0 = jax.random.split(jax.random.fold_in(master, 0), 4)
    hs, ho = hv.reset(keys0)
    ds, do = dv.reset(keys0)
    np.testing.assert_array_equal(np.asarray(ho), np.asarray(do))
    for t in range(30):
        k = jax.random.fold_in(master, t + 1)
        actions = jax.random.randint(k, (4,), 0, env.n_actions)
        keys = jax.random.split(k, 4)
        hs, ho, hr, hd = hv.step(hs, actions, keys)
        ds, do, dr, dd = dv.step(ds, actions, keys)
        np.testing.assert_array_equal(np.asarray(ho), np.asarray(do))
        np.testing.assert_array_equal(np.asarray(hr), np.asarray(dr))
        np.testing.assert_array_equal(np.asarray(hd), np.asarray(dd))


def test_seeded_gridmaze_spec_trains_same_on_both_backends():
    """End-to-end: one seeded-maze spec, host vs device env_backend,
    identical trajectories and params (the runtime-level cell of the
    scenario_seed axis)."""
    env = get_env("gridmaze", scenario_seed=7)
    outs = []
    for backend in ("host", "device"):
        cfg = HTSConfig(alpha=4, n_envs=4, seed=3, algorithm="ppo",
                        env_backend=backend)
        policy = models.get_policy("mlp", env)
        params = policy.init(jax.random.key(0))
        rt = engine.make_runtime("mesh", env, policy.apply, params,
                                 rmsprop(7e-4, eps=1e-5), cfg)
        outs.append(rt.run(3))
    _assert_same(*outs)
