"""HTS-RL(A2C) vs synchronous A2C vs IMPALA-style async on a pixel env —
the paper's Tab. 1 / Fig. 5 comparison, end-to-end, with every contender
selected from the runtime registry (one code path, swap the name).

Uses the paper's conv policy trunk on GridMaze (the deterministic
pixel-observation Atari stand-in; see DESIGN.md §8 for why not ALE).
Reports final-metric rewards at equal environment steps AND virtual-time
throughput under a high-variance step-time model (Claim 1's regime).

    PYTHONPATH=src python examples/atari_a2c.py --intervals 120
"""
import argparse

import numpy as np
import jax

from repro.configs.paper_cnn import CNNPolicyConfig
from repro.core import engine
from repro.core.baselines import AsyncConfig
from repro.core.engine import HTSConfig
from repro.core.runtime_model import expected_runtime
from repro.envs import gridmaze
from repro.models.cnn_policy import apply_cnn, init_cnn
from repro.optim import rmsprop

RUNTIMES = (
    ("mesh", "HTS-RL(A2C)", {}),
    ("sync", "sync A2C", {}),
    ("async", "async+vtrace (k=8)",
     {"acfg": AsyncConfig(staleness=8, correction="vtrace")}),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--intervals", type=int, default=120)
    ap.add_argument("--n-envs", type=int, default=8)
    ap.add_argument("--alpha", type=int, default=5)
    args = ap.parse_args()

    env1 = gridmaze.make()
    cfg = HTSConfig(alpha=args.alpha, n_envs=args.n_envs, seed=0,
                    entropy_coef=0.01)
    ccfg = CNNPolicyConfig(obs_shape=env1.obs_shape, conv_sizes=(3, 3, 3),
                           conv_strides=(1, 1, 1), hidden=128)

    def policy(params, obs):
        return apply_cnn(params, obs, ccfg)

    params = init_cnn(jax.random.key(0), ccfg, env1.n_actions,
                      env1.obs_shape)
    opt = rmsprop(7e-4, eps=1e-5)

    def tail(rewards):
        r = np.asarray(rewards)
        return float(r[-max(1, len(r) // 5):].mean())

    # (throughput comparisons live in benchmarks/engine_sps.py, which
    # warms the compile caches first; a single cold run's SPS would
    # mostly measure XLA compilation)
    print("final-metric reward/step (last 20%):")
    for name, label, kw in RUNTIMES:
        out = engine.make_runtime(name, env1, policy, params, opt, cfg,
                                  **kw).run(args.intervals)
        print(f"  {label + ':':<22}{tail(out.rewards):+.4f}")

    # virtual-time: same steps, modeled wall-clock (Claim 1 regime:
    # exponential step times, mean 1)
    K = args.intervals * cfg.alpha * cfg.n_envs
    t_hts = expected_runtime(K, cfg.n_envs, cfg.alpha, beta=1.0)
    t_sync = expected_runtime(K, cfg.n_envs, 1, beta=1.0) + \
        args.intervals * cfg.alpha * 0.05   # alternating learner time
    print(f"modeled wall-clock for {K} steps (exp step times): "
          f"HTS-RL {t_hts:.0f}s vs sync-A2C {t_sync:.0f}s "
          f"({t_sync / t_hts:.2f}x speedup)")


if __name__ == "__main__":
    main()
