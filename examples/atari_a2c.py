"""HTS-RL(A2C) vs synchronous A2C vs IMPALA-style async on a pixel env —
the paper's Tab. 1 / Fig. 5 comparison, end-to-end, with every contender
one declarative spec (repro.api): same env/policy/optimizer axes, only
the ``runtime`` axis (and its kwargs) swapped.

Uses the paper's conv policy trunk on GridMaze (the deterministic
pixel-observation Atari stand-in; see DESIGN.md §8 for why not ALE).
Reports final-metric rewards at equal environment steps AND virtual-time
throughput under a high-variance step-time model (Claim 1's regime).

    PYTHONPATH=src python examples/atari_a2c.py --intervals 120
"""
import argparse

import numpy as np

from repro import api
from repro.core.runtime_model import expected_runtime

RUNTIMES = (
    ("mesh", "HTS-RL(A2C)", {}),
    ("sync", "sync A2C", {}),
    ("async", "async+vtrace (k=8)",
     {"acfg": {"staleness": 8, "correction": "vtrace"}}),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--intervals", type=int, default=120)
    ap.add_argument("--n-envs", type=int, default=8)
    ap.add_argument("--alpha", type=int, default=5)
    args = ap.parse_args()

    def spec(runtime, kwargs):
        return api.ExperimentSpec(
            env="gridmaze",
            policy={"name": "cnn",
                    "kwargs": {"conv_sizes": [3, 3, 3],
                               "conv_strides": [1, 1, 1], "hidden": 128}},
            optimizer={"name": "rmsprop",
                       "kwargs": {"lr": 7e-4, "eps": 1e-5}},
            algorithm="a2c",
            runtime={"name": runtime, "kwargs": kwargs},
            hts={"alpha": args.alpha, "n_envs": args.n_envs, "seed": 0,
                 "entropy_coef": 0.01},
            intervals=args.intervals)

    def tail(rewards):
        r = np.asarray(rewards)
        return float(r[-max(1, len(r) // 5):].mean())

    # (throughput comparisons live in benchmarks/engine_sps.py, which
    # warms the compile caches first; a single cold run's SPS would
    # mostly measure XLA compilation)
    print("final-metric reward/step (last 20%):")
    for name, label, kw in RUNTIMES:
        out = api.build(spec(name, kw)).run()
        print(f"  {label + ':':<22}{tail(out.rewards):+.4f}")

    # virtual-time: same steps, modeled wall-clock (Claim 1 regime:
    # exponential step times, mean 1)
    K = args.intervals * args.alpha * args.n_envs
    t_hts = expected_runtime(K, args.n_envs, args.alpha, beta=1.0)
    t_sync = expected_runtime(K, args.n_envs, 1, beta=1.0) + \
        args.intervals * args.alpha * 0.05   # alternating learner time
    print(f"modeled wall-clock for {K} steps (exp step times): "
          f"HTS-RL {t_hts:.0f}s vs sync-A2C {t_sync:.0f}s "
          f"({t_sync / t_hts:.2f}x speedup)")


if __name__ == "__main__":
    main()
