"""End-to-end driver: HTS-RL training of a transformer policy.

The assigned-architecture backbones as RL policies on the token
environment: rollouts are collected with the behavior snapshot
(theta_{j-1}-delayed), the learner applies the one-step delayed gradient
— the complete HTS-RL loop at language-model shape. Defaults to a ~4M
parameter starcoder2-family config so a few hundred intervals finish on
CPU; pass --arch/--layers/--d-model to scale (the same code pjit's onto
the production mesh via launch/train.py).

    PYTHONPATH=src python examples/llm_policy_hts.py --intervals 200
"""
import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import delayed_grad, learner
from repro.data.pipeline import TokenStream
from repro.models import backbone
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--intervals", type=int, default=200)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--vocab", type=int, default=256)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(),
        n_layers=args.layers, d_model=args.d_model,
        vocab_size=args.vocab, d_ff=4 * args.d_model)
    n_params = sum(int(np.prod(s.shape)) for s in
                   jax.tree.leaves(backbone.abstract_params(cfg)))
    print(f"policy: {args.arch} reduced -> {n_params / 1e6:.1f}M params")

    params = backbone.init_params(cfg, jax.random.key(0))
    opt = adam(3e-4)
    dg = delayed_grad.init(params, opt)
    step = jax.jit(learner.make_train_step(cfg, opt), donate_argnums=(0,))

    stream = TokenStream(cfg.vocab_size, args.batch, args.seq)
    t0 = time.time()
    correct = []
    for j in range(args.intervals):
        batch = stream.next_batch()
        # behavior policy = dg.params_prev: measure its next-token accuracy
        if j % 20 == 0 or j == args.intervals - 1:
            h, _, _ = backbone.forward(dg.params_prev, cfg,
                                       batch["tokens"])
            logits, _ = backbone.logits_and_value(dg.params_prev, cfg, h)
            acc = float((jnp.argmax(logits, -1) ==
                         batch["actions"]).mean())
            correct.append(acc)
            print(f"interval {j:4d} behavior-policy accuracy {acc:.3f} "
                  f"({(time.time() - t0) / (j + 1):.2f}s/interval)",
                  flush=True)
        dg, stats = step(dg, batch)
    print(f"accuracy: {correct[0]:.3f} -> {correct[-1]:.3f} "
          f"(reward = correct continuations under the token MDP)")


if __name__ == "__main__":
    main()
