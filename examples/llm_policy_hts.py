"""End-to-end driver: HTS-RL training of a transformer policy.

The assigned-architecture backbones as RL policies on the token
environment, declared as one spec: env ``token_stream`` x policy
``backbone`` x runtime ``stream`` (the engine-contract LLM learner,
core/stream_runtime.py — rollouts are collected with the behavior
snapshot, theta_{j-1}-delayed, and the learner applies the one-step
delayed gradient: the complete HTS-RL loop at language-model shape).
Defaults to a ~4M parameter starcoder2-family config so a few hundred
intervals finish on CPU; pass --arch/--layers/--d-model to scale (the
same spec pjit's onto the production mesh via ``runtime.kwargs.mesh``,
which is what repro.launch.train sets).

Progress comes through the Session's streaming observer; the
behavior-policy accuracy probe rides on ``state()`` capsules between
``run_from`` segments — the training stream itself is untouched.

    PYTHONPATH=src python examples/llm_policy_hts.py --intervals 200
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import api, envs
from repro.models import backbone


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--intervals", type=int, default=200)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--vocab", type=int, default=256)
    args = ap.parse_args()

    spec = api.ExperimentSpec(
        env={"name": "token_stream",
             "kwargs": {"vocab": args.vocab, "batch": args.batch,
                        "seq": args.seq}},
        policy={"name": "backbone",
                "kwargs": {"arch": args.arch, "reduced": True,
                           "n_layers": args.layers,
                           "d_model": args.d_model,
                           "vocab_size": args.vocab,
                           "d_ff": 4 * args.d_model}},
        optimizer={"name": "adam", "kwargs": {"lr": 3e-4}},
        algorithm="a2c",
        runtime="stream",
        intervals=args.intervals)
    session = api.build(spec)

    cfg = session.policy.config
    n_params = sum(int(np.prod(s.shape)) for s in
                   jax.tree.leaves(backbone.abstract_params(cfg)))
    print(f"policy: {args.arch} reduced -> {n_params / 1e6:.1f}M params")

    def behavior_accuracy(state) -> float:
        """Next-token accuracy of the behavior policy (theta_{j-1}, the
        capsule's params_prev) on the batch the stream serves next."""
        probe = envs.get_env("token_stream", vocab=args.vocab,
                             batch=args.batch, seq=args.seq).skip(
            1 + int(state.interval)).next_batch()
        h, _, _ = backbone.forward(state.algo.params_prev, cfg,
                                   probe["tokens"])
        logits, _ = backbone.logits_and_value(state.algo.params_prev,
                                              cfg, h)
        return float((jnp.argmax(logits, -1) == probe["actions"]).mean())

    t0 = time.time()
    correct = []
    state = session.state()
    done = 0
    while done < args.intervals:
        acc = behavior_accuracy(state)
        correct.append(acc)
        print(f"interval {done:4d} behavior-policy accuracy {acc:.3f} "
              f"({(time.time() - t0) / max(done, 1):.2f}s/interval)",
              flush=True)
        chunk = min(20, args.intervals - done)
        session.run_from(state, chunk)
        state = session.state()
        done += chunk
    correct.append(behavior_accuracy(state))
    print(f"accuracy: {correct[0]:.3f} -> {correct[-1]:.3f} "
          f"(reward = correct continuations under the token MDP)")


if __name__ == "__main__":
    main()
