"""HTS-RL(PPO) on the mini-football academy drill (GFootball stand-in) —
the paper's Tab. 2 setting: PPO + high step-time variance environment,
with the threaded host runtime exercising the real executor/actor/learner
concurrency + double-buffer swap discipline.

    PYTHONPATH=src python examples/football_ppo.py --intervals 40
"""
import argparse

import numpy as np
import jax

from repro.core.host_runtime import HostConfig, HostHTSRL
from repro.core.mesh_runtime import HTSConfig
from repro.envs import football
from repro.envs.steptime import StepTimeModel
from repro.models.cnn_policy import apply_mlp_policy, init_mlp_policy
from repro.optim import rmsprop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--intervals", type=int, default=40)
    ap.add_argument("--n-envs", type=int, default=8)
    ap.add_argument("--n-actors", type=int, default=2)
    ap.add_argument("--alpha", type=int, default=16)
    ap.add_argument("--simulate-step-time", action="store_true",
                    help="inject exponential step delays (scaled down)")
    args = ap.parse_args()

    env1 = football.make()
    cfg = HTSConfig(alpha=args.alpha, n_envs=args.n_envs, seed=0,
                    algorithm="ppo", use_gae=True, ppo_epochs=2)

    params = init_mlp_policy(jax.random.key(0), env1.obs_shape[0],
                             env1.n_actions)
    opt = rmsprop(3e-4, eps=1e-5)
    host = HostConfig(
        n_actors=args.n_actors,
        step_time=StepTimeModel(shape=1.0, rate=1.0)
        if args.simulate_step_time else None,
        time_scale=0.002)
    runner = HostHTSRL(env1, apply_mlp_policy, params, opt, cfg, host)
    out = runner.run(args.intervals)
    r = out["rewards"]
    print(f"steps: {out['steps']}  wall: {out['wall_time']:.1f}s  "
          f"SPS: {out['sps']:.0f}")
    print(f"goal rate: first 25% {r[:len(r)//4].mean():.4f} -> "
          f"last 25% {r[-len(r)//4:].mean():.4f}")


if __name__ == "__main__":
    main()
