"""HTS-RL(PPO) on the mini-football academy drill (GFootball stand-in) —
the paper's Tab. 2 setting: PPO + high step-time variance environment,
with the threaded host runtime exercising the real executor/actor/learner
concurrency + slab-ring swap discipline. The whole experiment is one
declarative spec (repro.api): pass ``--runtime mesh`` (or ``sharded``)
to run the identical experiment on a fused scheduler instead — only the
spec's runtime axis changes. The simulated step-time model rides inside
the spec's runtime kwargs as plain JSON.

    PYTHONPATH=src python examples/football_ppo.py --intervals 40
"""
import argparse

from repro import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runtime", default="host",
                    choices=[n for n in api.runtime_names()
                             if n != "stream"])
    ap.add_argument("--intervals", type=int, default=40)
    ap.add_argument("--n-envs", type=int, default=8)
    ap.add_argument("--n-actors", type=int, default=2)
    ap.add_argument("--alpha", type=int, default=16)
    ap.add_argument("--simulate-step-time", action="store_true",
                    help="inject exponential step delays (scaled down; "
                         "host runtime only)")
    args = ap.parse_args()

    kw = {}
    if args.runtime != "host" and (args.n_actors != 2
                                   or args.simulate_step_time):
        print(f"note: --n-actors/--simulate-step-time only affect the "
              f"host runtime; ignored for '{args.runtime}'")
    if args.runtime == "host":
        host = {"n_actors": args.n_actors, "time_scale": 0.002}
        if args.simulate_step_time:
            host["step_time"] = {"shape": 1.0, "rate": 1.0}
        kw["host"] = host

    spec = api.ExperimentSpec(
        env="football",
        policy="mlp",
        optimizer={"name": "rmsprop", "kwargs": {"lr": 3e-4, "eps": 1e-5}},
        algorithm="ppo",
        runtime={"name": args.runtime, "kwargs": kw},
        hts={"alpha": args.alpha, "n_envs": args.n_envs, "seed": 0,
             "use_gae": True},
        intervals=args.intervals)

    out = api.build(spec).run()
    r = out.rewards
    print(f"[{args.runtime}] steps: {out.steps}  "
          f"wall: {out.wall_time:.1f}s  SPS: {out.sps:.0f} (incl. compile)")
    print(f"goal rate: first 25% {r[:len(r)//4].mean():.4f} -> "
          f"last 25% {r[-len(r)//4:].mean():.4f}")


if __name__ == "__main__":
    main()
