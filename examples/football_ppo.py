"""HTS-RL(PPO) on the mini-football academy drill (GFootball stand-in) —
the paper's Tab. 2 setting: PPO + high step-time variance environment,
with the threaded host runtime exercising the real executor/actor/learner
concurrency + double-buffer swap discipline. The runtime comes from the
registry: pass ``--runtime mesh`` (or ``sharded``) to run the identical
experiment on a fused scheduler instead.

    PYTHONPATH=src python examples/football_ppo.py --intervals 40
"""
import argparse

import jax

from repro.core import engine
from repro.core.engine import HTSConfig
from repro.core.host_runtime import HostConfig
from repro.envs import football
from repro.envs.steptime import StepTimeModel
from repro.models.cnn_policy import apply_mlp_policy, init_mlp_policy
from repro.optim import rmsprop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runtime", default="host",
                    choices=engine.runtime_names())
    ap.add_argument("--intervals", type=int, default=40)
    ap.add_argument("--n-envs", type=int, default=8)
    ap.add_argument("--n-actors", type=int, default=2)
    ap.add_argument("--alpha", type=int, default=16)
    ap.add_argument("--simulate-step-time", action="store_true",
                    help="inject exponential step delays (scaled down; "
                         "host runtime only)")
    args = ap.parse_args()

    env1 = football.make()
    cfg = HTSConfig(alpha=args.alpha, n_envs=args.n_envs, seed=0,
                    algorithm="ppo", use_gae=True)

    params = init_mlp_policy(jax.random.key(0), env1.obs_shape[0],
                             env1.n_actions)
    opt = rmsprop(3e-4, eps=1e-5)
    kw = {}
    if args.runtime != "host" and (args.n_actors != 2
                                   or args.simulate_step_time):
        print(f"note: --n-actors/--simulate-step-time only affect the "
              f"host runtime; ignored for '{args.runtime}'")
    if args.runtime == "host":
        kw["host"] = HostConfig(
            n_actors=args.n_actors,
            step_time=StepTimeModel(shape=1.0, rate=1.0)
            if args.simulate_step_time else None,
            time_scale=0.002)
    runner = engine.make_runtime(args.runtime, env1, apply_mlp_policy,
                                 params, opt, cfg, **kw)
    out = runner.run(args.intervals)
    r = out.rewards
    print(f"[{args.runtime}] steps: {out.steps}  "
          f"wall: {out.wall_time:.1f}s  SPS: {out.sps:.0f} (incl. compile)")
    print(f"goal rate: first 25% {r[:len(r)//4].mean():.4f} -> "
          f"last 25% {r[-len(r)//4:].mean():.4f}")


if __name__ == "__main__":
    main()
