"""Quickstart: HTS-RL in ~40 lines.

Trains the paper's A2C (HTS-RL-scheduled: concurrent rollout+learning,
one-step delayed gradient, deterministic executor seeding) on the Catch
environment through the runtime registry, then verifies the paper's
determinism claim by re-running. Swap ``--runtime`` for any registered
scheduler — same algorithm, same data, different concurrency model.

    PYTHONPATH=src python examples/quickstart.py [--runtime mesh]
"""
import argparse

import numpy as np
import jax

from repro.core import engine
from repro.core.engine import HTSConfig
from repro.envs import catch
from repro.models.cnn_policy import apply_mlp_policy, init_mlp_policy
from repro.optim import rmsprop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runtime", default="mesh",
                    choices=engine.runtime_names())
    ap.add_argument("--intervals", type=int, default=400)
    ap.add_argument("--staleness", type=int, default=1,
                    help="staleness bound K for the HTS-family runtimes "
                         "(slab-ring depth K+1, delay-K gradient; 1 = "
                         "the paper's double buffer)")
    args = ap.parse_args()

    env1 = catch.make()
    cfg = HTSConfig(alpha=8, n_envs=16, seed=0, staleness=args.staleness)

    def policy(params, obs):
        return apply_mlp_policy(params, obs.reshape(obs.shape[0], -1))

    params = init_mlp_policy(jax.random.key(0),
                             int(np.prod(env1.obs_shape)), env1.n_actions)
    opt = rmsprop(7e-4, eps=1e-5)

    out = engine.make_runtime(args.runtime, env1, policy, params, opt,
                              cfg).run(args.intervals)
    r = out.rewards.reshape(args.intervals, -1)
    print(f"[{args.runtime}] {out.steps} steps in {out.wall_time:.1f}s "
          f"({out.sps:.0f} SPS incl. compile)")
    print("mean reward per interval block (catch: max +0.111/step):")
    q = max(1, args.intervals // 4)
    for i in range(0, args.intervals, q):
        print(f"  intervals {i:3d}-{i + q - 1:3d}: {r[i:i + q].mean():+.4f}")

    out2 = engine.make_runtime(args.runtime, env1, policy, params, opt,
                               cfg).run(args.intervals)
    identical = all(
        bool((a == b).all())
        for a, b in zip(jax.tree.leaves(out.params),
                        jax.tree.leaves(out2.params)))
    print(f"full determinism (bit-identical rerun): {identical}")


if __name__ == "__main__":
    main()
