"""Quickstart: HTS-RL in ~40 lines.

Trains the paper's A2C (HTS-RL-scheduled: concurrent rollout+learning,
one-step delayed gradient, deterministic executor seeding) on the Catch
environment, then verifies the paper's determinism claim by re-running.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.core import mesh_runtime
from repro.core.mesh_runtime import HTSConfig
from repro.envs import catch
from repro.envs.interfaces import vectorize
from repro.models.cnn_policy import apply_mlp_policy, init_mlp_policy
from repro.optim import rmsprop


def main():
    env1 = catch.make()
    cfg = HTSConfig(alpha=8, n_envs=16, seed=0)
    venv = vectorize(env1, cfg.n_envs)

    def policy(params, obs):
        return apply_mlp_policy(params, obs.reshape(obs.shape[0], -1))

    params = init_mlp_policy(jax.random.key(0),
                             int(np.prod(env1.obs_shape)), env1.n_actions)
    opt = rmsprop(7e-4, eps=1e-5)

    carry, metrics = mesh_runtime.train(params, policy, venv, opt, cfg,
                                        n_intervals=400)
    r = np.asarray(metrics["rewards"]).reshape(400, -1)
    print("mean reward per interval block (catch: max +0.111/step):")
    for i in range(0, 400, 100):
        print(f"  intervals {i:3d}-{i + 99:3d}: {r[i:i + 100].mean():+.4f}")

    carry2, metrics2 = mesh_runtime.train(params, policy, venv, opt, cfg,
                                          n_intervals=400)
    identical = all(
        bool((a == b).all())
        for a, b in zip(jax.tree.leaves(carry[0].params),
                        jax.tree.leaves(carry2[0].params)))
    print(f"full determinism (bit-identical rerun): {identical}")


if __name__ == "__main__":
    main()
