"""Quickstart: HTS-RL in ~30 lines, through the declarative surface.

One ``ExperimentSpec`` names the whole experiment — env x policy x
optimizer x algorithm x runtime x HTSConfig knobs, each a registry
name — and ``api.build`` resolves it into a running Session. Trains the
paper's A2C (HTS-RL-scheduled: concurrent rollout+learning, one-step
delayed gradient, deterministic executor seeding) on the Catch
environment, then verifies the paper's determinism claim by rebuilding
the SAME spec from its canonical JSON and re-running. Swap ``--runtime``
for any registered scheduler — same spec, same data, different
concurrency model.

    PYTHONPATH=src python examples/quickstart.py [--runtime mesh]

The committed spec file examples/specs/quickstart.json is this exact
experiment; ``python -m repro.launch.run --spec`` runs it without this
script.
"""
import argparse

import jax

from repro import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runtime", default="mesh",
                    choices=[n for n in api.runtime_names()
                             if n != "stream"])
    ap.add_argument("--intervals", type=int, default=400)
    ap.add_argument("--staleness", type=int, default=1,
                    help="staleness bound K for the HTS-family runtimes "
                         "(slab-ring depth K+1, delay-K gradient; 1 = "
                         "the paper's double buffer)")
    args = ap.parse_args()

    spec = api.ExperimentSpec(
        env="catch",
        policy="mlp",
        optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4, "eps": 1e-5}},
        algorithm="a2c",
        runtime=args.runtime,
        hts={"alpha": 8, "n_envs": 16, "seed": 0,
             "staleness": args.staleness},
        intervals=args.intervals)

    out = api.build(spec).run()
    r = out.rewards.reshape(args.intervals, -1)
    print(f"[{args.runtime}] {out.steps} steps in {out.wall_time:.1f}s "
          f"({out.sps:.0f} SPS incl. compile)")
    print("mean reward per interval block (catch: max +0.111/step):")
    q = max(1, args.intervals // 4)
    for i in range(0, args.intervals, q):
        print(f"  intervals {i:3d}-{i + q - 1:3d}: {r[i:i + q].mean():+.4f}")

    # determinism, end to end: the spec's canonical JSON rebuilds the
    # experiment bit-identically
    out2 = api.build(api.loads(api.dumps(spec))).run()
    identical = all(
        bool((a == b).all())
        for a, b in zip(jax.tree.leaves(out.params),
                        jax.tree.leaves(out2.params)))
    print(f"full determinism (bit-identical rerun from the spec JSON): "
          f"{identical}")


if __name__ == "__main__":
    main()
