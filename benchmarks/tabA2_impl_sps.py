"""Tab. A2: implementation throughput — fused mesh runtime vs threaded
host runtime vs sync baseline, real wall-clock (no simulated delays).

All three come from the runtime registry (the full sweep, including the
sharded and async runtimes, is benchmarks/engine_sps.py); the labels keep
the paper-table names."""
from benchmarks import engine_sps

IV = 12

LABELS = {
    "engine_sps_mesh": "tabA2_mesh_runtime",
    "engine_sps_host": "tabA2_host_runtime",
    "engine_sps_sync": "tabA2_sync_fused",
}


def run():
    rows = engine_sps.run(runtimes=("mesh", "host", "sync"), intervals=IV)
    return [(LABELS[name], value, unit) for name, value, unit in rows]
