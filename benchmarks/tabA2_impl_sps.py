"""Tab. A2: implementation throughput — fused mesh runtime vs threaded
host runtime vs sync baseline, real wall-clock (no simulated delays)."""
import time

import numpy as np
import jax

from repro.core import mesh_runtime
from repro.core.baselines import make_sync_step, sync_init_carry
from repro.core.host_runtime import HostConfig, HostHTSRL
from repro.core.mesh_runtime import HTSConfig
from repro.envs import catch
from repro.envs.interfaces import vectorize
from repro.models.cnn_policy import apply_mlp_policy, init_mlp_policy
from repro.optim import rmsprop

IV = 12


def run():
    env1 = catch.make()
    cfg = HTSConfig(alpha=8, n_envs=8, seed=0)
    venv = vectorize(env1, cfg.n_envs)
    params = init_mlp_policy(jax.random.key(0),
                             int(np.prod(env1.obs_shape)), env1.n_actions)
    opt = rmsprop(7e-4)
    policy = lambda p, o: apply_mlp_policy(p, o.reshape(o.shape[0], -1))
    steps = IV * cfg.alpha * cfg.n_envs
    rows = []

    step = mesh_runtime.make_hts_step(policy, venv, opt, cfg)
    carry = mesh_runtime.init_carry(params, opt, venv, cfg, policy)
    jrun_hts = jax.jit(lambda c: jax.lax.scan(step, c, None, length=IV))
    jax.block_until_ready(jrun_hts(carry))       # compile
    t0 = time.perf_counter()
    jax.block_until_ready(jrun_hts(carry))
    rows.append(("tabA2_mesh_runtime", steps / (time.perf_counter() - t0),
                 "sps"))

    out = HostHTSRL(env1, policy, params, opt, cfg,
                    HostConfig(n_actors=2)).run(IV)
    rows.append(("tabA2_host_runtime", out["sps"], "sps"))

    sstep = make_sync_step(policy, venv, opt, cfg)
    sc = sync_init_carry(params, opt, venv, cfg)
    jrun = jax.jit(lambda c: jax.lax.scan(sstep, c, None, length=IV))
    jax.block_until_ready(jrun(sc))
    t0 = time.perf_counter()
    jax.block_until_ready(jrun(sc))
    rows.append(("tabA2_sync_fused", steps / (time.perf_counter() - t0),
                 "sps"))
    return rows
