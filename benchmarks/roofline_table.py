"""§Roofline: aggregate the dry-run artifacts into the per-(arch, shape)
three-term table (reads artifacts/dryrun/*.json; run the dry-run first)."""
import glob
import json


def run():
    rows = []
    for f in sorted(glob.glob("artifacts/dryrun/*__pod.json")):
        d = json.load(open(f))
        if d.get("skipped") or d.get("error"):
            continue
        r = d["roofline"]
        tag = f"{d['arch']}__{d['shape']}"
        rows.append((f"roofline_{tag}_compute", r["compute_s"], "s"))
        rows.append((f"roofline_{tag}_memory", r["memory_s"], "s"))
        rows.append((f"roofline_{tag}_collective", r["collective_s"], "s"))
        rows.append((f"roofline_{tag}_bottleneck",
                     {"compute": 0, "memory": 1, "collective": 2}[
                         r["bottleneck"]], "0=c,1=m,2=coll"))
    if not rows:
        rows.append(("roofline_missing_run_dryrun_first", float("nan"),
                     ""))
    return rows
