"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,tab1]
    PYTHONPATH=src python -m benchmarks.run --runtime host,mesh,sharded
    PYTHONPATH=src python -m benchmarks.run --runtime mesh \
        --append-sps BENCH_sps.json        # CI smoke: append a JSON line

Prints ``name,value,unit`` CSV rows per benchmark. ``--runtime`` runs the
registry SPS sweep (benchmarks/engine_sps.py) for the named engine
runtimes instead of the paper tables.
"""
import argparse
import json
import sys
import time
import traceback

MODULES = [
    "fig3_runtime_model",
    "fig4_speedup",
    "fig4_sps_scaling",
    "fig5_curves",
    "tab1_final_time",
    "tab2_required_time",
    "tab3_multiagent",
    "tab4_actor_ablation",
    "tab5_sync_interval",
    "tabA1_correction",
    "tabA2_impl_sps",       # (engine_sps backs it; full sweep via --runtime)
    "roofline_table",
]


def _run_runtime_sweep(args) -> None:
    from benchmarks import engine_sps
    names = args.runtime.split(",")
    t0 = time.time()
    rows, failed = [], 0
    print("name,value,unit")
    for rt_name in names:          # per-runtime isolation, like the tables
        try:
            sub = engine_sps.run(runtimes=[rt_name],
                                 intervals=args.intervals)
        except Exception:
            failed += 1
            print(f"# runtime {rt_name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
            continue
        rows.extend(sub)
        for name, value, unit in sub:
            print(f"{name},{value:.6g},{unit}", flush=True)
    if args.append_sps:
        record = {
            "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "intervals": args.intervals,
            "wall_s": round(time.time() - t0, 2),
            "sps": {name: round(value, 2) for name, value, _ in rows},
        }
        with open(args.append_sps, "a") as f:
            f.write(json.dumps(record) + "\n")
        print(f"# appended to {args.append_sps}", file=sys.stderr,
              flush=True)
    if failed:
        raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substring filters")
    ap.add_argument("--runtime", default=None,
                    help="comma-separated engine runtime names "
                         "(host,mesh,sharded,sync,async): run the registry "
                         "SPS sweep instead of the paper tables")
    ap.add_argument("--intervals", type=int, default=12,
                    help="intervals per timed run for --runtime")
    ap.add_argument("--append-sps", default=None, metavar="FILE",
                    help="with --runtime: append the sweep as a JSON line "
                         "to FILE (e.g. BENCH_sps.json)")
    args = ap.parse_args()
    if args.runtime and args.only:
        ap.error("--only filters the paper tables; it does not combine "
                 "with --runtime (the registry sweep)")
    if args.append_sps and not args.runtime:
        ap.error("--append-sps requires --runtime")

    if args.runtime:
        _run_runtime_sweep(args)
        return

    filters = args.only.split(",") if args.only else None
    print("name,value,unit")
    failed = 0
    for mod_name in MODULES:
        if filters and not any(f in mod_name for f in filters):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            for name, value, unit in mod.run():
                print(f"{name},{value:.6g},{unit}", flush=True)
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:
            failed += 1
            print(f"# {mod_name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
