"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,tab1]
    PYTHONPATH=src python -m benchmarks.run --runtime host,mesh,sharded
    PYTHONPATH=src python -m benchmarks.run --runtime mesh \
        --append-sps BENCH_sps.json        # CI smoke: append a JSON line
    PYTHONPATH=src python -m benchmarks.run --runtime host,mesh,sharded \
        --ckpt-dir bench_ckpt --resume     # restartable long sweep

Prints ``name,value,unit`` CSV rows per benchmark. ``--runtime`` runs the
registry SPS sweep (benchmarks/engine_sps.py) for the named engine
runtimes instead of the paper tables; ``--env-backend host,device`` adds
the device-resident env axis (rows keyed ``engine_sps_<rt>_device``).
With ``--ckpt-dir`` the sweep records each completed runtime x backend
cell in ``<dir>/sweep_progress.json`` after it finishes; ``--resume``
replays recorded rows instead of re-timing them, so a preempted
multi-hour sweep restarts where it died.
"""
import argparse
import json
import os
import platform
import sys
import time
import traceback


def host_fingerprint() -> str:
    """Coarse hardware identity stamped into --append-sps records.
    benchmarks.check_sps only compares SPS between records with equal
    fingerprints: a CI runner regressing against a dev-machine baseline
    would measure hardware, not code."""
    return f"{sys.platform}-{platform.machine()}-{os.cpu_count()}cpu"

MODULES = [
    "fig3_runtime_model",
    "fig4_speedup",
    "fig4_sps_scaling",
    "fig5_curves",
    "tab1_final_time",
    "tab2_required_time",
    "tab3_multiagent",
    "tab4_actor_ablation",
    "tab5_sync_interval",
    "tabA1_correction",
    "tabA2_impl_sps",       # (engine_sps backs it; full sweep via --runtime)
    "profile_hot_path",     # host runtime per-phase breakdown
    "staleness_sweep",      # throughput-vs-staleness frontier (K sweep)
    "roofline_table",
]


def _progress_path(args) -> str:
    return os.path.join(args.ckpt_dir, "sweep_progress.json")


def _load_progress(args) -> dict:
    if not (args.ckpt_dir and args.resume):
        return {}
    try:
        with open(_progress_path(args)) as f:
            saved = json.load(f)
    except (OSError, ValueError):
        return {}
    # completed runtimes are only reusable if the sweep shape matches
    if (saved.get("intervals") != args.intervals
            or saved.get("staleness", 1) != args.staleness
            or saved.get("n_replicas", "1") != args.n_replicas):
        return {}
    return saved.get("done", {})


def _save_progress(args, done: dict) -> None:
    os.makedirs(args.ckpt_dir, exist_ok=True)
    tmp = _progress_path(args) + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"intervals": args.intervals,
                   "staleness": args.staleness,
                   "n_replicas": args.n_replicas, "done": done}, f,
                  indent=1)
    os.replace(tmp, _progress_path(args))


def _sweep_progress(rt_name: str, m: dict) -> None:
    """Session on_interval observer for the sweep's warmup runs: a
    stderr marker that each runtime's warmup actually produced data
    (live per interval on the host runtime; one post-program burst on
    the fused ones). The timed run carries no observer —
    engine_sps.run."""
    if m["interval"] % 4 == 0:
        print(f"# {rt_name} warmup interval {m['interval']} "
              f"reward/step {float(m['rewards'].mean()):+.3f}",
              file=sys.stderr, flush=True)


def _run_runtime_sweep(args) -> None:
    from benchmarks import engine_sps
    names = args.runtime.split(",")
    replicas = [int(r) for r in args.n_replicas.split(",")]
    t0 = time.time()
    failed = 0
    rows_by_nr = {nr: [] for nr in replicas}
    restored_by_nr = {nr: [] for nr in replicas}
    done = _load_progress(args)
    print("name,value,unit")
    backends = args.env_backend.split(",")
    # one sweep cell per runtime x env_backend x n_replicas, isolated
    # like the tables; cells are named like their sps keys ("mesh",
    # "mesh_device", "sharded_r2") so checkpoints and check_sps's
    # restored-row staleness test agree
    cells = [(rt, be, nr) for rt in names for be in backends
             for nr in replicas]
    for rt_name, backend, nr in cells:
        cell = engine_sps.sweep_key(rt_name, backend,
                                    nr)[len("engine_sps_"):]
        if cell in done:           # resumed: replay the recorded rows
            sub = [tuple(row) for row in done[cell]]
            restored_by_nr[nr].append(cell)
            print(f"# runtime {cell} restored from checkpoint",
                  file=sys.stderr, flush=True)
        else:
            try:
                sub = engine_sps.run(runtimes=[rt_name],
                                     intervals=args.intervals,
                                     staleness=args.staleness,
                                     progress=_sweep_progress,
                                     env_backends=(backend,),
                                     n_replicas=nr)
            except Exception:
                failed += 1
                print(f"# runtime {cell} FAILED:\n"
                      f"{traceback.format_exc()}",
                      file=sys.stderr, flush=True)
                continue
            if args.ckpt_dir:
                done[cell] = sub
                _save_progress(args, done)
        rows_by_nr[nr].extend(sub)
        for name, value, unit in sub:
            print(f"{name},{value:.6g},{unit}", flush=True)
    if args.append_sps:
        # one record PER replica count: the workload fingerprint of a
        # multi-replica sweep includes its batch block, and check_sps
        # only compares records with equal configs — so replica rows
        # can never gate (or be gated by) single-replica baselines
        with open(args.append_sps, "a") as f:
            for nr in replicas:
                rows = rows_by_nr[nr]
                if not rows:
                    continue
                record = {
                    "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                        time.gmtime()),
                    "intervals": args.intervals,
                    "host": host_fingerprint(),
                    "config": engine_sps.config_fingerprint(
                        staleness=args.staleness, n_replicas=nr),
                    "wall_s": round(time.time() - t0, 2),
                    "sps": {name: round(value, 2)
                            for name, value, _ in rows},
                }
                if restored_by_nr[nr]:
                    # replayed rows carry an older measurement's numbers
                    # — flag them so the bench trajectory isn't polluted
                    record["restored_runtimes"] = restored_by_nr[nr]
                f.write(json.dumps(record) + "\n")
        print(f"# appended to {args.append_sps}", file=sys.stderr,
              flush=True)
    if failed:
        raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substring filters")
    ap.add_argument("--runtime", default=None,
                    help="comma-separated engine runtime names "
                         "(host,mesh,sharded,sync,async): run the registry "
                         "SPS sweep instead of the paper tables")
    ap.add_argument("--intervals", type=int, default=12,
                    help="intervals per timed run for --runtime")
    ap.add_argument("--staleness", type=int, default=1,
                    help="HTSConfig.staleness for the --runtime sweep "
                         "(host/mesh/sharded); the sync/async baselines "
                         "refuse staleness != 1 — drop them from "
                         "--runtime when sweeping K")
    ap.add_argument("--env-backend", default="host",
                    help="comma-separated env backends for the --runtime "
                         "sweep (host,device): 'host' rows keep their "
                         "historical engine_sps_<rt> keys, 'device' rows "
                         "are keyed engine_sps_<rt>_device. Only envs "
                         "with device ports (catch, gridmaze) support "
                         "'device'")
    ap.add_argument("--n-replicas", default="1",
                    help="comma-separated replica counts for the "
                         "--runtime sweep (batch.n_replicas axis): "
                         "counts != 1 write rows keyed "
                         "engine_sps_<rt>_r<N> in their OWN --append-sps "
                         "record (the replica count is part of the "
                         "config fingerprint). Geometry-aware runtimes "
                         "only (host,mesh,sharded); sharded needs that "
                         "many visible devices")
    ap.add_argument("--append-sps", default=None, metavar="FILE",
                    help="with --runtime: append the sweep as a JSON line "
                         "to FILE (e.g. BENCH_sps.json)")
    ap.add_argument("--ckpt-dir", default=None, metavar="DIR",
                    help="with --runtime: record per-runtime results in "
                         "DIR/sweep_progress.json as they complete")
    ap.add_argument("--resume", action="store_true",
                    help="with --ckpt-dir: skip runtimes already recorded "
                         "(restartable long sweeps)")
    args = ap.parse_args()
    if args.runtime and args.only:
        ap.error("--only filters the paper tables; it does not combine "
                 "with --runtime (the registry sweep)")
    if args.append_sps and not args.runtime:
        ap.error("--append-sps requires --runtime")
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")
    if args.ckpt_dir and not args.runtime:
        ap.error("--ckpt-dir applies to the --runtime sweep")
    if args.env_backend != "host" and not args.runtime:
        ap.error("--env-backend applies to the --runtime sweep")
    if args.n_replicas != "1" and not args.runtime:
        ap.error("--n-replicas applies to the --runtime sweep")

    if args.runtime:
        _run_runtime_sweep(args)
        return

    filters = args.only.split(",") if args.only else None
    print("name,value,unit")
    failed = 0
    for mod_name in MODULES:
        if filters and not any(f in mod_name for f in filters):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            for name, value, unit in mod.run():
                print(f"{name},{value:.6g},{unit}", flush=True)
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:
            failed += 1
            print(f"# {mod_name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
