"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,tab1]

Prints ``name,value,unit`` CSV rows per benchmark.
"""
import argparse
import sys
import time
import traceback

MODULES = [
    "fig3_runtime_model",
    "fig4_speedup",
    "fig4_sps_scaling",
    "fig5_curves",
    "tab1_final_time",
    "tab2_required_time",
    "tab3_multiagent",
    "tab4_actor_ablation",
    "tab5_sync_interval",
    "tabA1_correction",
    "tabA2_impl_sps",
    "roofline_table",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substring filters")
    args = ap.parse_args()
    filters = args.only.split(",") if args.only else None

    print("name,value,unit")
    failed = 0
    for mod_name in MODULES:
        if filters and not any(f in mod_name for f in filters):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            for name, value, unit in mod.run():
                print(f"{name},{value:.6g},{unit}", flush=True)
            print(f"# {mod_name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:
            failed += 1
            print(f"# {mod_name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
