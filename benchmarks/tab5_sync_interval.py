"""Tab. 5: synchronization-interval ablation — throughput rises with
alpha (Claim 1) while the final score stays consistent."""
import numpy as np
import jax

from benchmarks.common import tail_mean
from repro.core import mesh_runtime
from repro.core.mesh_runtime import HTSConfig
from repro.core.runtime_model import expected_runtime
from repro.envs import token_env
from repro.envs.interfaces import vectorize
from repro.models.cnn_policy import apply_token_policy, init_token_policy
from repro.optim import rmsprop

VOCAB, N_ENVS, TOTAL_STEPS = 32, 8, 64 * 8 * 50


def run():
    env1 = token_env.make(vocab=VOCAB, seed=1)
    venv = vectorize(env1, N_ENVS)
    params = init_token_policy(jax.random.key(0), VOCAB, hidden=64)
    opt = rmsprop(5e-3, eps=1e-5)
    rows = []
    for alpha in (4, 16, 64):
        cfg = HTSConfig(alpha=alpha, n_envs=N_ENVS, seed=0,
                        entropy_coef=0.003)
        iv = TOTAL_STEPS // (alpha * N_ENVS)
        _, m = mesh_runtime.train(params, apply_token_policy, venv, opt,
                                  cfg, iv)
        t = expected_runtime(TOTAL_STEPS, N_ENVS, alpha, beta=1.0)
        rows.append((f"tab5_alpha{alpha}_sps", TOTAL_STEPS / t,
                     "virtual_sps"))
        rows.append((f"tab5_alpha{alpha}_reward",
                     tail_mean(m["rewards"]), "r/step"))
    return rows
