"""Per-phase breakdown of the host runtime's hot path — and the device
path it is racing against.

Where does an interval's wall time actually go? The host runtime
accumulates per-phase timers when ``HostConfig(profile=True)``:

    actor_wait        executors blocked waiting for a sampled action
    env_step_wait     executors blocked waiting for a batched env step
    actor_forward     actor threads inside the policy dispatch + sync
    env_step_dispatch stepper thread inside the env dispatch + sync
    learner_drain     coordinator blocked on the previous learner before
                      a slab is reused (the swap barrier's read side)
    interval_barrier  coordinator waiting for executors to finish the
                      interval (the swap barrier's write side)
    sim_env_sleep     injected StepTimeModel sleep (0 unless simulating)

Phase times are summed across threads, so they don't add up to wall
time (n_envs executors wait concurrently); they rank where the next
optimization should go. ``learner_drain`` near zero means the learner
fully hides behind the rollout — the paper's overlap claim.

The device-backend rows put those host phase costs in perspective:

    hot_path_device_fused_sps/wall   the mesh runtime with
                     env_backend="device" — actor+env+learner in ONE
                     XLA program, zero per-step host dispatch. Its wall
                     time is what the host path's env_step_wait +
                     actor_wait + dispatch overhead is competing with.
    hot_path_device_env_scan         an alpha-step scan of JUST the
                     batched device env (random actions) — the env
                     share of the fused program.
    hot_path_device_actor_scan       an alpha-step scan of JUST the
                     policy forward + sample — the actor share.

    PYTHONPATH=src python -m benchmarks.run --only profile
"""
import time

import jax
import jax.numpy as jnp

from repro import models
from repro.core import engine
from repro.core.host_runtime import HostConfig
from repro.envs import catch
from repro.envs.device import batched_env
from repro.optim import rmsprop

IV = 12


def _timed(fn, *args):
    """Wall-time one jitted program: compile outside the clock, then
    block on the result."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def _device_rows(env1, policy, params, cfg, intervals):
    """The fused device path plus its two attributable halves."""
    venv = batched_env(env1, cfg.n_envs, "device")
    opt = rmsprop(7e-4)
    rt = engine.make_runtime("mesh", env1, policy.apply, params, opt,
                             cfg._replace(env_backend="device"))
    rt.run(intervals)              # warmup: compile + caches
    out = rt.run(intervals)
    rows = [("hot_path_device_fused_sps", out.sps, "sps"),
            ("hot_path_device_fused_wall", out.wall_time, "s")]

    steps = intervals * cfg.alpha
    keys = jax.random.split(jax.random.key(0), cfg.n_envs)
    state, obs = venv.reset(keys)
    acts = jnp.zeros((cfg.n_envs,), jnp.int32)

    @jax.jit
    def env_scan(state):
        def body(s, k):
            ns, o, r, d = venv.step(s, acts, jax.random.split(k, cfg.n_envs))
            return ns, r
        return jax.lax.scan(body, state,
                            jax.random.split(jax.random.key(1), steps))

    @jax.jit
    def actor_scan(obs):
        def body(o, k):
            logits, value = policy.apply(params, o)
            a = jax.random.categorical(k, logits)
            return o, a
        return jax.lax.scan(body, obs,
                            jax.random.split(jax.random.key(2), steps))

    rows.append(("hot_path_device_env_scan", _timed(env_scan, state), "s"))
    rows.append(("hot_path_device_actor_scan", _timed(actor_scan, obs),
                 "s"))
    return rows


def run(intervals=IV, alpha=8, n_envs=8):
    env1 = catch.make()
    cfg = engine.HTSConfig(alpha=alpha, n_envs=n_envs, seed=0)
    policy = models.get_policy("mlp", env1)
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4)
    rt = engine.make_runtime("host", env1, policy.apply, params, opt, cfg,
                             host=HostConfig(profile=True))
    rt.run(intervals)              # warmup: compile + caches
    out = rt.run(intervals)
    rows = [("hot_path_sps", out.sps, "sps"),
            ("hot_path_wall", out.wall_time, "s")]
    for key in sorted(rt.profile):
        rows.append((f"hot_path_{key}", rt.profile[key], "s"))
    rows.extend(_device_rows(env1, policy, params, cfg, intervals))
    return rows
