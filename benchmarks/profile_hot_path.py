"""Per-phase breakdown of the host runtime's hot path.

Where does an interval's wall time actually go? The host runtime
accumulates per-phase timers when ``HostConfig(profile=True)``:

    actor_wait        executors blocked waiting for a sampled action
    env_step_wait     executors blocked waiting for a batched env step
    actor_forward     actor threads inside the policy dispatch + sync
    env_step_dispatch stepper thread inside the env dispatch + sync
    learner_drain     coordinator blocked on the previous learner before
                      a slab is reused (the swap barrier's read side)
    interval_barrier  coordinator waiting for executors to finish the
                      interval (the swap barrier's write side)
    sim_env_sleep     injected StepTimeModel sleep (0 unless simulating)

Phase times are summed across threads, so they don't add up to wall
time (n_envs executors wait concurrently); they rank where the next
optimization should go. ``learner_drain`` near zero means the learner
fully hides behind the rollout — the paper's overlap claim.

    PYTHONPATH=src python -m benchmarks.run --only profile
"""
import jax

from repro import models
from repro.core import engine
from repro.core.host_runtime import HostConfig
from repro.envs import catch
from repro.optim import rmsprop

IV = 12


def run(intervals=IV, alpha=8, n_envs=8):
    env1 = catch.make()
    cfg = engine.HTSConfig(alpha=alpha, n_envs=n_envs, seed=0)
    policy = models.get_policy("mlp", env1)
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4)
    rt = engine.make_runtime("host", env1, policy.apply, params, opt, cfg,
                             host=HostConfig(profile=True))
    rt.run(intervals)              # warmup: compile + caches
    out = rt.run(intervals)
    rows = [("hot_path_sps", out.sps, "sps"),
            ("hot_path_wall", out.wall_time, "s")]
    for key in sorted(rt.profile):
        rows.append((f"hot_path_{key}", rt.profile[key], "s"))
    return rows
