"""Recovery bench: restore latency under a pinned FaultPlan storm, and
serving throughput while the dispatcher is being killed and restarted.

    PYTHONPATH=src python -m benchmarks.recovery_bench \
        --intervals 8 --fault-seed 7 --append-sps BENCH_sps.json

Two legs, one record:

* **training** — a host-runtime catch x mlp fit under a
  ``FaultPlan.generate(fault_seed, ...)`` storm (worker/env/learner
  faults) with supervision on. Records how many restarts the storm
  cost, the restore latency per recovery (the supervisor's
  capsule-restore time, NOT the backoff sleep — backoff is policy,
  restore is the quantity this layer must keep bounded), and whether
  the recovered run's final params + episode-return stream are
  BIT-EXACT to a fault-free twin of the same spec (``recovery_bitexact``
  is 1.0 or 0.0 — the recovery contract, measured, not assumed).
* **serving** — the serve_bench workload with dispatcher kills at
  consecutive dispatch indices and in-place restart enabled
  (``serve.max_restarts``): offered load answered while the dispatcher
  dies mid-storm, with loadgen retry absorbing the shed requests.

``--append-sps`` writes the usual BENCH_sps.json line (bench
"recovery", host + config fingerprints), so benchmarks/check_sps.py
can gate ``recovery_restore_ms_max`` — "restores stay bounded" — the
same way it gates throughput keys.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile
import time

import jax
import numpy as np

from repro import api
from repro.faults import FaultPlan
from repro.serve import loadgen


def train_spec(ckpt_dir: str, intervals: int,
               faults=None) -> api.ExperimentSpec:
    """The engine bench workload (catch x mlp) on the host runtime —
    the one training runtime with live worker-pool fault sites."""
    return api.ExperimentSpec(
        env="catch",
        policy="mlp",
        optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4}},
        algorithm="a2c",
        runtime="host",
        hts={"alpha": 4, "n_envs": 4, "seed": 0},
        intervals=intervals,
        checkpoint={"dir": ckpt_dir, "every": 2},
        faults=faults if faults is not None else {})


def serve_spec(faults=None, max_restarts: int = 4) -> api.ExperimentSpec:
    return api.ExperimentSpec(
        env="catch",
        policy="mlp",
        optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4}},
        algorithm="a2c",
        runtime="serve",
        hts={"alpha": 8, "n_envs": 8, "seed": 0},
        serve={"max_batch": 32, "max_queue": 1024, "timeout_ms": 20.0,
               "max_restarts": max_restarts, "restart_backoff_ms": 1.0},
        faults=faults if faults is not None else {})


def run_training(intervals: int, fault_seed: int):
    """Faulted supervised fit vs fault-free twin; returns the metric
    rows for the training leg plus the plan that was replayed."""
    plan = FaultPlan.generate(fault_seed, intervals, n_events=3)
    base = tempfile.mkdtemp(prefix="recovery_bench_")
    try:
        chaos = api.build(train_spec(f"{base}/chaos", intervals,
                                     faults=plan)).fit()
        clean = api.build(train_spec(f"{base}/clean", intervals)).fit()
    finally:
        shutil.rmtree(base, ignore_errors=True)
    bitexact = float(
        all(np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(chaos.params),
                            jax.tree.leaves(clean.params)))
        and np.array_equal(chaos.episode_returns, clean.episode_returns))
    restore_ms = [1e3 * r["restore_s"] for r in chaos.recoveries]
    rows = [
        ("recovery_restarts", float(chaos.restarts), "count"),
        ("recovery_restore_ms_mean",
         float(np.mean(restore_ms)) if restore_ms else 0.0, "ms"),
        ("recovery_restore_ms_max",
         float(np.max(restore_ms)) if restore_ms else 0.0, "ms"),
        ("recovery_bitexact", bitexact, "bool"),
    ]
    return rows, plan


def run_serving(requests: int, rate: float, kills: int,
                warmup: int = 64):
    """Loadgen against a server whose dispatcher dies at ``kills``
    consecutive dispatch indices (each restart's next dispatch dies
    again — a persistent-fault storm, absorbed in place). The kills are
    scheduled just past the warmup dispatches (warmup acts are
    sequential, one dispatch each) so the MEASURED phase is the one
    degraded."""
    first = min(warmup, requests) + 1
    plan = FaultPlan(events=tuple(("dispatcher", d)
                                  for d in range(first, first + kills)))
    metrics = loadgen.run(serve_spec(faults=plan, max_restarts=kills + 1),
                          requests=requests, rate=rate, seed=0,
                          warmup=warmup, retry=3, retry_backoff_ms=2.0)
    return [
        ("degraded_serve_qps", metrics["serve_qps"], "req/s"),
        ("degraded_serve_p99_ms", metrics["serve_p99_ms"], "ms"),
        ("degraded_serve_shed", float(metrics["serve_shed"]), "count"),
        ("degraded_serve_restarts",
         float(metrics["serve_restarts"]), "count"),
    ]


def config_fingerprint(intervals: int, fault_seed: int, requests: int,
                       rate: float, kills: int) -> dict:
    """Everything that changes what a recovery number means: the
    training workload, the pinned storm, and the serving load."""
    fp = api.workload_fingerprint(train_spec("<tmp>", intervals))
    fp["faults"] = FaultPlan.generate(fault_seed, intervals,
                                      n_events=3).canonical()
    fp["load"] = {"intervals": int(intervals), "requests": int(requests),
                  "rate": float(rate), "kills": int(kills)}
    return fp


def main() -> None:
    from benchmarks.run import host_fingerprint
    ap = argparse.ArgumentParser()
    ap.add_argument("--intervals", type=int, default=8)
    ap.add_argument("--fault-seed", type=int, default=7,
                    help="FaultPlan.generate seed — pin it and the "
                         "identical storm replays every run")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--rate", type=float, default=2000.0)
    ap.add_argument("--kills", type=int, default=2,
                    help="consecutive dispatcher kills during serving")
    ap.add_argument("--append-sps", default=None, metavar="FILE",
                    help="append the result as a JSON line (e.g. "
                         "BENCH_sps.json)")
    args = ap.parse_args()
    t0 = time.time()
    rows, plan = run_training(args.intervals, args.fault_seed)
    rows += run_serving(args.requests, args.rate, args.kills)
    print("name,value,unit")
    for name, value, unit in rows:
        print(f"{name},{value:.6g},{unit}", flush=True)
    by_name = {name: value for name, value, _ in rows}
    if by_name["recovery_bitexact"] != 1.0:
        print("# recovery_bench: RECOVERED RUN DIVERGED from the "
              "fault-free twin — the bit-exact recovery contract is "
              "broken", file=sys.stderr)
        sys.exit(1)
    if args.append_sps:
        record = {
            "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "bench": "recovery",
            "host": host_fingerprint(),
            "config": config_fingerprint(args.intervals, args.fault_seed,
                                         args.requests, args.rate,
                                         args.kills),
            "wall_s": round(time.time() - t0, 2),
            "sps": {name: round(value, 2) for name, value, _ in rows},
        }
        with open(args.append_sps, "a") as f:
            f.write(json.dumps(record) + "\n")
        print(f"# appended to {args.append_sps}", file=sys.stderr,
              flush=True)


if __name__ == "__main__":
    main()
