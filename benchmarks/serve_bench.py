"""Serving throughput/latency bench: open-loop Poisson load against a
PolicyServer (repro.serve.loadgen), recorded like every other bench.

    PYTHONPATH=src python -m benchmarks.serve_bench \
        --requests 400 --rate 2000 --append-sps BENCH_sps.json

The workload is the serving mirror of the default engine bench
(catch x mlp) behind a ``runtime="serve"`` session. ``--append-sps``
records ``serve_qps`` / ``serve_p50_ms`` / ``serve_p99_ms`` /
``serve_mean_batch`` into BENCH_sps.json with the host fingerprint and
a serve-specific config fingerprint — the workload fingerprint PLUS the
serve block and the offered load (max_batch and the request rate both
change what a QPS number means) — so benchmarks/check_sps.py gates
``serve_qps`` exactly like the training sps keys: against the median of
comparable prior records, on the same host, same config.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro import api
from repro.serve import loadgen


def serve_spec(max_batch: int = 32, max_queue: int = 1024,
               timeout_ms: float = 20.0) -> api.ExperimentSpec:
    """The default serving bench workload: the engine bench's
    catch x mlp policy behind a ``runtime="serve"`` session."""
    return api.ExperimentSpec(
        env="catch",
        policy="mlp",
        optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4}},
        algorithm="a2c",
        runtime="serve",
        hts={"alpha": 8, "n_envs": 8, "seed": 0},
        serve={"max_batch": max_batch, "max_queue": max_queue,
               "timeout_ms": timeout_ms})


def config_fingerprint(spec: api.ExperimentSpec, requests: int,
                       rate: float) -> dict:
    """Everything that changes what a serve_* number means: the policy
    workload, the serve block (dispatch width bounds occupancy), and
    the offered load."""
    fp = api.workload_fingerprint(spec)
    fp["serve"] = spec.serve.canonical()
    fp["load"] = {"requests": int(requests), "rate": float(rate)}
    return fp


def run(requests: int = 400, rate: float = 2000.0, seed: int = 0,
        spec: api.ExperimentSpec | None = None,
        checkpoint: str | None = None):
    """Bench-CSV wrapper over repro.serve.loadgen.run: returns
    ``(rows, spec)``, rows as ``(name, value, unit)``."""
    spec = spec if spec is not None else serve_spec()
    metrics = loadgen.run(spec, requests=requests, rate=rate, seed=seed,
                          checkpoint=checkpoint)
    units = {"serve_qps": "req/s", "serve_p50_ms": "ms",
             "serve_p99_ms": "ms", "serve_mean_batch": "rows"}
    return [(name, value, units[name])
            for name, value in metrics.items()], spec


def main() -> None:
    from benchmarks.run import host_fingerprint
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="offered load, req/s (open-loop Poisson)")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spec", default=None, metavar="FILE",
                    help="serve an ExperimentSpec JSON instead of the "
                         "default catch x mlp workload")
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="TrainState capsule base path (step_NNNNNNNN, "
                         "no suffix); default: the spec's checkpoint "
                         "dir's latest, else initial params")
    ap.add_argument("--append-sps", default=None, metavar="FILE",
                    help="append the result as a JSON line (e.g. "
                         "BENCH_sps.json)")
    args = ap.parse_args()
    spec = (api.load(args.spec) if args.spec
            else serve_spec(max_batch=args.max_batch))
    t0 = time.time()
    rows, spec = run(requests=args.requests, rate=args.rate,
                     seed=args.seed, spec=spec,
                     checkpoint=args.checkpoint)
    print("name,value,unit")
    for name, value, unit in rows:
        print(f"{name},{value:.6g},{unit}", flush=True)
    if args.append_sps:
        record = {
            "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "bench": "serve",
            "host": host_fingerprint(),
            "config": config_fingerprint(spec, args.requests, args.rate),
            "wall_s": round(time.time() - t0, 2),
            "sps": {name: round(value, 2) for name, value, _ in rows},
        }
        with open(args.append_sps, "a") as f:
            f.write(json.dumps(record) + "\n")
        print(f"# appended to {args.append_sps}", file=sys.stderr,
              flush=True)


if __name__ == "__main__":
    main()
