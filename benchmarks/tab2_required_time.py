"""Tab. 2: required-TIME metric — virtual wall-clock to reach a target
reward on the mini-football drill (PPO), per system."""
import numpy as np
import jax

from repro.core import mesh_runtime
from repro.core.baselines import make_sync_step, sync_init_carry
from repro.core.mesh_runtime import HTSConfig
from repro.core.runtime_model import expected_runtime
from repro.envs import football
from repro.envs.interfaces import vectorize
from repro.models.cnn_policy import apply_mlp_policy, init_mlp_policy
from repro.optim import rmsprop

N_ENVS, ALPHA, MAX_IV = 8, 16, 80
LEARN_FRAC = 0.25


def _first_hit(metrics, per_step_time, alpha, target):
    r = np.asarray(metrics["rewards"])          # (iv, alpha, envs)
    run = np.cumsum(r.reshape(r.shape[0], -1).mean(1)) / \
        np.arange(1, r.shape[0] + 1)
    hits = np.nonzero(run >= target)[0]
    if len(hits) == 0:
        return float("nan")
    steps = (hits[0] + 1) * alpha * N_ENVS
    return steps * per_step_time


def run():
    env1 = football.make()
    venv = vectorize(env1, N_ENVS)
    cfg = HTSConfig(alpha=ALPHA, n_envs=N_ENVS, seed=0, algorithm="ppo",
                    use_gae=True)
    params = init_mlp_policy(jax.random.key(0), env1.obs_shape[0],
                             env1.n_actions)
    opt = rmsprop(3e-4, eps=1e-5)
    policy = apply_mlp_policy

    K = MAX_IV * ALPHA * N_ENVS
    t_hts = expected_runtime(K, N_ENVS, ALPHA, 1.0) / K
    t_sync = (expected_runtime(K, N_ENVS, 1, 1.0) +
              LEARN_FRAC * K / N_ENVS) / K

    _, m_hts = mesh_runtime.train(params, policy, venv, opt, cfg, MAX_IV)
    sstep = make_sync_step(policy, venv, opt, cfg)
    _, m_sync = jax.jit(lambda c: jax.lax.scan(
        sstep, c, None, length=MAX_IV))(
        sync_init_carry(params, opt, venv, cfg))

    def final(m):
        r = np.asarray(m["rewards"])
        return float(r[-r.shape[0] // 4:].mean())

    # self-calibrating target: half the better system's final rate
    target = 0.5 * max(final(m_hts), final(m_sync), 1e-4)
    return [
        ("tab2_target_goal_rate", target, "r/step"),
        ("tab2_required_time_hts_ppo",
         _first_hit(m_hts, t_hts, ALPHA, target), "virtual_s"),
        ("tab2_required_time_sync_ppo",
         _first_hit(m_sync, t_sync, ALPHA, target), "virtual_s"),
    ]
