"""Fig. 3(a,b,c): analytic runtime/latency models vs simulation.

(a) E[T] vs step-time variance (fixed alpha=4)
(b) E[T] vs synchronization interval alpha (fixed beta=2)
(c) E[L] stale-policy latency vs number of actors (M/M/1) — HTS-RL = 1.
"""
import numpy as np

from repro.core.runtime_model import expected_runtime, simulate_runtime
from repro.core.stale_sim import expected_latency, hts_latency, \
    simulate_latency

K, N = 64000, 16


def run():
    rows = []
    # (a) variance sweep at fixed per-step mean 1 (Gamma(k, k))
    for k_shape in (16.0, 4.0, 1.0, 0.25):
        var = 1.0 / k_shape
        pred = expected_runtime(K, N, 4, beta=k_shape, step_shape=k_shape)
        sim = np.mean([simulate_runtime(K, N, 4, beta=k_shape,
                                        step_shape=k_shape, seed=s)
                       for s in range(3)])
        rows.append((f"fig3a_var{var:g}_analytic", pred, "s"))
        rows.append((f"fig3a_var{var:g}_sim", float(sim), "s"))
    # (b) alpha sweep, beta=2 exponential
    for alpha in (1, 4, 16, 64):
        pred = expected_runtime(K, N, alpha, beta=2.0)
        sim = np.mean([simulate_runtime(K, N, alpha, 2.0, seed=s)
                       for s in range(3)])
        rows.append((f"fig3b_alpha{alpha}_analytic", pred, "s"))
        rows.append((f"fig3b_alpha{alpha}_sim", float(sim), "s"))
    # (c) latency vs actors (lam0=100, mu=4000 — the paper's GFootball #s)
    for n in (4, 8, 16, 32):
        rows.append((f"fig3c_actors{n}_analytic",
                     expected_latency(n, 100.0, 4000.0), "updates"))
        rows.append((f"fig3c_actors{n}_sim",
                     simulate_latency(n, 100.0, 4000.0), "updates"))
        rows.append((f"fig3c_actors{n}_hts", float(hts_latency(n)),
                     "updates"))
    return rows
