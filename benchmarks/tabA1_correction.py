"""Tab. A1: the delayed gradient vs off-policy corrections under forced
staleness — HTS-RL's delay-1 + delayed gradient should match or beat
truncated-IS / eps / no-correction at staleness k."""
import jax

from benchmarks.common import tail_mean
from repro.core import mesh_runtime
from repro.core.baselines import (AsyncConfig, async_init_carry,
                                  make_async_step)
from repro.core.mesh_runtime import HTSConfig
from repro.envs import token_env
from repro.envs.interfaces import vectorize
from repro.models.cnn_policy import apply_token_policy, init_token_policy
from repro.optim import rmsprop

VOCAB, N_ENVS, IV = 32, 8, 60


def run():
    env1 = token_env.make(vocab=VOCAB, seed=1)
    venv = vectorize(env1, N_ENVS)
    cfg = HTSConfig(alpha=8, n_envs=N_ENVS, seed=0, entropy_coef=0.003)
    params = init_token_policy(jax.random.key(0), VOCAB, hidden=64)
    opt = rmsprop(5e-3, eps=1e-5)
    # Tab. A1 setting: behavior data is exactly ONE update old for every
    # variant (HTS-RL's guarantee); what varies is where the gradient is
    # taken + the correction. Ours: gradient at theta_{j-1} (delayed).
    # Alternatives: gradient at theta_j on the 1-delayed data with
    # truncated-IS / eps / no correction (staleness=1 async schedule).
    rows = []
    import numpy as np
    scores = {"delayed_gradient": []}
    for corr in ("trunc_is", "epsilon", "none"):
        scores[f"stale1_{corr}"] = []
    for seed in (0, 1, 2):
        cfg_s = cfg._replace(seed=seed)
        _, m = mesh_runtime.train(params, apply_token_policy, venv, opt,
                                  cfg_s, IV)
        scores["delayed_gradient"].append(tail_mean(m["rewards"]))
        for corr in ("trunc_is", "epsilon", "none"):
            acfg = AsyncConfig(staleness=1, correction=corr)
            astep = make_async_step(apply_token_policy, venv, opt, cfg_s,
                                    acfg)
            ac = async_init_carry(params, opt, venv, cfg_s, acfg)
            _, m = jax.jit(lambda c, s=astep: jax.lax.scan(
                s, c, None, length=IV))(ac)
            scores[f"stale1_{corr}"].append(tail_mean(m["rewards"]))
    for k, v in scores.items():
        rows.append((f"tabA1_{k}", float(np.mean(v)), "r/step"))
    return rows
