"""Fig. 4(left): HTS-RL speedup over sync A2C/PPO vs step-time variance.

Modeled wall-clock: sync baseline synchronizes every step (alpha=1) AND
alternates rollout/learning (adds learner time per interval); HTS-RL
batches alpha=16 and overlaps the learner (max instead of sum).
"""
from repro.core.runtime_model import expected_runtime

K, N, ALPHA = 32000, 16, 16
LEARN_FRAC = 0.25      # learner time as a fraction of mean rollout time
MIN_SHAPE = 1.0 / 16.0


def run():
    rows = []
    # NOTE: Eq. (7)'s extreme-value approximation needs Gamma shape
    # alpha*k >= ~0.25; the sync baseline (alpha=1) bounds how much
    # per-step variance we can model, so the sweep stops at var=4.
    for k_shape, label in ((16.0, "lowvar"), (1.0, "expvar"),
                           (0.25, "highvar")):
        t_roll_sync = expected_runtime(K, N, 1, beta=k_shape,
                                       step_shape=k_shape)
        t_roll_hts = expected_runtime(K, N, ALPHA, beta=k_shape,
                                      step_shape=k_shape)
        learn = LEARN_FRAC * K / N
        t_sync = t_roll_sync + learn             # alternating
        t_hts = max(t_roll_hts, learn)           # concurrent
        rows.append((f"fig4_{label}_sync", t_sync, "s"))
        rows.append((f"fig4_{label}_hts", t_hts, "s"))
        rows.append((f"fig4_{label}_speedup", t_sync / t_hts, "x"))
    return rows
