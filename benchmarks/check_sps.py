"""SPS regression gate over the committed bench trajectory.

``BENCH_sps.json`` is an append-only JSON-lines file: one record per
``benchmarks.run --runtime ... --append-sps`` invocation, each with an
``sps`` mapping of ``engine_sps_<runtime>[_<backend>] -> steps/second``.
CI appends a fresh record on every push and then runs this checker,
which compares the NEWEST record carrying ``--key`` (the run that just
happened; several benches append to one file, so the last line may
belong to a different bench) against the MEDIAN of the last
``--baseline-window`` prior records measured with
the same ``intervals`` setting, the same host fingerprint
(``benchmarks.run.host_fingerprint``), AND the same workload config
fingerprint (``benchmarks.engine_sps.config_fingerprint``: alpha,
n_envs, env, algorithm, staleness, ...) — the committed baseline
trajectory. The pass floor is variance-aware: it widens with the
window's median absolute deviation (``--mads``), because single-record
gating flaps on keys that are intrinsically noisy on shared hardware
(the committed host entry has swung 1330 -> 454 sps with no code
change). Records from different hardware or different workloads are
never compared: that would gate on machine/workload identity, not on
code. Old records written before config fingerprinting are skipped as
baselines — loudly, so the vacuous comparison is visible in CI logs.

    python -m benchmarks.check_sps BENCH_sps.json \
        --key engine_sps_mesh --max-regression 0.30

Exit codes: 0 = pass or graceful skip (no baseline / no comparable
record / missing key), 1 = regression beyond the threshold. Skips are
loud (printed to stderr) so a silently-vacuous gate is visible in CI
logs — and a no-baseline skip names the AXIS each candidate was
rejected on (host fingerprint, intervals, config fingerprint), with
the current and candidate values, so "the runner's core count changed"
reads as exactly that instead of a generic "no comparable record".
"""
from __future__ import annotations

import argparse
import json
import sys


def load_records(path: str):
    try:
        with open(path) as f:
            lines = [(i, ln.strip()) for i, ln in enumerate(f, 1)
                     if ln.strip()]
    except OSError:
        return None
    records = []
    for lineno, ln in lines:
        try:
            records.append(json.loads(ln))
        except ValueError as e:
            # tolerate a truncated/hand-edited line, but LOUDLY: a
            # silently-dropped record shrinks the baseline window (or
            # hides the record being gated) with no visible trace
            print(f"# check_sps skip: {path}:{lineno} is not valid JSON "
                  f"({e}) — line ignored", file=sys.stderr)
            continue
    return records


def _is_fresh(rec, key: str) -> bool:
    """False when the record's value for ``key`` was replayed from a
    sweep checkpoint (benchmarks.run --resume) rather than measured —
    stale numbers must neither be gated nor serve as a baseline."""
    return not any(key == f"engine_sps_{r}"
                   for r in rec.get("restored_runtimes", []))


def _config_diff(a, b) -> str:
    """Field-level differences between two workload config fingerprints
    (canonical spec dicts — benchmarks.engine_sps.config_fingerprint),
    one ``path: ours != theirs`` line each. Falls back to repr for
    fingerprints that predate the spec form."""
    try:
        from repro.api.spec import diff_canonical
        lines = diff_canonical(a or {}, b or {})
    except ImportError:       # standalone use without PYTHONPATH=src
        return f"current={a!r} vs candidate={b!r}"
    return "; ".join(lines) if lines else "(equal)"


def _median(values):
    vs = sorted(values)
    n = len(vs)
    mid = n // 2
    return vs[mid] if n % 2 else 0.5 * (vs[mid - 1] + vs[mid])


def check(records, key: str, max_regression: float,
          window: int = 5, mads: float = 4.0):
    """Returns (ok: bool, message: str). ok=True includes skips.

    The baseline is the MEDIAN of the last ``window`` comparable prior
    records, and the pass floor is widened by the window's observed
    noise: ``floor = median - max(mads * MAD, max_regression * median)``
    where MAD is the median absolute deviation of the window. A noisy
    entry (the committed host numbers wobble 1330 -> 454 sps run to run
    on shared CI hardware) therefore widens its own tolerance band
    instead of making the single-latest-record gate flap; a genuinely
    quiet key (MAD ~ 0) keeps the plain ``1 - max_regression`` ratio
    floor, which is also the exact behavior when only one comparable
    prior record exists."""
    if not records:
        return True, f"skip: no records (no baseline yet for {key})"
    # the gated measurement is the NEWEST record carrying this key:
    # BENCH_sps.json interleaves records from several benches (engine
    # sweep, staleness sweep, serve bench), so records[-1] may belong to
    # a different bench entirely — anchoring on it would silently skip
    # every key whose bench did not happen to append last
    cur_idx = next((i for i in range(len(records) - 1, -1, -1)
                    if records[i].get("sps", {}).get(key) is not None),
                   None)
    if cur_idx is None:
        return True, f"skip: no record has a {key} measurement"
    current = records[cur_idx]
    cur_sps = current["sps"][key]
    if not _is_fresh(current, key):
        return True, (f"skip: newest record with {key} was replayed "
                      f"from a sweep checkpoint, not measured")
    baselines, rejected, near_miss = [], {}, None
    for rec in reversed(records[:cur_idx]):
        if len(baselines) >= max(1, window):
            break             # newest-first: the trailing window is full
        if rec.get("sps", {}).get(key) is None:
            continue
        if not _is_fresh(rec, key):
            continue          # replayed measurement — not a baseline
        if rec.get("intervals") != current.get("intervals"):
            # SPS only comparable at equal sweep shape. Every rejection
            # below records WHICH axis mismatched (with both values) —
            # a gate that silently stops gating because e.g. the runner
            # changed core count must say so, not print a generic
            # "no baseline" (the 1cpu-vs-2cpu host drift did exactly
            # that before this bookkeeping existed)
            rejected.setdefault("intervals", []).append(
                f"{current.get('intervals')!r} != {rec.get('intervals')!r}")
            continue
        if rec.get("host") != current.get("host"):
            # equal hardware only: a CI runner regressing against a
            # dev-machine baseline measures hardware, not code
            rejected.setdefault("host fingerprint", []).append(
                f"{current.get('host')!r} != {rec.get('host')!r}")
            continue
        if "config" not in rec:
            # pre-fingerprint record: it may have been measured with ANY
            # HTSConfig (alpha/n_envs/env/staleness), so treating it as
            # the baseline would gate on workload identity, not code.
            # Skip it — loudly, below — rather than guess.
            rejected.setdefault("no config fingerprint", []).append("")
            continue
        if rec.get("config") != current.get("config"):
            # different workload — SPS not comparable; keep the nearest
            # one so the skip message can show WHICH fields differ
            # instead of an opaque "fingerprint differs"
            rejected.setdefault("config fingerprint", []).append("")
            near_miss = near_miss or rec
            continue
        baselines.append(rec)
    if not baselines:
        axes = []
        for axis, vals in rejected.items():
            sample = next((v for v in vals if v), None)
            axes.append(f"{len(vals)} on {axis}"
                        + (f" (current vs candidate: {sample})"
                           if sample else ""))
        extra = ("; rejected candidate baseline(s): " + "; ".join(axes)
                 if axes else "")
        if "no config fingerprint" in rejected:
            extra += (" — unfingerprinted records cannot verify the "
                      "workload matches")
        if near_miss is not None:
            extra += (f"; nearest config candidate "
                      f"({near_miss.get('ts', '?')}) differs in: "
                      f"{_config_diff(current.get('config'), near_miss.get('config'))}")
        return True, (f"skip: no prior record with {key} at "
                      f"intervals={current.get('intervals')} on host "
                      f"{current.get('host')!r} with matching config "
                      f"fingerprint — nothing to regress against{extra}")
    values = [rec["sps"][key] for rec in baselines]
    base_sps = _median(values)
    if base_sps <= 0:
        return True, f"skip: degenerate baseline {key}={base_sps}"
    mad = _median([abs(v - base_sps) for v in values])
    floor = base_sps - max(mads * mad, max_regression * base_sps)
    ratio = cur_sps / base_sps
    msg = (f"{key}: current={cur_sps:.1f} sps, baseline={base_sps:.1f} sps "
           f"(median of {len(values)}, newest {baselines[0].get('ts', '?')}, "
           f"MAD={mad:.1f}), ratio={ratio:.2f}, floor={floor:.1f}")
    if cur_sps < floor:
        return False, f"REGRESSION {msg}"
    return True, f"OK {msg}"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("file", help="BENCH_sps.json (JSON-lines)")
    ap.add_argument("--key", default="engine_sps_mesh",
                    help="sps entry to gate on (default engine_sps_mesh)")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="minimum tolerance: fail only when current < "
                         "baseline - max(mads*MAD, this*baseline)")
    ap.add_argument("--baseline-window", type=int, default=5,
                    help="number of comparable prior records whose "
                         "median (and MAD) form the baseline")
    ap.add_argument("--mads", type=float, default=4.0,
                    help="noise tolerance in median-absolute-deviations "
                         "of the baseline window")
    args = ap.parse_args()
    records = load_records(args.file)
    if records is None:
        print(f"# check_sps skip: {args.file} not found", file=sys.stderr)
        return 0
    ok, msg = check(records, args.key, args.max_regression,
                    window=args.baseline_window, mads=args.mads)
    print(f"# check_sps {msg}", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
