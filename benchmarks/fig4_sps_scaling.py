"""Fig. 4(right): throughput (steps/s) vs number of environments —
threaded host runtime with real (scaled) exponential step delays, catch
policy. HTS-RL SPS should scale ~linearly in n_envs; the synchronous
baseline's shouldn't (straggler effect).

Second axis (PR 9): replica scale-out. ``run()`` adds
``engine_sps_sharded_r<N>`` rows for every replica count the local
platform can size (batch.n_replicas ∈ {1, 2, ...} up to the device
count, fixed global batch) — the data-parallel half of Fig. 4, where
the determinism contract means the curves measure pure scheduling,
never a changed optimization problem. Standalone:

    XLA_FLAGS=--xla_force_host_platform_device_count=2 PYTHONPATH=src \
        python -m benchmarks.fig4_sps_scaling --n-replicas 1,2 \
        --append-sps BENCH_sps.json

(the module CLI defers to benchmarks.run's sweep machinery, which owns
fingerprinting and record layout)."""
import jax

from repro import models
from repro.core.host_runtime import HostConfig, HostHTSRL
from repro.core.mesh_runtime import HTSConfig
from repro.core.runtime_model import expected_runtime
from repro.envs import catch
from repro.envs.steptime import StepTimeModel
from repro.optim import rmsprop

SCALE = 0.004            # seconds per simulated mean step


def replica_rows(n_replicas=None, intervals=12, n_envs=8):
    """``engine_sps_sharded_r<N>`` rows: the sharded runtime at each
    replica count, fixed global batch. ``n_replicas=None`` sizes the
    axis to the local platform: every power of two up to the visible
    device count (1 device -> just r1)."""
    from benchmarks import engine_sps
    if n_replicas is None:
        n_replicas = []
        r = 1
        while r <= len(jax.devices()):
            n_replicas.append(r)
            r *= 2
    rows = []
    for nr in n_replicas:
        rows.extend(engine_sps.run(runtimes=["sharded"],
                                   intervals=intervals, n_envs=n_envs,
                                   n_replicas=nr))
    return rows


def run():
    env1 = catch.make()
    policy = models.get_policy("mlp", env1)
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4)
    rows = []
    for n_envs in (2, 4, 8, 16):
        cfg = HTSConfig(alpha=8, n_envs=n_envs, seed=0)
        host = HostConfig(n_actors=2,
                          step_time=StepTimeModel(shape=1.0, rate=1.0),
                          time_scale=SCALE)
        runner = HostHTSRL(env1, policy.apply, params, opt, cfg, host)
        out = runner.run(4)
        rows.append((f"fig4r_hts_envs{n_envs}", out.sps, "sps"))
        # sync baseline: same steps, modeled (alpha=1 barrier per step)
        K = 4 * cfg.alpha * n_envs
        t_sync = expected_runtime(K, n_envs, 1, beta=1.0) * SCALE
        rows.append((f"fig4r_syncmodel_envs{n_envs}", K / t_sync,
                     "virtual_sps"))
    # the replica-scaling half: auto-sized to the local platform
    rows.extend(replica_rows())
    return rows


if __name__ == "__main__":
    # the CLI form used by CI's forced-2-device scaling leg; delegates
    # to benchmarks.run so records carry the standard fingerprints
    import sys
    from benchmarks.run import main
    sys.argv = ([sys.argv[0], "--runtime", "sharded"]
                + sys.argv[1:])
    main()
