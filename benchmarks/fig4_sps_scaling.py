"""Fig. 4(right): throughput (steps/s) vs number of environments —
threaded host runtime with real (scaled) exponential step delays, catch
policy. HTS-RL SPS should scale ~linearly in n_envs; the synchronous
baseline's shouldn't (straggler effect)."""
import jax

from repro import models
from repro.core.host_runtime import HostConfig, HostHTSRL
from repro.core.mesh_runtime import HTSConfig
from repro.core.runtime_model import expected_runtime
from repro.envs import catch
from repro.envs.steptime import StepTimeModel
from repro.optim import rmsprop

SCALE = 0.004            # seconds per simulated mean step


def run():
    env1 = catch.make()
    policy = models.get_policy("mlp", env1)
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4)
    rows = []
    for n_envs in (2, 4, 8, 16):
        cfg = HTSConfig(alpha=8, n_envs=n_envs, seed=0)
        host = HostConfig(n_actors=2,
                          step_time=StepTimeModel(shape=1.0, rate=1.0),
                          time_scale=SCALE)
        runner = HostHTSRL(env1, policy.apply, params, opt, cfg, host)
        out = runner.run(4)
        rows.append((f"fig4r_hts_envs{n_envs}", out.sps, "sps"))
        # sync baseline: same steps, modeled (alpha=1 barrier per step)
        K = 4 * cfg.alpha * n_envs
        t_sync = expected_runtime(K, n_envs, 1, beta=1.0) * SCALE
        rows.append((f"fig4r_syncmodel_envs{n_envs}", K / t_sync,
                     "virtual_sps"))
    return rows
