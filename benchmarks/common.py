"""Shared helpers for the benchmark suite. Each module exposes
``run() -> list[(name, value, unit)]`` rows; benchmarks.run prints CSV."""
import time

import numpy as np
import jax


def tail_mean(arr, frac=0.25):
    a = np.asarray(arr)
    n = max(1, int(a.shape[0] * frac))
    return float(a[-n:].mean())


def timer(fn, *args, repeat=3, **kw):
    fn(*args, **kw)           # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat
