"""Tab. 4: actor-count ablation — SPS saturates with actors while the
final scores are IDENTICAL (full determinism)."""
import numpy as np
import jax

from repro import models
from repro.core.host_runtime import HostConfig, HostHTSRL
from repro.core.mesh_runtime import HTSConfig
from repro.envs import catch
from repro.envs.steptime import StepTimeModel
from repro.optim import rmsprop


def run():
    env1 = catch.make()
    cfg = HTSConfig(alpha=8, n_envs=8, seed=0)
    policy = models.get_policy("mlp", env1)
    params = policy.init(jax.random.key(0))
    opt = rmsprop(7e-4)
    rows, finals = [], []
    for n_actors in (1, 2, 4):
        host = HostConfig(n_actors=n_actors,
                          step_time=StepTimeModel(1.0, 1.0),
                          time_scale=0.002)
        out = HostHTSRL(env1, policy.apply, params, opt, cfg, host).run(4)
        finals.append(np.concatenate(
            [np.asarray(x).ravel() for x in
             jax.tree.leaves(out.params)]))
    identical = all(np.array_equal(finals[0], f) for f in finals[1:])
    rows.append(("tab4_scores_identical_1_2_4_actors", float(identical),
                 "bool"))
    # SPS vs actor count: Eq. (7) with actor compute time c / n_actors
    # (this container has 1 core, so thread-level actor parallelism is
    # modeled, not measured; the determinism claim above IS measured)
    from repro.core.runtime_model import expected_runtime
    K, c0 = 32000, 0.8
    for n_actors in (1, 4, 8, 16):
        t = expected_runtime(K, cfg.n_envs, cfg.alpha, beta=1.0,
                             c=c0 / n_actors)
        rows.append((f"tab4_model_sps_actors{n_actors}", K / t,
                     "virtual_sps"))
    return rows
