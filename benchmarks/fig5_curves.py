"""Fig. 5: training curves — reward vs environment steps AND reward vs
virtual wall-clock, for HTS-RL / sync / async(V-trace) / async(none).

Emits one row per (system, checkpoint): cumulative steps, virtual time,
running reward. The top-row claim (HTS-RL ~ sync in steps-domain, async
below) and the bottom-row claim (HTS-RL first in time-domain) are both
readable from the CSV.
"""
import numpy as np
import jax

from repro.core import mesh_runtime
from repro.core.baselines import (AsyncConfig, async_init_carry,
                                  make_async_step, make_sync_step,
                                  sync_init_carry)
from repro.core.mesh_runtime import HTSConfig
from repro.core.runtime_model import expected_runtime
from repro.envs import token_env
from repro.envs.interfaces import vectorize
from repro.models.cnn_policy import apply_token_policy, init_token_policy
from repro.optim import rmsprop

VOCAB, N_ENVS, ALPHA, IV = 32, 8, 8, 90
LEARN_FRAC = 0.25
CKPTS = 6


def _curve(metrics):
    r = np.asarray(metrics["rewards"]).reshape(IV, -1).mean(1)
    run = np.cumsum(r) / np.arange(1, IV + 1)
    idx = np.linspace(IV // CKPTS, IV - 1, CKPTS).astype(int)
    return idx, run[idx]


def run():
    env1 = token_env.make(vocab=VOCAB, seed=1)
    venv = vectorize(env1, N_ENVS)
    cfg = HTSConfig(alpha=ALPHA, n_envs=N_ENVS, seed=0,
                    entropy_coef=0.003)
    params = init_token_policy(jax.random.key(0), VOCAB, hidden=64)
    opt = rmsprop(5e-3, eps=1e-5)
    K = IV * ALPHA * N_ENVS

    per_step = {
        "hts": expected_runtime(K, N_ENVS, ALPHA, 1.0) / K,
        "sync": (expected_runtime(K, N_ENVS, 1, 1.0) +
                 LEARN_FRAC * K / N_ENVS) / K,
        "async_vtrace": 1.0 / N_ENVS * 1.05,   # near-ideal streaming
        "async_none": 1.0 / N_ENVS * 1.05,
    }

    curves = {}
    _, m = mesh_runtime.train(params, apply_token_policy, venv, opt, cfg,
                              IV)
    curves["hts"] = _curve(m)
    sstep = make_sync_step(apply_token_policy, venv, opt, cfg)
    _, m = jax.jit(lambda c: jax.lax.scan(sstep, c, None, length=IV))(
        sync_init_carry(params, opt, venv, cfg))
    curves["sync"] = _curve(m)
    for corr in ("vtrace", "none"):
        acfg = AsyncConfig(staleness=16, correction=corr)
        astep = make_async_step(apply_token_policy, venv, opt, cfg, acfg)
        _, m = jax.jit(lambda c, s=astep: jax.lax.scan(
            s, c, None, length=IV))(
            async_init_carry(params, opt, venv, cfg, acfg))
        curves[f"async_{corr}"] = _curve(m)

    rows = []
    for name, (idx, vals) in curves.items():
        for i, v in zip(idx, vals):
            steps = (i + 1) * ALPHA * N_ENVS
            t = steps * per_step[name]
            rows.append((f"fig5_{name}_steps{steps}_t{t:.0f}", float(v),
                         "r/step"))
    return rows
