"""Tab. 3: multi-player training — HTS-RL(PPO) controlling 1 vs 2 players
on the mini-football drill; more controlled players should reach equal or
higher scores (teammates drag the defender)."""
import numpy as np
import jax

from benchmarks.common import tail_mean
from repro.core import mesh_runtime
from repro.core.mesh_runtime import HTSConfig
from repro.envs import football
from repro.envs.interfaces import vectorize
from repro.models.cnn_policy import apply_mlp_policy, init_mlp_policy
from repro.optim import rmsprop

N_ENVS, ALPHA, IV = 8, 16, 70


def run():
    rows = []
    for n_players in (1, 2):
        env1 = (football.make() if n_players == 1
                else football.make_multi(n_players))
        venv = vectorize(env1, N_ENVS)
        cfg = HTSConfig(alpha=ALPHA, n_envs=N_ENVS, seed=0,
                        algorithm="ppo", use_gae=True)
        params = init_mlp_policy(jax.random.key(0), env1.obs_shape[0],
                                 env1.n_actions)
        opt = rmsprop(3e-4, eps=1e-5)
        _, m = mesh_runtime.train(params, apply_mlp_policy, venv, opt,
                                  cfg, IV)
        rows.append((f"tab3_goal_rate_{n_players}p",
                     tail_mean(m["rewards"]), "r/step"))
    return rows
