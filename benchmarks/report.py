"""Generate the §Dry-run / §Roofline markdown tables from artifacts.

    PYTHONPATH=src python -m benchmarks.report > artifacts/roofline_tables.md
"""
import glob
import json
from collections import defaultdict


def load(mesh):
    out = {}
    for f in sorted(glob.glob(f"artifacts/dryrun/*__{mesh}.json")):
        d = json.load(open(f))
        out[(d["arch"], d["shape"])] = d
    return out


def fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def main():
    pod = load("pod")
    multi = load("multipod")

    print("### Dry-run matrix (lower + compile status, peak memory/chip)\n")
    print("| arch | shape | 1-pod (256) | 2-pod (512) | peak/chip GB "
          "(raw CPU / TPU-est) | fits 16G |")
    print("|---|---|---|---|---|---|")
    for key in sorted(pod):
        d = pod[key]
        m = multi.get(key, {})
        if d.get("skipped"):
            print(f"| {key[0]} | {key[1]} | SKIP | SKIP | — | — |")
            continue
        ok1 = "OK" if not d.get("error") else "FAIL"
        ok2 = "OK" if (m and not m.get("error") and not m.get("skipped")) \
            else ("SKIP" if m.get("skipped") else "FAIL")
        peak = (f"{fmt_bytes(d['peak_bytes_per_chip'])} / "
                f"{fmt_bytes(d['peak_bytes_per_chip_tpu_est'])}")
        fits = "yes" if d.get("fits_16g") else "NO"
        print(f"| {key[0]} | {key[1]} | {ok1} ({d['compile_s']}s) | {ok2} "
              f"| {peak} | {fits} |")

    print("\n### Roofline terms per (arch x shape), single pod "
          "(256 x v5e chips)\n")
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | MODEL_FLOPS/HLO | dominant collectives |")
    print("|---|---|---|---|---|---|---|---|")
    for key in sorted(pod):
        d = pod[key]
        if d.get("skipped") or d.get("error"):
            continue
        r = d["roofline"]
        det = r.get("collective_detail", {})
        top = sorted(det.items(), key=lambda kv: -kv[1])[:2]
        tops = ", ".join(f"{k} {v / 1e9:.1f}GB" for k, v in top) or "—"
        print(f"| {key[0]} | {key[1]} | {r['compute_s']:.4f} | "
              f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
              f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
              f"{tops} |")

    opt = load("pod__opt")
    if opt:
        print("\n### Optimized vs baseline (per-arch best flags, "
              "EXPERIMENTS.md §Perf)\n")
        print("| arch | shape | dominant term base s | opt s | speedup | "
              "peak base GB | opt GB |")
        print("|---|---|---|---|---|---|---|")
        for key in sorted(opt):
            d = opt[key]
            b = pod.get(key, {})
            if d.get("skipped") or d.get("error") or not b or \
                    b.get("skipped") or b.get("error"):
                continue
            rb, ro = b["roofline"], d["roofline"]
            dom = rb["bottleneck"]
            base_t = rb[f"{dom}_s"]
            opt_t = ro[f"{dom}_s"]
            sp = base_t / max(opt_t, 1e-9)
            print(f"| {key[0]} | {key[1]} | {base_t:.2f} ({dom}) | "
                  f"{opt_t:.2f} | {sp:.1f}x | "
                  f"{fmt_bytes(b['peak_bytes_per_chip_tpu_est'])} | "
                  f"{fmt_bytes(d['peak_bytes_per_chip_tpu_est'])} |")

    print("\n### Multi-pod (2 x 256) deltas — what the pod axis costs\n")
    print("| arch | shape | coll term 1-pod s | coll term 2-pod s | "
          "peak/chip 2-pod GB |")
    print("|---|---|---|---|---|")
    for key in sorted(multi):
        d = multi[key]
        p = pod.get(key, {})
        if d.get("skipped") or d.get("error") or p.get("skipped"):
            continue
        print(f"| {key[0]} | {key[1]} | "
              f"{p['roofline']['collective_s']:.3f} | "
              f"{d['roofline']['collective_s']:.3f} | "
              f"{fmt_bytes(d['peak_bytes_per_chip_tpu_est'])} |")


if __name__ == "__main__":
    main()
