"""Registry-driven throughput sweep: every runtime through one code path.

Each registered runtime (host, mesh, sharded, sync, async) trains the same
policy on the same envs with the same HTSConfig; we report steps/second
after a warmup run absorbs compilation. This is the generalization of
Tab. A2 — adding a runtime to the registry automatically adds it here.

``run(runtimes=..., intervals=...)`` is also the backend of
``benchmarks.run --runtime ...`` and the CI SPS smoke check.
``config_fingerprint`` is what gets stamped into each ``BENCH_sps.json``
record: benchmarks/check_sps.py only compares SPS between records whose
fingerprints match, so a sweep run with a different alpha/n_envs/env/
staleness can never silently become the regression gate's baseline.
"""
import numpy as np
import jax

from repro.core import engine
from repro.envs import catch
from repro.models.cnn_policy import apply_mlp_policy, init_mlp_policy
from repro.optim import rmsprop

IV = 12


def config_fingerprint(alpha=8, n_envs=8, staleness=1):
    """Everything about the benchmark workload that changes what an SPS
    number means (env, model, optimizer, and the HTSConfig knobs the
    sweep exposes) — comparable across records only when equal."""
    return {"env": "catch", "model": "mlp", "opt": "rmsprop",
            "algorithm": "a2c", "seed": 0, "alpha": alpha,
            "n_envs": n_envs, "staleness": staleness}


def run(runtimes=None, intervals=IV, alpha=8, n_envs=8, staleness=1):
    env1 = catch.make()
    cfg = engine.HTSConfig(alpha=alpha, n_envs=n_envs, seed=0,
                           staleness=staleness)
    params = init_mlp_policy(jax.random.key(0),
                             int(np.prod(env1.obs_shape)), env1.n_actions)
    opt = rmsprop(7e-4)
    policy = lambda p, o: apply_mlp_policy(p, o.reshape(o.shape[0], -1))

    rows = []
    for name in (runtimes or engine.runtime_names()):
        # staleness reaches every runtime unmodified: the baselines
        # refuse K != 1 with a loud ValueError (sync is undelayed, async
        # has AsyncConfig.staleness) rather than silently running a
        # different workload than the record's config fingerprint claims
        rt = engine.make_runtime(name, env1, policy, params, opt, cfg)
        rt.run(intervals)              # warmup: compile + caches
        out = rt.run(intervals)
        rows.append((f"engine_sps_{name}", out.sps, "sps"))
    return rows
