"""Registry-driven throughput sweep: every runtime through one code path.

Each registered runtime (host, mesh, sharded, sync, async) trains the same
policy on the same envs with the same HTSConfig; we report steps/second
after a warmup run absorbs compilation. This is the generalization of
Tab. A2 — adding a runtime to the registry automatically adds it here.

``run(runtimes=..., intervals=...)`` is also the backend of
``benchmarks.run --runtime ...`` and the CI SPS smoke check.
"""
import numpy as np
import jax

from repro.core import engine
from repro.envs import catch
from repro.models.cnn_policy import apply_mlp_policy, init_mlp_policy
from repro.optim import rmsprop

IV = 12


def run(runtimes=None, intervals=IV, alpha=8, n_envs=8):
    env1 = catch.make()
    cfg = engine.HTSConfig(alpha=alpha, n_envs=n_envs, seed=0)
    params = init_mlp_policy(jax.random.key(0),
                             int(np.prod(env1.obs_shape)), env1.n_actions)
    opt = rmsprop(7e-4)
    policy = lambda p, o: apply_mlp_policy(p, o.reshape(o.shape[0], -1))

    rows = []
    for name in (runtimes or engine.runtime_names()):
        rt = engine.make_runtime(name, env1, policy, params, opt, cfg)
        rt.run(intervals)              # warmup: compile + caches
        out = rt.run(intervals)
        rows.append((f"engine_sps_{name}", out.sps, "sps"))
    return rows
