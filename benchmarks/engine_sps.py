"""Registry-driven throughput sweep: every runtime through one code path.

Each registered runtime (host, mesh, sharded, sync, async) trains the
same declarative workload — ``bench_spec()``, the default bench
ExperimentSpec (catch x mlp x rmsprop x a2c) — with only the spec's
``runtime`` axis swapped; we report steps/second after a warmup run
absorbs compilation. This is the generalization of Tab. A2 — adding a
runtime to the registry automatically adds it here.

The sweep has a second axis, ``env_backends``: "host" steps the vmapped
scalar env (the bit-exactness oracle), "device" the natively-batched
device-resident port (repro.envs.device). Device rows are keyed
``engine_sps_<runtime>_device``; host rows keep their historical
``engine_sps_<runtime>`` keys so the committed baseline trajectory in
``BENCH_sps.json`` stays comparable.

``run(runtimes=..., intervals=...)`` is also the backend of
``benchmarks.run --runtime ...`` and the CI SPS smoke check.
``config_fingerprint`` — stamped into each ``BENCH_sps.json`` record —
IS the spec's canonical JSON (repro.api.workload_fingerprint), minus
the runtime axis and the env_backend knob (one record spans every
runtime x backend cell in the sweep; both are encoded in the row key):
benchmarks/check_sps.py only compares SPS between records whose
fingerprints match, and prints the field-level spec diff when they
don't, so a sweep run with a different alpha/n_envs/env/staleness can
never silently become the regression gate's baseline.
"""
from repro import api

IV = 12


def bench_spec(runtime: str = "mesh", alpha: int = 8, n_envs: int = 8,
               staleness: int = 1, intervals: int = IV,
               env_backend: str = "host",
               n_replicas: int = 1) -> api.ExperimentSpec:
    """The default bench workload as a declarative spec. The hts dict
    carries ``env_backend`` only when non-default — and the batch block
    likewise defaults (and is popped from the fingerprint) at
    ``n_replicas=1`` — so host-backend single-replica specs serialize
    byte-identically to every pre-backend-axis record (the fingerprint
    match that keeps old baselines comparable)."""
    hts = {"alpha": alpha, "n_envs": n_envs, "seed": 0,
           "staleness": staleness}
    if env_backend != "host":
        hts["env_backend"] = env_backend
    return api.ExperimentSpec(
        env="catch",
        policy="mlp",
        optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4}},
        algorithm="a2c",
        runtime=runtime,
        hts=hts,
        intervals=intervals,
        batch=({"n_replicas": n_replicas} if n_replicas != 1 else None))


def config_fingerprint(alpha=8, n_envs=8, staleness=1, n_replicas=1):
    """Everything about the benchmark workload that changes what an SPS
    number means — the bench spec's canonical serialization, minus the
    runtime axis (the record's ``sps`` mapping is keyed per
    runtime x env_backend cell). Comparable across records only when
    equal. A non-default replica count STAYS in the fingerprint
    (workload_fingerprint keeps non-default batch blocks): an SPS
    number measured on a 2-replica mesh must never gate — or be gated
    by — a single-replica baseline."""
    fp = api.workload_fingerprint(
        bench_spec(alpha=alpha, n_envs=n_envs, staleness=staleness,
                   n_replicas=n_replicas))
    fp.pop("runtime")
    # the backend axis also lives in the row key (``_device`` suffix),
    # never in the fingerprint — a sweep that adds device rows must not
    # orphan the committed host baselines
    fp["hts"].pop("env_backend", None)
    return fp


def sweep_key(runtime: str, env_backend: str = "host",
              n_replicas: int = 1) -> str:
    """The ``sps``-mapping key for one runtime x backend x replicas
    cell. Host single-replica rows keep the historical un-suffixed
    keys; replica rows are suffixed ``_r<N>`` (satellite of the
    batch-geometry axis: ``engine_sps_sharded_r2`` etc.)."""
    suffix = "" if env_backend == "host" else f"_{env_backend}"
    rep = "" if n_replicas == 1 else f"_r{n_replicas}"
    return f"engine_sps_{runtime}{suffix}{rep}"


def run(runtimes=None, intervals=IV, alpha=8, n_envs=8, staleness=1,
        progress=None, env_backends=("host",), n_replicas=1):
    """``progress`` (optional) is attached as a Session ``on_interval``
    observer during the WARMUP run only, never the timed run. It fires
    live per interval on coordinator runtimes (host); the fused
    runtimes deliver it in one burst when the warmup program returns —
    still a progress marker between runtimes, not a per-interval
    heartbeat."""
    from repro.core import engine

    rows = []
    # training runtimes only: the serving entry ("serve") shares the
    # registry but has no interval semantics — its throughput is
    # measured by benchmarks/serve_bench.py in req/s, not sps
    for name in (runtimes or engine.training_runtime_names()):
        if n_replicas != 1 and name not in ("host", "mesh", "sharded"):
            # replica sweeps only make sense on geometry-aware runtimes
            # (the baselines reject non-default batch at build time)
            continue
        for backend in env_backends:
            # staleness reaches every runtime unmodified: the baselines
            # refuse K != 1 with a loud ValueError (sync is undelayed,
            # async has AsyncConfig.staleness) rather than silently
            # running a different workload than the record's config
            # fingerprint claims
            cell = sweep_key(name, backend, n_replicas)[len("engine_sps_"):]
            session = api.build(bench_spec(
                runtime=name, alpha=alpha, n_envs=n_envs,
                staleness=staleness, intervals=intervals,
                env_backend=backend, n_replicas=n_replicas))
            if progress is not None:
                observer = session.on_interval(
                    lambda m, _c=cell: progress(_c, m))
            session.run(intervals)         # warmup: compile + caches
            if progress is not None:
                session.remove_observer(observer)
            out = session.run(intervals)
            rows.append((sweep_key(name, backend, n_replicas), out.sps,
                         "sps"))
    return rows
