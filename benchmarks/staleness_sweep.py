"""The throughput-vs-staleness frontier (DESIGN.md §4).

Sweeps the host runtime over K ∈ {1, 2, 4, 8} under *learner-dominated*
simulated profiles: environment steps follow a seeded ``steptime`` model
(the paper's Fig. 3 distributions) while ``HostConfig.learner_time``
models a serial learner whose per-update duration rivals — or exceeds —
one interval of rollout. This is exactly the regime where the paper's
K=1 "price of determinism" bites: the coordinator stalls on the
previous learner every interval. A staleness budget K gives every
gradient pass K intervals of rollout wall time before anything blocks
on it, so throughput recovers toward the asynchronous bound while the
behavior lag stays structurally bounded at K (delayed-gradient delay-K
rule, core/delayed_grad.py) — the Staleness-Constrained Rollout
Coordination tradeoff, reproduced deterministically.

    PYTHONPATH=src python -m benchmarks.staleness_sweep \
        [--append-sps BENCH_sps.json]

Rows are named ``staleness_sps_host_<profile>_k<K>`` (distinct from the
``engine_sps_*`` regression-gate keys, so the sweep never pollutes the
gate's baseline search). The same simulated profile is also run through
the analytic runtime model's synchronized bound for reference.
"""
import argparse
import json
import sys
import time

from repro import api
from repro.envs.steptime import StepTimeModel

K_VALUES = (1, 2, 4, 8)
INTERVALS = 16
ALPHA, N_ENVS = 4, 4
SCALE = 4e-3      # simulated seconds-per-unit; keeps the sweep fast

# learner-dominated profiles: the learner's per-update duration rivals a
# full interval of rollout (mean env interval ≈ alpha * mean_step +
# dispatch overhead), so at K=1 the coordinator pays
# max(interval_j, learner_j) EVERY interval — the synchronization loss
# the paper calls the price of determinism. A staleness budget K >= 2
# pools that jitter across the pipeline (throughput moves from
# sum-of-maxes toward max-of-sums); the gain scales with the VARIANCE
# of the two sides, which is why the heavy-tailed profiles (the paper's
# Fig. 3 regime, and real game engines / real learners) are the
# interesting ones. A learner much slower than rollout is rate-bound at
# EVERY K (no schedule beats a saturated serial learner), so the
# profiles sit at the ~1x crossover where the frontier actually moves.
PROFILES = {
    # (env step model, learner_time: units, const or a StepTimeModel)
    "hivar_constL": (StepTimeModel(shape=0.1, rate=0.1), 10.0),
    "hivar_hivarL": (StepTimeModel(shape=0.1, rate=0.1),
                     StepTimeModel(shape=0.25, rate=0.25 / 14.0)),
}


def _predicted_total(model, lt, K, intervals):
    """The analytic pipeline bound on the same seeded traces the host
    runtime will draw (core/runtime_model.staleness_pipeline_runtime) —
    simulated durations only, so it predicts the speedup shape, not the
    absolute SPS (real dispatch overheads sit on top)."""
    from repro.core.runtime_model import staleness_pipeline_runtime
    R = [max(sum(model.sample(e, j * ALPHA + t, 0)
                 for t in range(ALPHA)) for e in range(N_ENVS))
         for j in range(intervals)]
    L = [lt.sample(0, j, 0 ^ 0x1EA12) if isinstance(lt, StepTimeModel)
         else lt for j in range(intervals)]
    return staleness_pipeline_runtime(R, L, K)


def _desc(t):
    """JSON-able description of a duration spec (const or StepTimeModel)."""
    if isinstance(t, StepTimeModel):
        return {"gamma_shape": t.shape, "gamma_rate": t.rate, "base": t.base}
    return t


def _stm_json(m: StepTimeModel) -> dict:
    """StepTimeModel -> the JSON runtime-kwargs form repro.api decodes
    (repro.api.session._decode_steptime)."""
    return {"shape": m.shape, "rate": m.rate, "base": m.base}


def sweep_spec(pname: str, K: int,
               intervals: int = INTERVALS) -> api.ExperimentSpec:
    """One sweep cell as a declarative spec — the simulated host profile
    rides in the runtime kwargs, JSON end to end."""
    model, learner_time = PROFILES[pname]
    host = {"n_actors": 2, "step_time": _stm_json(model),
            "time_scale": SCALE,
            "learner_time": (_stm_json(learner_time)
                             if isinstance(learner_time, StepTimeModel)
                             else learner_time)}
    return api.ExperimentSpec(
        env="catch", policy="mlp",
        optimizer={"name": "rmsprop", "kwargs": {"lr": 7e-4}},
        algorithm="a2c",
        runtime={"name": "host", "kwargs": {"host": host}},
        hts={"alpha": ALPHA, "n_envs": N_ENVS, "seed": 0, "staleness": K},
        intervals=intervals)


def run(k_values=K_VALUES, intervals=INTERVALS):
    rows = []
    for pname in PROFILES:
        for K in k_values:
            session = api.build(sweep_spec(pname, K, intervals))
            session.run(intervals)       # warmup: compile + caches
            out = session.run(intervals)
            rows.append((f"staleness_sps_host_{pname}_k{K}", out.sps,
                         "sps"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--append-sps", default=None, metavar="FILE",
                    help="append the sweep as a JSON line to FILE "
                         "(e.g. BENCH_sps.json)")
    ap.add_argument("--intervals", type=int, default=INTERVALS)
    args = ap.parse_args()
    t0 = time.time()
    rows = run(intervals=args.intervals)
    print("name,value,unit")
    for name, value, unit in rows:
        print(f"{name},{value:.6g},{unit}")
    for pname, (model, lt) in PROFILES.items():
        k1 = next(v for n, v, _ in rows if n.endswith(f"{pname}_k1"))
        best = max(v for n, v, _ in rows if f"_{pname}_k" in n)
        pred = {K: _predicted_total(model, lt, K, args.intervals)
                for K in K_VALUES}
        print(f"# {pname}: best/k1 speedup = {best / k1:.2f}x; analytic "
              f"pipeline model predicts "
              + ", ".join(f"k{K}={pred[1] / pred[K]:.2f}x"
                          for K in K_VALUES),
              file=sys.stderr)
    if args.append_sps:
        from benchmarks.run import host_fingerprint
        record = {
            "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "bench": "staleness_sweep",
            "intervals": args.intervals,
            "host": host_fingerprint(),
            "config": {"env": "catch", "model": "mlp", "alpha": ALPHA,
                       "n_envs": N_ENVS,
                       "profiles": {p: [_desc(m), _desc(lt)]
                                    for p, (m, lt) in PROFILES.items()},
                       "time_scale": SCALE},
            "wall_s": round(time.time() - t0, 2),
            "sps": {name: round(value, 2) for name, value, _ in rows},
        }
        with open(args.append_sps, "a") as f:
            f.write(json.dumps(record) + "\n")
        print(f"# appended to {args.append_sps}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
