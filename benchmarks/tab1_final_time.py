"""Tab. 1: final-TIME metric — average reward achieved within a fixed
virtual wall-clock budget (the paper sets the budget to IMPALA's 20M-step
finish time; here: the async system's finish time for K steps).

Equal-time step budgets come from the throughput model (exp step times,
mean 1): async processes K steps; sync/HTS get however many steps fit in
the async wall-clock. Each system then trains for its own step budget on
the token env and reports the final metric (tail mean reward).
"""
import numpy as np
import jax

from benchmarks.common import tail_mean
from repro.core import mesh_runtime
from repro.core.baselines import (AsyncConfig, async_init_carry,
                                  make_async_step, make_sync_step,
                                  sync_init_carry)
from repro.core.mesh_runtime import HTSConfig
from repro.core.runtime_model import async_runtime, expected_runtime
from repro.envs import token_env
from repro.envs.interfaces import vectorize
from repro.models.cnn_policy import apply_token_policy, init_token_policy
from repro.optim import rmsprop

VOCAB, N_ENVS, ALPHA = 32, 8, 8
BASE_INTERVALS = 80
LEARN_FRAC = 0.25


def run():
    env1 = token_env.make(vocab=VOCAB, seed=1)
    venv = vectorize(env1, N_ENVS)
    cfg = HTSConfig(alpha=ALPHA, n_envs=N_ENVS, seed=0, entropy_coef=0.003)
    params = init_token_policy(jax.random.key(0), VOCAB, hidden=64)
    opt = rmsprop(5e-3, eps=1e-5)

    K = BASE_INTERVALS * ALPHA * N_ENVS
    t_budget = async_runtime(K, N_ENVS, beta=1.0)          # async finishes
    t_hts_per_step = expected_runtime(K, N_ENVS, ALPHA, 1.0) / K
    t_sync_per_step = (expected_runtime(K, N_ENVS, 1, 1.0) +
                       LEARN_FRAC * K / N_ENVS) / K
    hts_steps = int(t_budget / t_hts_per_step)
    sync_steps = int(t_budget / t_sync_per_step)
    hts_iv = max(1, min(hts_steps // (ALPHA * N_ENVS), 3 * BASE_INTERVALS))
    sync_iv = max(1, min(sync_steps // (ALPHA * N_ENVS), 3 * BASE_INTERVALS))

    _, m_hts = mesh_runtime.train(params, apply_token_policy, venv, opt,
                                  cfg, hts_iv)
    sstep = make_sync_step(apply_token_policy, venv, opt, cfg)
    _, m_sync = jax.jit(lambda c: jax.lax.scan(
        sstep, c, None, length=sync_iv))(
        sync_init_carry(params, opt, venv, cfg))
    acfg = AsyncConfig(staleness=48, correction="vtrace")
    astep = make_async_step(apply_token_policy, venv, opt, cfg, acfg)
    _, m_async = jax.jit(lambda c: jax.lax.scan(
        astep, c, None, length=BASE_INTERVALS))(
        async_init_carry(params, opt, venv, cfg, acfg))

    return [
        ("tab1_budget_virtual_s", t_budget, "s"),
        ("tab1_steps_hts", hts_iv * ALPHA * N_ENVS, "steps"),
        ("tab1_steps_sync", sync_iv * ALPHA * N_ENVS, "steps"),
        ("tab1_steps_async", BASE_INTERVALS * ALPHA * N_ENVS, "steps"),
        ("tab1_reward_hts", tail_mean(m_hts["rewards"]), "r/step"),
        ("tab1_reward_sync", tail_mean(m_sync["rewards"]), "r/step"),
        ("tab1_reward_async_vtrace_k48", tail_mean(m_async["rewards"]),
         "r/step"),
    ]
