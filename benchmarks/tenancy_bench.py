"""Multi-tenant pool throughput bench: pooled vs best-sequential.

    PYTHONPATH=src python -m benchmarks.tenancy_bench \
        --append-sps BENCH_sps.json --min-speedup 1.3

The default two-tenant workload is the aggregate-utilization case the
pool exists for (DESIGN.md §13): two equal *simulation-bound* tenants
on the host runtime with the paper's low-variance gamma step-time
model (the Fig. 3 throughput-harness idiom — wall time is env-step
simulation, not learner compute). Sequentially, each tenant's
simulated env stalls leave the process idle; pooled with overlapped
slice execution, one tenant's stalls host the other tenant's compute.
Equal tenants matter: pooled wall is bounded below by the slowest
tenant's solo wall, so a lopsided pair caps the speedup at
1 + fast/slow no matter how well the pool overlaps. Sleep-dominated,
compute-light tenants (few envs, long stalls) are the regime where
the ideal 2x is approachable even on a single core, where only the
sleeps — not compute — can overlap.

Recorded keys (``--append-sps``):

  * ``tenant_agg_sps``  — pooled aggregate steps/s (the CI-gated key)
  * ``tenant_seq_sps``  — best-sequential aggregate steps/s
  * ``tenant_speedup``  — pooled / sequential aggregate SPS
  * ``tenant_jain``     — Jain fairness over weight-normalized granted
    intervals (1.0 = shares exactly proportional to weights)
  * ``tenant_sps_<name>`` — per-tenant pooled steps/s (vs pool wall)

The config fingerprint is the TUPLE of tenant workload fingerprints
plus the pool shape (weights, concurrency) — pooled records never
compare against solo records, and a change to either tenant's workload
starts a fresh baseline window (benchmarks/check_sps.py).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro import api
from repro.launch.pool import jain_index


def sim_spec(name: str, step_time: dict, seed: int,
             intervals: int = 12) -> api.ExperimentSpec:
    """Simulation-bound tenant: host runtime with a seeded gamma
    step-time model (mean sleep = _STEP_SCALE seconds), few envs so
    per-round compute stays small next to the simulated stalls.
    Quantum = half the budget: slice dispatch (capsule round-trip +
    host-pool spin-up) costs a few hundred ms, so the bench grants
    coarse slices — the overlap win is identical, the overhead
    amortized."""
    return api.ExperimentSpec(
        env="catch", runtime={
            "name": "host",
            "kwargs": {"host": {
                "n_actors": 4,
                "step_time": step_time,
                "time_scale": _STEP_SCALE,
            }},
        },
        algorithm="a2c", hts={"alpha": 4, "n_envs": 4, "seed": seed},
        intervals=intervals,
        tenancy={"name": name, "quantum": max(1, intervals // 2)})


_STEP_SCALE = 0.12    # 1.0-mean gamma step times -> ~120ms sleeps


def default_specs(intervals: int):
    """Two equal tenants with the paper's low-variance step-time model
    (envs/steptime.py preset LOW_VAR, mean 1), different run seeds."""
    return [
        sim_spec("sim-a", {"shape": 16.0, "rate": 16.0, "base": 0.0},
                 seed=3, intervals=intervals),
        sim_spec("sim-b", {"shape": 16.0, "rate": 16.0, "base": 0.0},
                 seed=4, intervals=intervals),
    ]


def config_fingerprint(specs, weights, max_concurrency: int) -> dict:
    return {
        "tenants": [api.workload_fingerprint(s) for s in specs],
        "tenant_intervals": [int(s.intervals) for s in specs],
        "weights": [int(w) for w in weights],
        "max_concurrency": int(max_concurrency),
    }


def run(specs, max_concurrency: int = 2, warmup: bool = True):
    """Pooled run, then the same tenants back-to-back. Returns
    ``(rows, pool)`` with rows as ``(name, value, unit)``.

    ``warmup`` runs every tenant for one untimed interval first, so
    neither measured phase pays jit compilation — the comparison is
    steady-state schedule vs schedule, not compile-order luck."""
    if warmup:
        for spec in specs:
            api.build(spec).run(1)
    t0 = time.perf_counter()
    pool = api.Session.pool(specs, max_concurrency=max_concurrency)
    results = pool.run()
    pool_wall = time.perf_counter() - t0
    total_steps = sum(r.steps for r in results.values())

    # best sequential schedule: independent tenants run back-to-back
    # have wall = sum of solo walls in ANY order, so one order IS the
    # best. Fresh builds — same compile budget as the pooled run paid.
    t0 = time.perf_counter()
    seq_steps = 0
    for spec in specs:
        seq_steps += api.build(spec).run(spec.intervals).steps
    seq_wall = time.perf_counter() - t0

    counts = pool.schedule_counts()
    weights = {n: pool._get(n).weight for n in results}
    jain = jain_index([counts[n] / weights[n] for n in results])
    agg = total_steps / max(pool_wall, 1e-9)
    seq = seq_steps / max(seq_wall, 1e-9)
    rows = [
        ("tenant_agg_sps", agg, "steps/s"),
        ("tenant_seq_sps", seq, "steps/s"),
        ("tenant_speedup", agg / max(seq, 1e-9), "x"),
        ("tenant_jain", jain, "index"),
    ]
    for name, r in results.items():
        rows.append((f"tenant_sps_{name}",
                     r.steps / max(pool_wall, 1e-9), "steps/s"))
    return rows, pool


def main() -> None:
    from benchmarks.run import host_fingerprint
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", action="append", default=None,
                    metavar="FILE", help="tenant spec JSON; repeat (at "
                    "least 2); default: two sim-bound host tenants")
    ap.add_argument("--intervals", type=int, default=8,
                    help="per-tenant interval budget for the default "
                    "workload")
    ap.add_argument("--max-concurrency", type=int, default=2)
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero unless pooled/sequential "
                    "aggregate SPS >= this (CI gate, e.g. 1.3)")
    ap.add_argument("--append-sps", default=None, metavar="FILE",
                    help="append the result as a JSON line (e.g. "
                         "BENCH_sps.json)")
    args = ap.parse_args()
    if args.spec:
        if len(args.spec) < 2:
            ap.error("--spec must repeat: a pool of one is no pool")
        specs = [api.load(p) for p in args.spec]
    else:
        specs = default_specs(args.intervals)
    t0 = time.time()
    rows, pool = run(specs, max_concurrency=args.max_concurrency)
    print("name,value,unit")
    for name, value, unit in rows:
        print(f"{name},{value:.6g},{unit}", flush=True)
    if args.append_sps:
        weights = [pool._get(n).weight for n in pool.tenants()]
        record = {
            "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "bench": "tenancy",
            "host": host_fingerprint(),
            "config": config_fingerprint(specs, weights,
                                         args.max_concurrency),
            "wall_s": round(time.time() - t0, 2),
            "sps": {name: round(value, 2) for name, value, _ in rows},
        }
        with open(args.append_sps, "a") as f:
            f.write(json.dumps(record) + "\n")
        print(f"# appended to {args.append_sps}", file=sys.stderr,
              flush=True)
    speedup = dict((n, v) for n, v, _ in rows)["tenant_speedup"]
    if args.min_speedup and speedup < args.min_speedup:
        print(f"tenancy_bench: pooled/sequential speedup {speedup:.2f}x "
              f"< required {args.min_speedup}x", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
