"""Catch: the classic pixel-control test environment (Atari stand-in).

A ball falls from a random column of a ROWS x COLS board; the paddle on the
bottom row moves left/stay/right. Reward +1 on catch, -1 on miss, episode
length = ROWS - 1 steps. Observation: (ROWS, COLS, 1) float image.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.interfaces import Env, with_autoreset

ROWS, COLS = 10, 5


def _obs(state):
    board = jnp.zeros((ROWS, COLS), jnp.float32)
    board = board.at[state["ball_r"], state["ball_c"]].set(1.0)
    board = board.at[ROWS - 1, state["paddle"]].set(1.0)
    return board[..., None]


def _reset(key):
    state = {
        "ball_r": jnp.zeros((), jnp.int32),
        "ball_c": jax.random.randint(key, (), 0, COLS),
        "paddle": jnp.full((), COLS // 2, jnp.int32),
    }
    return state, _obs(state)


def _step(state, action, key):
    move = action - 1                       # {0,1,2} -> {-1,0,1}
    paddle = jnp.clip(state["paddle"] + move, 0, COLS - 1)
    ball_r = state["ball_r"] + 1
    ns = {"ball_r": ball_r, "ball_c": state["ball_c"], "paddle": paddle}
    done = (ball_r >= ROWS - 1)
    caught = (paddle == state["ball_c"])
    reward = jnp.where(done, jnp.where(caught, 1.0, -1.0), 0.0)
    return ns, _obs(ns), reward, done.astype(jnp.float32)


def make() -> Env:
    return with_autoreset("catch", _reset, _step, (ROWS, COLS, 1), 3)
