"""Token environment: next-token prediction as an MDP, so the assigned
sequence-model backbones are *policies* trained by the HTS-RL learner.

A hidden deterministic transition table T: V -> V (a permutation composed
with a lossy projection, derived from the env seed) generates a token
stream. The observation is the current token; the action is a vocabulary
token; reward +1 when the action equals the true next token. This has the
observation/action shapes of language modeling while remaining a genuine
RL problem (no supervised targets are exposed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.interfaces import Env, with_autoreset

HORIZON = 64


def make(vocab: int = 256, seed: int = 0) -> Env:
    table = jax.random.permutation(jax.random.key(seed * 7 + 1),
                                   jnp.arange(vocab))
    # make it lossy so the chain has merging paths (harder than a cycle)
    table = jnp.where(jnp.arange(vocab) % 17 == 0, table[0], table)

    def _obs(state):
        return state["tok"]

    def _reset(key):
        state = {"tok": jax.random.randint(key, (), 0, vocab),
                 "t": jnp.zeros((), jnp.int32)}
        return state, _obs(state)

    def _step(state, action, key):
        del key
        nxt = table[state["tok"]]
        reward = (action == nxt).astype(jnp.float32)
        t = state["t"] + 1
        ns = {"tok": nxt, "t": t}
        done = (t >= HORIZON).astype(jnp.float32)
        return ns, _obs(ns), reward, done

    return with_autoreset(f"token{vocab}", _reset, _step, (), vocab)
