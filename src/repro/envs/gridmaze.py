"""GridMaze: deterministic navigation with pixel observations (Atari-like
horizon/credit structure, fully deterministic transition function).

N x N grid with a wall pattern; agent starts top-left. Actions:
up/down/left/right. Reward: +1 at goal, -0.01 per step. Horizon 4*N.
Observation: (N, N, 3) image (walls, agent, goal).

Two scenario sources:

  * default (``scenario_seed=None``) — the fixed legacy board: walls
    ``WALLS``, goal bottom-right. The goldens' board.
  * ``scenario_seed=k`` — a procedurally sampled board from
    ``sample_scenario(k)``: a pure numpy function of the seed alone
    (wall segments + BFS solvability check + deterministic farthest-
    reachable goal), shared verbatim by the device port — so host and
    device backends of the same seed see bit-identical static boards,
    and pool tenants (repro.tenancy) each train on a distinct
    deterministic scenario by seed.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.envs.interfaces import Env, with_autoreset

N = 9
HORIZON = 4 * N


def _walls():
    w = jnp.zeros((N, N), jnp.float32)
    w = w.at[2, 1:N - 2].set(1.0)
    w = w.at[5, 2:N].set(1.0)
    w = w.at[7, 1:4].set(1.0)
    return w


WALLS = _walls()
MOVES = jnp.array([[-1, 0], [1, 0], [0, -1], [0, 1]], jnp.int32)


def _bfs_dist(walls: np.ndarray) -> np.ndarray:
    """Grid distances from (0, 0) through open cells; -1 = unreachable."""
    dist = np.full((N, N), -1, np.int32)
    if walls[0, 0] > 0:
        return dist
    dist[0, 0] = 0
    frontier = [(0, 0)]
    while frontier:
        nxt = []
        for r, c in frontier:
            for dr, dc in ((-1, 0), (1, 0), (0, -1), (0, 1)):
                rr, cc = r + dr, c + dc
                if 0 <= rr < N and 0 <= cc < N and walls[rr, cc] == 0 \
                        and dist[rr, cc] < 0:
                    dist[rr, cc] = dist[r, c] + 1
                    nxt.append((rr, cc))
        frontier = nxt
    return dist


def sample_scenario(seed: int) -> Tuple[np.ndarray, Tuple[int, int]]:
    """Sample a solvable (walls, goal) board as a PURE function of the
    seed: numpy-only, no global state, no backend involvement — which
    is what makes host and device ports of the same seed bit-identical
    by construction. Rejection-samples wall layouts until the farthest
    BFS-reachable cell is at least N steps from the start (a
    nontrivially-deep maze); the goal is that farthest cell, row-major
    tie-break via argmax."""
    rng = np.random.default_rng(int(seed))
    while True:
        walls = np.zeros((N, N), np.float32)
        for _ in range(3 + int(rng.integers(0, 3))):   # 3..5 segments
            horiz = bool(rng.integers(0, 2))
            r = int(rng.integers(1, N - 1))
            c = int(rng.integers(1, N - 1))
            length = int(rng.integers(3, N - 1))
            if horiz:
                walls[r, c:min(c + length, N)] = 1.0
            else:
                walls[r:min(r + length, N), c] = 1.0
        walls[0, 0] = 0.0
        dist = _bfs_dist(walls)
        dist[0, 0] = -1                    # the start is never the goal
        if dist.max() < N:
            continue                       # too shallow/unsolvable: reject
        goal = np.unravel_index(int(dist.argmax()), dist.shape)
        return walls, (int(goal[0]), int(goal[1]))


def _scalar_fns(walls: jnp.ndarray, goal: Tuple[int, int]):
    """The scalar reset/step pair over one (walls, goal) board."""
    gr, gc = goal
    goal_plane = jnp.zeros((N, N), jnp.float32).at[gr, gc].set(1.0)

    def obs(state):
        agent = jnp.zeros((N, N), jnp.float32) \
            .at[state["r"], state["c"]].set(1.0)
        return jnp.stack([walls, agent, goal_plane], axis=-1)

    def reset(key):
        del key
        state = {"r": jnp.zeros((), jnp.int32),
                 "c": jnp.zeros((), jnp.int32),
                 "t": jnp.zeros((), jnp.int32)}
        return state, obs(state)

    def step(state, action, key):
        del key
        mv = MOVES[action]
        nr = jnp.clip(state["r"] + mv[0], 0, N - 1)
        nc = jnp.clip(state["c"] + mv[1], 0, N - 1)
        blocked = walls[nr, nc] > 0
        nr = jnp.where(blocked, state["r"], nr)
        nc = jnp.where(blocked, state["c"], nc)
        t = state["t"] + 1
        at_goal = (nr == gr) & (nc == gc)
        done = at_goal | (t >= HORIZON)
        reward = jnp.where(at_goal, 1.0, -0.01)
        ns = {"r": nr, "c": nc, "t": t}
        return ns, obs(ns), reward, done.astype(jnp.float32)

    return reset, step


def resolve_board(scenario_seed: Optional[int]):
    """(walls, goal) for a scenario seed; None = the legacy board."""
    if scenario_seed is None:
        return WALLS, (N - 1, N - 1)
    walls, goal = sample_scenario(scenario_seed)
    return jnp.asarray(walls), goal


def make(scenario_seed: Optional[int] = None) -> Env:
    walls, goal = resolve_board(scenario_seed)
    reset, step = _scalar_fns(walls, goal)
    kwargs = (None if scenario_seed is None
              else {"scenario_seed": int(scenario_seed)})
    return with_autoreset("gridmaze", reset, step, (N, N, 3), 4,
                          make_kwargs=kwargs)
