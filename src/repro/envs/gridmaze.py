"""GridMaze: deterministic navigation with pixel observations (Atari-like
horizon/credit structure, fully deterministic transition function).

N x N grid with a fixed wall pattern; agent starts top-left, goal
bottom-right. Actions: up/down/left/right. Reward: +1 at goal, -0.01 per
step. Horizon 4*N. Observation: (N, N, 3) image (walls, agent, goal).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.interfaces import Env, with_autoreset

N = 9
HORIZON = 4 * N


def _walls():
    w = jnp.zeros((N, N), jnp.float32)
    w = w.at[2, 1:N - 2].set(1.0)
    w = w.at[5, 2:N].set(1.0)
    w = w.at[7, 1:4].set(1.0)
    return w


WALLS = _walls()
MOVES = jnp.array([[-1, 0], [1, 0], [0, -1], [0, 1]], jnp.int32)


def _obs(state):
    agent = jnp.zeros((N, N), jnp.float32).at[state["r"], state["c"]].set(1.0)
    goal = jnp.zeros((N, N), jnp.float32).at[N - 1, N - 1].set(1.0)
    return jnp.stack([WALLS, agent, goal], axis=-1)


def _reset(key):
    del key
    state = {"r": jnp.zeros((), jnp.int32), "c": jnp.zeros((), jnp.int32),
             "t": jnp.zeros((), jnp.int32)}
    return state, _obs(state)


def _step(state, action, key):
    del key
    mv = MOVES[action]
    nr = jnp.clip(state["r"] + mv[0], 0, N - 1)
    nc = jnp.clip(state["c"] + mv[1], 0, N - 1)
    blocked = WALLS[nr, nc] > 0
    nr = jnp.where(blocked, state["r"], nr)
    nc = jnp.where(blocked, state["c"], nc)
    t = state["t"] + 1
    at_goal = (nr == N - 1) & (nc == N - 1)
    done = at_goal | (t >= HORIZON)
    reward = jnp.where(at_goal, 1.0, -0.01)
    ns = {"r": nr, "c": nc, "t": t}
    return ns, _obs(ns), reward, done.astype(jnp.float32)


def make() -> Env:
    return with_autoreset("gridmaze", _reset, _step, (N, N, 3), 4)
