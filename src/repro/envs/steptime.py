"""Environment step-time models.

The paper's Claims 1–2 and the throughput experiments depend on the *step
time distribution*, not on game content. ``StepTimeModel`` provides
deterministic per-(env, step) simulated durations for the virtual-clock
harness (container-core-count independent) and can also busy-wait or
sleep for real wall-clock experiments in the threaded host runtime.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class StepTimeModel:
    """Step time ~ Gamma(shape, rate). shape=1 -> exponential (the paper's
    Fig. 3 setting); variance = shape / rate^2."""
    shape: float = 1.0
    rate: float = 2.0
    base: float = 0.0          # deterministic floor added to every step

    def sample(self, env_id: int, step: int, seed: int = 0) -> float:
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, env_id, step]))
        return float(self.base + rng.gamma(self.shape, 1.0 / self.rate))

    def sample_batch(self, n_envs: int, n_steps: int, seed: int = 0):
        rng = np.random.default_rng(np.random.SeedSequence([seed]))
        return self.base + rng.gamma(self.shape, 1.0 / self.rate,
                                     size=(n_steps, n_envs))

    @property
    def mean(self) -> float:
        return self.base + self.shape / self.rate

    @property
    def variance(self) -> float:
        return self.shape / self.rate ** 2


def busy_wait(seconds: float) -> None:
    """Spin (not sleep) — models a CPU-bound game engine step."""
    end = time.perf_counter() + seconds
    while time.perf_counter() < end:
        pass


CONSTANT = StepTimeModel(shape=1e6, rate=1e6 / 1.0)   # ~constant 1.0
LOW_VAR = StepTimeModel(shape=16.0, rate=16.0)        # mean 1, var 1/16
EXP_VAR = StepTimeModel(shape=1.0, rate=1.0)          # mean 1, var 1
HIGH_VAR = StepTimeModel(shape=0.25, rate=0.25)       # mean 1, var 4
