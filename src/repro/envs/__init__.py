"""Environment registry — the third leaf registry (after runtimes and
algorithms): ``get_env(name, **kwargs)`` resolves a *workload source* by
name, so experiment specs (repro.api.ExperimentSpec) can name their
environment instead of importing a factory.

Most entries build an ``Env`` (repro.envs.interfaces): a bundle of pure
``reset``/``step`` functions that every engine runtime replicates to
``cfg.n_envs``. One entry — ``token_stream`` — builds a
``repro.data.pipeline.TokenStream`` instead: the batched deterministic
token source consumed ONLY by the ``stream`` runtime (the LLM-scale
learner loop behind ``repro.launch.train``). ``repro.api.build``
enforces that pairing; the registry itself just constructs.

    from repro import envs
    env1 = envs.get_env("catch")
    envs.env_names()   # -> ['catch', 'football', 'gridmaze', 'token', ...]

Built-ins resolve lazily (importing this package never drags in every
environment module); third parties add entries with ``@register_env``.
"""
from __future__ import annotations

import importlib
from typing import Any, Callable, Dict

_REGISTRY: Dict[str, Callable[..., Any]] = {}

# name -> (module, factory attribute), imported on first lookup
_LAZY: Dict[str, tuple] = {
    "catch": ("repro.envs.catch", "make"),
    "gridmaze": ("repro.envs.gridmaze", "make"),
    "football": ("repro.envs.football", "make"),
    "token": ("repro.envs.token_env", "make"),
    "token_stream": ("repro.data.pipeline", "TokenStream"),
    # device-resident batched ports (repro.envs.device): registered
    # alongside their host oracles. Specs normally reach them through
    # ``hts.env_backend="device"`` with the HOST name; the ``_device``
    # entries exist for direct construction and tests.
    "catch_device": ("repro.envs.device.catch", "make"),
    "gridmaze_device": ("repro.envs.device.gridmaze", "make"),
}


def register_env(name: str):
    """Factory decorator: ``@register_env("my_env")`` over a
    ``(**kwargs) -> Env`` callable."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_env_factory(name: str) -> Callable[..., Any]:
    """Resolve an environment factory by registry name."""
    if name not in _REGISTRY and name in _LAZY:
        module, attr = _LAZY[name]
        _REGISTRY[name] = getattr(importlib.import_module(module), attr)
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown env {name!r}; "
                       f"registered: {env_names()}") from None


def get_env(name: str, **kwargs):
    """Construct a registered environment: ``get_env("catch")``,
    ``get_env("token", vocab=128)``."""
    return get_env_factory(name)(**kwargs)


def env_names():
    return sorted(set(_REGISTRY) | set(_LAZY))


def has_device_port(name: str) -> bool:
    """Does host env ``name`` have a device-resident port
    (``HTSConfig.env_backend="device"``)? See repro.envs.device."""
    from repro.envs import device as device_envs
    return device_envs.has_device_port(name)


def get_device_env(name: str, **kwargs):
    """Construct the device-resident port of host env ``name``; loud
    ValueError listing the supported pairs when there is none."""
    from repro.envs import device as device_envs
    return device_envs.get_device_env(name, **kwargs)
