"""Device-resident gridmaze: the batched port of ``repro.envs.gridmaze``.

State layout: ``{"r", "c", "t"}``, each ``(n,)`` int32 — the stacked
pytree of the vmapped host env, capsule-compatible across backends.

Fully deterministic, so the whole step is broadcast arithmetic: moves
are a gather from the shared MOVES table, wall collisions a batched
advanced-index lookup into the shared walls board, and the 3-channel
observation is assembled from one-hot comparison masks plus broadcast
copies of the static walls/goal planes — no scatter anywhere.

Procedural scenarios: ``make(scenario_seed=k)`` resolves the SAME
``sample_scenario(k)`` board as the host factory (one pure numpy
function of the seed, repro.envs.gridmaze), so both backends of a
seeded scenario are bit-identical by construction — the equivalence
suite then pins the dynamic streams too (tests/test_device_envs.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.envs.gridmaze import HORIZON, MOVES, N, WALLS, resolve_board
from repro.envs.device import DeviceEnv, device_autoreset


def _batched_fns(walls: jnp.ndarray, goal):
    gr, gc = goal
    goal_plane = jnp.zeros((N, N), jnp.float32).at[gr, gc].set(1.0)

    def obs(state):
        rows = (state["r"][:, None]
                == jnp.arange(N, dtype=jnp.int32)).astype(jnp.float32)
        cols = (state["c"][:, None]
                == jnp.arange(N, dtype=jnp.int32)).astype(jnp.float32)
        agent = rows[:, :, None] * cols[:, None, :]
        n = state["r"].shape[0]
        walls_b = jnp.broadcast_to(walls, (n, N, N))
        goal_b = jnp.broadcast_to(goal_plane, (n, N, N))
        return jnp.stack([walls_b, agent, goal_b], axis=-1)

    def reset(keys):
        n = keys.shape[0]
        zeros = jnp.zeros((n,), jnp.int32)
        # distinct buffers per leaf: the engine donates the carry, and
        # XLA rejects donating one buffer under several leaves (eager
        # jnp.zeros is constant-cached, so three names would share one)
        state = {"r": zeros, "c": jnp.copy(zeros), "t": jnp.copy(zeros)}
        return state, obs(state)

    def step(state, actions, keys):
        del keys
        mv = MOVES[actions]                     # (n, 2) gather
        nr = jnp.clip(state["r"] + mv[:, 0], 0, N - 1)
        nc = jnp.clip(state["c"] + mv[:, 1], 0, N - 1)
        blocked = walls[nr, nc] > 0             # batched advanced indexing
        nr = jnp.where(blocked, state["r"], nr)
        nc = jnp.where(blocked, state["c"], nc)
        t = state["t"] + 1
        at_goal = (nr == gr) & (nc == gc)
        done = at_goal | (t >= HORIZON)
        reward = jnp.where(at_goal, 1.0, -0.01)
        ns = {"r": nr, "c": nc, "t": t}
        return ns, obs(ns), reward, done.astype(jnp.float32)

    return reset, step


def make(scenario_seed: Optional[int] = None) -> DeviceEnv:
    walls, goal = resolve_board(scenario_seed)
    reset, step = _batched_fns(walls, goal)
    return device_autoreset("gridmaze@device", reset, step, (N, N, 3), 4,
                            host_name="gridmaze")
