"""Device-resident gridmaze: the batched port of ``repro.envs.gridmaze``.

State layout: ``{"r", "c", "t"}``, each ``(n,)`` int32 — the stacked
pytree of the vmapped host env, capsule-compatible across backends.

Fully deterministic, so the whole step is broadcast arithmetic: moves
are a gather from the shared MOVES table, wall collisions a batched
advanced-index lookup into the shared WALLS board, and the 3-channel
observation is assembled from one-hot comparison masks plus broadcast
copies of the static walls/goal planes — no scatter anywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.gridmaze import HORIZON, MOVES, N, WALLS
from repro.envs.device import DeviceEnv, device_autoreset

_GOAL = jnp.zeros((N, N), jnp.float32).at[N - 1, N - 1].set(1.0)


def _obs(state):
    rows = (state["r"][:, None]
            == jnp.arange(N, dtype=jnp.int32)).astype(jnp.float32)
    cols = (state["c"][:, None]
            == jnp.arange(N, dtype=jnp.int32)).astype(jnp.float32)
    agent = rows[:, :, None] * cols[:, None, :]
    n = state["r"].shape[0]
    walls = jnp.broadcast_to(WALLS, (n, N, N))
    goal = jnp.broadcast_to(_GOAL, (n, N, N))
    return jnp.stack([walls, agent, goal], axis=-1)


def _reset(keys):
    n = keys.shape[0]
    zeros = jnp.zeros((n,), jnp.int32)
    # distinct buffers per leaf: the engine donates the carry, and XLA
    # rejects donating one buffer under several leaves (eager jnp.zeros
    # is constant-cached, so three names would share one buffer)
    state = {"r": zeros, "c": jnp.copy(zeros), "t": jnp.copy(zeros)}
    return state, _obs(state)


def _step(state, actions, keys):
    del keys
    mv = MOVES[actions]                     # (n, 2) gather
    nr = jnp.clip(state["r"] + mv[:, 0], 0, N - 1)
    nc = jnp.clip(state["c"] + mv[:, 1], 0, N - 1)
    blocked = WALLS[nr, nc] > 0             # batched advanced indexing
    nr = jnp.where(blocked, state["r"], nr)
    nc = jnp.where(blocked, state["c"], nc)
    t = state["t"] + 1
    at_goal = (nr == N - 1) & (nc == N - 1)
    done = at_goal | (t >= HORIZON)
    reward = jnp.where(at_goal, 1.0, -0.01)
    ns = {"r": nr, "c": nc, "t": t}
    return ns, _obs(ns), reward, done.astype(jnp.float32)


def make() -> DeviceEnv:
    return device_autoreset("gridmaze@device", _reset, _step, (N, N, 3), 4,
                            host_name="gridmaze")
