"""Device-resident catch: the batched port of ``repro.envs.catch``.

State layout: ``{"ball_r", "ball_c", "paddle"}``, each an ``(n,)`` int32
array — exactly the stacked pytree ``vectorize(catch.make(), n)``
produces, so capsules (TrainState.env_state) cross backends unchanged.

The board observation is built scatter-free: one-hot row/column masks
from broadcast comparisons, combined with an elementwise ``maximum``
(the host env's two ``.at[].set(1.0)`` writes can land on the same cell
when the ball reaches the paddle row; max reproduces the set-twice
value exactly). The one stochastic draw — the reset column — goes
through ``jax.vmap`` of the very ``randint`` the host env performs per
key, which is what pins bit-exactness of the PRNG stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.catch import COLS, ROWS
from repro.envs.device import DeviceEnv, device_autoreset

_rand_col = jax.vmap(lambda k: jax.random.randint(k, (), 0, COLS))


def _obs(state):
    # (n, ROWS) x (n, COLS) one-hot masks -> (n, ROWS, COLS) boards via
    # broadcast products; exact 0.0/1.0 floats, no scatter
    ball_row = (state["ball_r"][:, None]
                == jnp.arange(ROWS, dtype=jnp.int32)).astype(jnp.float32)
    ball_col = (state["ball_c"][:, None]
                == jnp.arange(COLS, dtype=jnp.int32)).astype(jnp.float32)
    ball = ball_row[:, :, None] * ball_col[:, None, :]
    paddle_col = (state["paddle"][:, None]
                  == jnp.arange(COLS, dtype=jnp.int32)).astype(jnp.float32)
    bottom_row = (jnp.arange(ROWS, dtype=jnp.int32)
                  == ROWS - 1).astype(jnp.float32)
    paddle = bottom_row[None, :, None] * paddle_col[:, None, :]
    return jnp.maximum(ball, paddle)[..., None]


def _reset(keys):
    n = keys.shape[0]
    state = {
        "ball_r": jnp.zeros((n,), jnp.int32),
        "ball_c": _rand_col(keys),
        "paddle": jnp.full((n,), COLS // 2, jnp.int32),
    }
    return state, _obs(state)


def _step(state, actions, keys):
    del keys                                # transitions are deterministic
    move = actions - 1                      # {0,1,2} -> {-1,0,1}
    paddle = jnp.clip(state["paddle"] + move, 0, COLS - 1)
    ball_r = state["ball_r"] + 1
    ns = {"ball_r": ball_r, "ball_c": state["ball_c"], "paddle": paddle}
    done = (ball_r >= ROWS - 1)
    caught = (paddle == state["ball_c"])
    reward = jnp.where(done, jnp.where(caught, 1.0, -1.0), 0.0)
    return ns, _obs(ns), reward, done.astype(jnp.float32)


def make() -> DeviceEnv:
    return device_autoreset("catch@device", _reset, _step, (ROWS, COLS, 1),
                            3, host_name="catch")
