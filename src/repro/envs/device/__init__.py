"""Device-resident environment fleet: natively-batched pure-JAX ports.

A ``DeviceEnv`` is the batched sibling of ``repro.envs.interfaces.Env``:
its ``reset``/``step`` operate directly on STACKED per-env state pytrees
(every leaf carries a leading ``n_envs`` axis) instead of being a scalar
program replicated by ``jax.vmap``. The call signature is deliberately
identical to ``interfaces.vectorize(env, n)``:

    reset(keys)                  -> (state, obs)         keys: (n,)
    step(state, actions, keys)   -> (state, obs, r, done)

so the fused runtimes' scan body (core/rollout.rollout_interval) and the
host runtime's batched stepper consume either interchangeably — the
``HTSConfig.env_backend`` axis selects which (``batched_env``, below).

Why a hand-batched port when vmap already traces to one program: the
vmapped envs materialize observations through per-row scatters
(``board.at[r, c].set(1.0)`` under vmap lowers to batched
scatter/dynamic-update ops), which are the slowest lane on TPU-class
backends; the device ports build the same boards from broadcast
comparisons and elementwise products — VPU-shaped code with no scatter
on the hot path. PRNG draws, where an env has them, still go through
``jax.vmap`` of the exact per-key op the host env performs: that is what
makes the port *bit-exact*, not merely equivalent.

The oracle contract (DESIGN.md §2.2, tests/test_device_envs.py): for
every registered port, ``vectorize(host_env, n)`` and the DeviceEnv
produce bit-identical (state, obs, reward, done) streams for identical
(keys, actions) inputs — including through auto-reset boundaries. The
host envs stay the semantic source of truth; a port that drifts fails
the equivalence suite, not a downstream golden.

Registry: ports register against the HOST env's registry name
(``@register_device_port("catch")``); ``has_device_port``/
``get_device_env`` resolve them, and ``repro.envs.get_env`` also exposes
each port as ``"<name>_device"`` alongside the host version.
"""
from __future__ import annotations

import importlib
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.envs.interfaces import Env, _bcast, vectorize


class DeviceEnv(NamedTuple):
    """A natively-batched jittable env over stacked per-env state.

    Field-compatible with ``interfaces.Env`` (same attribute names) so
    every consumer of a vectorized Env — rollout scan bodies, policy
    sizing, the host runtime's batched stepper — duck-types over both.
    ``host_name`` records which host env this is the device port of
    (the oracle the equivalence suite compares against).
    """
    name: str
    reset: Callable          # keys (n,) -> (state, obs (n, ...))
    step: Callable           # (state, actions (n,), keys (n,)) -> 4-tuple
    obs_shape: Tuple[int, ...]
    n_actions: int
    host_name: str


def device_autoreset(name, reset_fn, inner_step, obs_shape, n_actions,
                     host_name) -> DeviceEnv:
    """Batched mirror of ``interfaces.with_autoreset``: on done rows the
    returned state/obs are already the first of the next episode. The
    reset key is ``fold_in(key, 7)`` per row — the SAME derivation the
    host wrapper applies per scalar env, so the PRNG stream (and hence
    every downstream value) is bit-identical to the vmapped host env."""

    fold7 = jax.vmap(lambda k: jax.random.fold_in(k, 7))

    def step(state, actions, keys):
        ns, obs, r, done = inner_step(state, actions, keys)
        rs, robs = reset_fn(fold7(keys))
        state_out = jax.tree.map(
            lambda a, b: jnp.where(_bcast(done, a), b, a), ns, rs)
        obs_out = jnp.where(_bcast(done, obs), robs, obs)
        return state_out, obs_out, r, done

    return DeviceEnv(name, reset_fn, step, obs_shape, n_actions, host_name)


# ------------------------------------------------------------- registry
_REGISTRY: Dict[str, Callable[..., DeviceEnv]] = {}

# host env name -> (module, factory attribute), imported on first lookup
_LAZY: Dict[str, tuple] = {
    "catch": ("repro.envs.device.catch", "make"),
    "gridmaze": ("repro.envs.device.gridmaze", "make"),
}


def register_device_port(host_name: str):
    """Factory decorator: ``@register_device_port("my_env")`` over a
    ``(**kwargs) -> DeviceEnv`` callable, keyed by the HOST env's
    registry name (the oracle it ports)."""
    def deco(factory):
        _REGISTRY[host_name] = factory
        return factory
    return deco


def has_device_port(host_name: str) -> bool:
    return host_name in _REGISTRY or host_name in _LAZY


def device_port_names() -> list:
    """Host env names that have a device-resident port."""
    return sorted(set(_REGISTRY) | set(_LAZY))


def get_device_env(host_name: str, **kwargs) -> DeviceEnv:
    """Resolve and construct the device port of a host env by the host
    env's registry name. Loud on envs with no port — the supported
    pairs are listed so the fix is obvious."""
    if host_name not in _REGISTRY and host_name in _LAZY:
        module, attr = _LAZY[host_name]
        _REGISTRY[host_name] = getattr(importlib.import_module(module), attr)
    try:
        factory = _REGISTRY[host_name]
    except KeyError:
        raise ValueError(
            f"env {host_name!r} has no device-resident port; "
            f"env_backend='device' supports {device_port_names()} "
            f"(use env_backend='host' for the rest)") from None
    return factory(**kwargs)


def batched_env(env: Env, n_envs: int, backend: str = "host"):
    """The one place ``HTSConfig.env_backend`` is interpreted: resolve
    the batched env every runtime steps ``n_envs`` replicas through.

    ``"host"``   -> ``vectorize(env, n_envs)`` (vmapped scalar env —
                    today's semantics, and the bit-exactness oracle);
    ``"device"`` -> the env's registered DeviceEnv port (natively
                    batched, stepped inside the scan body with no host
                    dispatch). Unknown backends and envs without a port
                    fail HERE, at runtime construction — never at trace
                    time."""
    if backend == "host":
        return vectorize(env, n_envs)
    if backend == "device":
        # the host env's construction kwargs (a scenario seed, say)
        # travel with it — the port must be built the same way, or two
        # backends of one spec would quietly step different worlds
        return get_device_env(env.name,
                              **(getattr(env, "make_kwargs", None) or {}))
    raise ValueError(
        f"unknown env_backend {backend!r}; choose 'host' (vmapped "
        f"scalar envs) or 'device' (device-resident batched port)")


def make_device_env(host_name: str, **kwargs) -> DeviceEnv:
    """`repro.envs.get_env("<name>_device")` entry point."""
    return get_device_env(host_name, **kwargs)
