"""Mini-football "academy" drill (GFootball stand-in).

A striker and a defender on a [0,1]^2 pitch, goal on the right edge.
Actions: 8 movement directions + shoot. The defender chases the ball
carrier deterministically. A shot succeeds with probability decreasing in
distance-to-goal and defender proximity (sampled from the executor key —
deterministic under HTS-RL seeding). Reward +1 on goal; episode ends on
goal, on interception, or at the horizon — giving the same
score-until-terminal structure as GFootball academy scenarios.

Observation: 12-dim "extracted map" float vector (positions, deltas,
distances), matching the paper's non-pixel GFootball input option.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.interfaces import Env, with_autoreset

HORIZON = 100
GOAL = jnp.array([1.0, 0.5], jnp.float32)
DIRS = jnp.array([[0, 1], [1, 1], [1, 0], [1, -1],
                  [0, -1], [-1, -1], [-1, 0], [-1, 1]], jnp.float32)
DIRS = DIRS / jnp.linalg.norm(DIRS, axis=-1, keepdims=True)
SPEED = 0.05
DEF_SPEED = 0.035


def _obs(state):
    p, d = state["player"], state["defender"]
    to_goal = GOAL - p
    to_def = d - p
    return jnp.concatenate([
        p, d, to_goal, to_def,
        jnp.array([jnp.linalg.norm(to_goal), jnp.linalg.norm(to_def)]),
        jnp.array([state["t"] / HORIZON, 1.0]),
    ]).astype(jnp.float32)


def _reset(key):
    k1, k2 = jax.random.split(key)
    state = {
        "player": jnp.array([0.2, 0.5]) + 0.05 * jax.random.normal(k1, (2,)),
        "defender": jnp.array([0.7, 0.5]) + 0.05 * jax.random.normal(k2, (2,)),
        "t": jnp.zeros((), jnp.int32),
    }
    return state, _obs(state)


def _step(state, action, key):
    is_shot = action >= 8
    mv = DIRS[jnp.minimum(action, 7)] * SPEED
    p = jnp.clip(state["player"] + jnp.where(is_shot, 0.0, 1.0) * mv, 0.0, 1.0)
    # defender chases
    dvec = p - state["defender"]
    dn = dvec / (jnp.linalg.norm(dvec) + 1e-6)
    d = jnp.clip(state["defender"] + DEF_SPEED * dn, 0.0, 1.0)
    t = state["t"] + 1

    dist_goal = jnp.linalg.norm(GOAL - p)
    dist_def = jnp.linalg.norm(d - p)
    p_goal = jnp.clip(1.2 - 1.5 * dist_goal, 0.0, 0.95) * \
        jnp.clip(dist_def / 0.2, 0.0, 1.0)
    shot_scores = jax.random.uniform(key) < p_goal
    goal = is_shot & shot_scores
    intercepted = (dist_def < 0.03) & ~goal
    done = goal | intercepted | (t >= HORIZON) | is_shot
    reward = jnp.where(goal, 1.0, 0.0)
    ns = {"player": p, "defender": d, "t": t}
    return ns, _obs(ns), reward, done.astype(jnp.float32)


def make() -> Env:
    return with_autoreset("minifootball", _reset, _step, (12,), 9)


# ------------------------------------------------- multi-player variant
def make_multi(n_players: int = 2) -> Env:
    """Paper Tab. 3: training MULTIPLE players against the defender with a
    shared score. Joint action space (9^n, factored per player); the ball
    carrier is the player closest to the goal, teammates drag the defender
    (so coordination — spreading out — raises the scoring probability).
    Observation: per-player positions + defender + ball-carrier index.
    """
    A = 9 ** n_players
    obs_dim = 2 * n_players + 2 + 2 + n_players + 1

    def _mobs(state):
        ps = state["players"]                      # (n, 2)
        d = state["defender"]
        dists = jnp.linalg.norm(GOAL[None] - ps, axis=-1)
        carrier = jnp.argmin(dists)
        return jnp.concatenate([
            ps.reshape(-1), d, GOAL - ps[carrier],
            jax.nn.one_hot(carrier, n_players),
            jnp.array([state["t"] / HORIZON]),
        ]).astype(jnp.float32)

    def _mreset(key):
        ks = jax.random.split(key, n_players + 1)
        ps = jnp.stack([jnp.array([0.2, 0.3 + 0.4 * i / max(n_players - 1, 1)])
                        + 0.05 * jax.random.normal(ks[i], (2,))
                        for i in range(n_players)])
        state = {"players": ps,
                 "defender": jnp.array([0.7, 0.5]) +
                 0.05 * jax.random.normal(ks[-1], (2,)),
                 "t": jnp.zeros((), jnp.int32)}
        return state, _mobs(state)

    def _mstep(state, action, key):
        # decode joint action -> per-player {move dir 0..7, shoot=8}
        acts = []
        a = action
        for _ in range(n_players):
            acts.append(a % 9)
            a = a // 9
        ps = state["players"]
        new_ps = []
        shoots = []
        for i, ai in enumerate(acts):
            is_shot = ai >= 8
            mv = DIRS[jnp.minimum(ai, 7)] * SPEED
            new_ps.append(jnp.clip(
                ps[i] + jnp.where(is_shot, 0.0, 1.0) * mv, 0.0, 1.0))
            shoots.append(is_shot)
        ps = jnp.stack(new_ps)
        dists = jnp.linalg.norm(GOAL[None] - ps, axis=-1)
        carrier = jnp.argmin(dists)
        # defender chases the carrier
        dvec = ps[carrier] - state["defender"]
        d = jnp.clip(state["defender"] + DEF_SPEED * dvec /
                     (jnp.linalg.norm(dvec) + 1e-6), 0.0, 1.0)
        t = state["t"] + 1
        shot = jnp.stack(shoots)[carrier]          # only the carrier shoots
        dist_goal = dists[carrier]
        dist_def = jnp.linalg.norm(d - ps[carrier])
        # teammates near the defender pull attention: bonus to p_goal
        others = jnp.linalg.norm(ps - d[None], axis=-1)
        drag = jnp.clip(0.15 * (others < 0.25).sum() / n_players, 0.0, 0.3)
        p_goal = jnp.clip(1.2 - 1.5 * dist_goal + drag, 0.0, 0.95) * \
            jnp.clip(dist_def / 0.2, 0.0, 1.0)
        goal = shot & (jax.random.uniform(key) < p_goal)
        intercepted = (dist_def < 0.03) & ~goal
        done = goal | intercepted | (t >= HORIZON) | shot
        reward = jnp.where(goal, 1.0, 0.0)
        ns = {"players": ps, "defender": d, "t": t}
        return ns, _mobs(ns), reward, done.astype(jnp.float32)

    return with_autoreset(f"minifootball{n_players}p", _mreset, _mstep,
                          (obs_dim,), A)
