"""Vectorized pure-JAX environment interface.

An ``Env`` is a bundle of pure functions (so it vmaps/jits/shards):

    reset(key)               -> (state, obs)
    step(state, action, key) -> (state, obs, reward, done)

``step`` auto-resets: when an episode terminates the returned obs/state are
already the first of the next episode and ``done=1`` marks the boundary.
The ``key`` passed to step is only used by stochastic envs and for the
auto-reset; with HTS-RL determinism it is derived from (run_seed, env_id,
step) at the executor (see core/determinism.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Env(NamedTuple):
    name: str
    reset: Callable          # key -> (state, obs)
    step: Callable           # (state, action, key) -> (state, obs, r, done)
    obs_shape: Tuple[int, ...]
    n_actions: int
    # construction kwargs that must survive backend re-resolution: when
    # HTSConfig.env_backend='device' swaps this env for its device port
    # (device.batched_env), these kwargs are forwarded to the port's
    # factory — a scenario-seeded board means the SAME board on either
    # backend, never a silently-default one. None: factory defaults.
    make_kwargs: Any = None


def with_autoreset(name, reset_fn, inner_step, obs_shape, n_actions,
                   make_kwargs=None) -> Env:
    """Wrap a raw step (that reports done without resetting) with
    auto-reset semantics."""

    def step(state, action, key):
        ns, obs, r, done = inner_step(state, action, key)
        rs, robs = reset_fn(jax.random.fold_in(key, 7))
        state_out = jax.tree.map(
            lambda a, b: jnp.where(_bcast(done, a), b, a), ns, rs)
        obs_out = jnp.where(_bcast(done, obs), robs, obs)
        return state_out, obs_out, r, done

    return Env(name, reset_fn, step, obs_shape, n_actions,
               make_kwargs=make_kwargs)


def _bcast(done, x):
    return jnp.reshape(done, done.shape + (1,) * (x.ndim - done.ndim)) \
        if x.ndim > done.ndim else done


def vectorize(env: Env, n: int) -> Env:
    """vmap an Env over n replicas (keys (n,), actions (n,))."""
    return Env(
        name=f"{env.name}x{n}",
        reset=jax.vmap(env.reset),
        step=jax.vmap(env.step),
        obs_shape=env.obs_shape,
        n_actions=env.n_actions,
        make_kwargs=env.make_kwargs,
    )
