"""Rollout -> learner-batch pipeline.

Two producers feed the HTS-RL learner:

* ``traj_to_batch`` — converts an (alpha, n_envs) trajectory pytree from
  the rollout into the flat (B, S) token batch the LLM-scale learner
  consumes (advantages/returns computed here, on the behavior values).

* ``TokenStream`` — a deterministic synthetic token source for the
  training examples / benchmarks when no environment is in the loop
  (same hidden-Markov generator as envs/token_env, batched).

Host staging for the threaded runtime is double-buffered in
core/buffers.py; this module is pure device-side transforms.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import losses


def traj_to_batch(traj: Dict, values: jnp.ndarray, bootstrap_value,
                  gamma: float = 0.99, lam: float = 0.95,
                  use_gae: bool = True) -> Dict:
    """traj: {obs/actions/rewards/dones/behavior_logprob (T, N, ...)} ->
    learner batch with (N, T) layout (envs as batch, time as sequence)."""
    if use_gae:
        adv, rets = losses.gae(traj["rewards"], traj["dones"], values,
                               bootstrap_value, gamma, lam)
    else:
        rets = losses.n_step_returns(traj["rewards"], traj["dones"],
                                     bootstrap_value, gamma)
        adv = rets - values

    def tn(x):
        return jnp.swapaxes(x, 0, 1)

    return {
        "tokens": tn(traj["obs"]).astype(jnp.int32),
        "actions": tn(traj["actions"]).astype(jnp.int32),
        "advantages": tn(adv),
        "returns": tn(rets),
        "behavior_logprob": tn(traj["behavior_logprob"]),
        "loss_mask": jnp.ones_like(tn(adv)),
    }


class TokenStream:
    """Deterministic batched token stream (B, S) with a hidden Markov
    transition table; next-token targets become RL actions with reward 1
    for the correct continuation (the token_env contract, vectorized)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.table = jax.random.permutation(
            jax.random.key(seed * 7 + 1), jnp.arange(vocab))
        self._step = 0
        self.key = jax.random.key(seed)

    def skip(self, n: int) -> "TokenStream":
        """Fast-forward past ``n`` batches without generating them — each
        batch is a pure function of (seed, step), so a resumed run
        (launch/train.py --resume) sees exactly the continuation of the
        stream the killed run was consuming."""
        self._step += n
        return self

    def next_batch(self) -> Dict:
        key = jax.random.fold_in(self.key, self._step)
        self._step += 1
        start = jax.random.randint(key, (self.batch,), 0, self.vocab)

        def unroll(tok, _):
            nxt = self.table[tok]
            return nxt, tok

        _, toks = jax.lax.scan(unroll, start, None, length=self.seq + 1)
        toks = jnp.swapaxes(toks, 0, 1)            # (B, S+1)
        tokens, targets = toks[:, :-1], toks[:, 1:]
        return {
            "tokens": tokens,
            "actions": targets,
            "advantages": jnp.ones(tokens.shape, jnp.float32),
            "returns": jnp.ones(tokens.shape, jnp.float32),
            "behavior_logprob": jnp.full(tokens.shape, -1.0, jnp.float32),
            "loss_mask": jnp.ones(tokens.shape, jnp.float32),
        }
