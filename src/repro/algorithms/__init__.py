"""Pluggable update algorithms (one copy of the math, every runtime).

    from repro import algorithms
    alg = algorithms.get_algorithm("a2c")
    loss, stats = alg.loss(policy_apply, params, traj, cfg)

Importing this package registers the built-ins: a2c, ppo, vtrace,
epsilon, trunc_is.
"""
from repro.algorithms.base import (  # noqa: F401
    Algorithm, algorithm_names, get_algorithm, register,
    advantages_and_returns, policy_on_traj)
from repro.algorithms import a2c, ppo, vtrace  # noqa: F401
