"""Stale-policy corrections (paper Eq. 5 + Sec. 2) as Algorithms.

The async baseline's learner differentiates the *current* params on data
produced by a behavior policy k updates behind. Each correction mode is
its own Algorithm (extracted from the former ``baselines._stale_loss``):

  * ``none``      — uncorrected A2C on stale data (GA3C w/o epsilon);
  * ``epsilon``   — GA3C's pi(a|s) + eps inside the log;
  * ``trunc_is``  — truncated importance sampling (Tab. A1 ablation);
  * ``vtrace``    — IMPALA's V-trace targets (core/vtrace.py).

``make_correction(acfg)`` builds an instance from an AsyncConfig-shaped
object; the default instances registered here use the paper's epsilon /
rho_max values.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algorithms import base
from repro.core import losses
from repro.core import vtrace as vtrace_mod


class StaleCorrected:
    """A2C on off-policy data with a configurable correction mode."""

    def __init__(self, correction: str = "vtrace", *, epsilon: float = 1e-3,
                 rho_max: float = 1.0, name: str | None = None):
        assert correction in ("none", "epsilon", "trunc_is", "vtrace"), \
            correction
        self.correction = correction
        self.epsilon = epsilon
        self.rho_max = rho_max
        self.name = name if name is not None else correction

    def loss(self, policy_apply, params, traj, cfg):
        logits, values, bv = base.policy_on_traj(policy_apply, params, traj)

        if self.correction == "vtrace":
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            tlp = jnp.take_along_axis(
                logp, traj["actions"][..., None], axis=-1)[..., 0]
            vt = vtrace_mod.vtrace(traj["behavior_logprob"],
                                   jax.lax.stop_gradient(tlp),
                                   traj["rewards"], traj["dones"],
                                   jax.lax.stop_gradient(values), bv,
                                   cfg.gamma, self.rho_max)
            ent = -(jnp.exp(logp) * logp).sum(-1)
            pg = -(tlp * vt.pg_advantages).mean()
            vl = jnp.square(values - vt.vs).mean()
            e = ent.mean()
            total = pg + cfg.value_coef * vl - cfg.entropy_coef * e
            return total, losses.LossStats(total, pg, vl, e)

        rets = losses.n_step_returns(traj["rewards"], traj["dones"], bv,
                                     cfg.gamma)
        adv = rets - jax.lax.stop_gradient(values)
        if self.correction == "trunc_is":
            st = losses.truncated_is_a2c_loss(
                logits, values, traj["actions"], adv, rets,
                traj["behavior_logprob"], self.rho_max,
                cfg.value_coef, cfg.entropy_coef)
            return st.total, st
        if self.correction == "epsilon":
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            p_a = jnp.exp(jnp.take_along_axis(
                logp, traj["actions"][..., None], axis=-1))[..., 0]
            lp = jnp.log(p_a + self.epsilon)
            ent = -(jnp.exp(logp) * logp).sum(-1)
            pg = -(lp * jax.lax.stop_gradient(adv)).mean()
            vl = jnp.square(values - rets).mean()
            e = ent.mean()
            total = pg + cfg.value_coef * vl - cfg.entropy_coef * e
            return total, losses.LossStats(total, pg, vl, e)
        st = losses.a2c_loss(logits, values, traj["actions"], adv, rets,
                             cfg.value_coef, cfg.entropy_coef)
        return st.total, st


def make_correction(acfg) -> StaleCorrected:
    """Instance from an AsyncConfig-shaped object (correction, epsilon,
    rho_max)."""
    return StaleCorrected(acfg.correction, epsilon=acfg.epsilon,
                          rho_max=acfg.rho_max)


base.register(StaleCorrected("vtrace"))
base.register(StaleCorrected("epsilon"))
base.register(StaleCorrected("trunc_is"))
