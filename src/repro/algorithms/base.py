"""The ``Algorithm`` protocol — the update math, decoupled from scheduling.

Every runtime (threaded host, fused mesh, sharded data-parallel, sync and
stale-async baselines) drives the same interface:

    loss(policy_apply, params, traj, cfg) -> (scalar, LossStats)

``traj`` is the interval trajectory pytree produced by
``core.rollout.rollout_interval`` — time-major ``(alpha, n_envs, ...)``
leaves plus ``bootstrap_obs`` — and ``cfg`` is any object exposing the
HTSConfig hyperparameter fields (gamma, value_coef, entropy_coef, use_gae,
gae_lambda, ppo_clip). Algorithms are pure and jit/pjit/shard_map-safe, so
a runtime is free to differentiate, vectorize, or all-reduce around them.

Instances register by name; ``get_algorithm("a2c" | "ppo" | "vtrace" |
...)`` is how runtimes and launchers resolve ``cfg.algorithm`` strings.
"""
from __future__ import annotations

from typing import Callable, Dict, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import losses


@runtime_checkable
class Algorithm(Protocol):
    name: str

    def loss(self, policy_apply: Callable, params, traj, cfg
             ) -> Tuple[jnp.ndarray, losses.LossStats]:
        """Scalar training loss (and stats) for one interval trajectory."""
        ...


_REGISTRY: Dict[str, Algorithm] = {}


def register(alg: Algorithm) -> Algorithm:
    _REGISTRY[alg.name] = alg
    return alg


def get_algorithm(name: str) -> Algorithm:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown algorithm {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def algorithm_names():
    return sorted(_REGISTRY)


# ------------------------------------------------------- shared pieces
def policy_on_traj(policy_apply, params, traj):
    """Forward the policy over an interval trajectory.

    traj leaves are (alpha, n_envs, ...); returns
    (logits (A, N, n_actions), values (A, N), bootstrap_value (N,)
    stop-gradiented).
    """
    A, N = traj["actions"].shape
    obs = traj["obs"]
    flat_obs = obs.reshape((A * N,) + obs.shape[2:])
    logits, values = policy_apply(params, flat_obs)
    logits = logits.reshape(A, N, -1)
    values = values.reshape(A, N)
    _, bv = policy_apply(params, traj["bootstrap_obs"])
    return logits, values, jax.lax.stop_gradient(bv)


def advantages_and_returns(values, bootstrap_value, traj, cfg):
    """(advantages, returns) per cfg.use_gae / cfg.gae_lambda / cfg.gamma."""
    if getattr(cfg, "use_gae", False):
        return losses.gae(traj["rewards"], traj["dones"],
                          jax.lax.stop_gradient(values), bootstrap_value,
                          cfg.gamma, cfg.gae_lambda)
    rets = losses.n_step_returns(traj["rewards"], traj["dones"],
                                 bootstrap_value, cfg.gamma)
    return rets - jax.lax.stop_gradient(values), rets
