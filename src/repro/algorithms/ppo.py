"""PPO-clip as a pluggable Algorithm (the paper's GFootball setting).

The clipping ratio is taken against the executor-recorded
``behavior_logprob``. Under HTS-RL's schedule the gradient is computed at
the behavior parameters themselves (one update behind the target), so the
ratio is exactly 1 and clipping is inactive at the differentiation point
— the clip matters for the stale-async baselines, where behavior lags by
k updates. One update per interval; see
``mesh_runtime.make_learner_update`` for why there are no PPO "epochs"
under the delayed-gradient schedule.
"""
from __future__ import annotations

from repro.algorithms import base
from repro.core import losses


class PPO:
    name = "ppo"

    def loss(self, policy_apply, params, traj, cfg):
        logits, values, bv = base.policy_on_traj(policy_apply, params, traj)
        adv, rets = base.advantages_and_returns(values, bv, traj, cfg)
        st = losses.ppo_loss(logits, values, traj["actions"], adv, rets,
                             traj["behavior_logprob"], cfg.ppo_clip,
                             cfg.value_coef, cfg.entropy_coef)
        return st.total, st


base.register(PPO())
