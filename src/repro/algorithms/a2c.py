"""A2C (paper Eq. 4) as a pluggable Algorithm.

Extracted from the former ``mesh_runtime._interval_loss`` so every runtime
shares one copy of the update math. n-step returns by default, GAE when
``cfg.use_gae``.
"""
from __future__ import annotations

from repro.algorithms import base
from repro.core import losses


class A2C:
    name = "a2c"

    def loss(self, policy_apply, params, traj, cfg):
        logits, values, bv = base.policy_on_traj(policy_apply, params, traj)
        adv, rets = base.advantages_and_returns(values, bv, traj, cfg)
        st = losses.a2c_loss(logits, values, traj["actions"], adv, rets,
                             cfg.value_coef, cfg.entropy_coef)
        return st.total, st


base.register(A2C())
