"""Logical-axis sharding rules -> PartitionSpecs, divisibility-aware.

Every parameter gets logical dimension names from its leaf name + rank;
logical names map to candidate mesh axes in priority order. A mesh axis is
assigned to a dim only if the dim size is divisible by the axis size and
the axis is not already used in that spec — so e.g. llama4's 40 query
heads (not divisible by model=16) automatically fall back to sharding
head_dim, and a batch of 1 (long_500k) falls back to replication.

Mesh layout (launch/mesh.py): single pod (data=16, model=16); multi-pod
(pod=2, data=16, model=16). ``pod`` composes with ``data`` for batch
sharding only — parameters/optimizer state are sharded over (data, model)
within a pod and replicated across pods, so only the gradient all-reduce
crosses the DCN.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# logical name -> candidate mesh-axis groups, in priority order.
MESH_MAP: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "batch": (("pod", "data"), ("data",)),
    "embed": (("data",),),          # FSDP: d_model param dim over data
    "dsq": (("model",),),           # second d_model dim of square weights
    "vocab": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (("model",),),
    "ffn": (("model",),),
    "experts": (("model",),),
    # KV-cache sequence dim: sharded over data axes when the batch dim
    # couldn't use them (long_500k B=1 would otherwise replicate a
    # multi-GB cache on every chip)
    "seq_data": (("pod", "data"), ("data",)),
    # residual-stream sequence dim: Megatron-style sequence parallelism
    # over the tensor axis — shards the per-block remat stash 16x, without
    # which the 80-layer train_4k residuals alone exceed HBM
    "seq_model": (("model",),),
    "frames": ((),),
    None: ((),),
}

# leaf name (+ rank, after removing a stacked leading dim) -> logical dims
PARAM_RULES: Dict[Tuple[str, int], Tuple[Optional[str], ...]] = {
    ("table", 2): ("vocab", "embed"),
    ("wq", 3): ("embed", "heads", "head_dim"),
    ("wk", 3): ("embed", "kv_heads", "head_dim"),
    ("wv", 3): ("embed", "kv_heads", "head_dim"),
    ("wo", 3): ("heads", "head_dim", "embed"),
    ("w_in", 2): ("embed", "ffn"),
    ("w_gate", 2): ("embed", "ffn"),
    ("w_out", 2): ("ffn", "embed"),
    ("w_in", 3): ("experts", "embed", "ffn"),       # MoE expert weights
    ("w_gate", 3): ("experts", "embed", "ffn"),
    ("w_out", 3): ("experts", "ffn", "embed"),
    ("router", 2): ("embed", "experts"),
    ("w_x_branch", 2): ("embed", "dsq"),
    ("w_gate_branch", 2): ("embed", "dsq"),
    ("w_a", 2): ("embed", "dsq"),
    ("w_i", 2): ("embed", "dsq"),
    ("w_r", 2): ("embed", "dsq"),
    ("w_k", 2): ("embed", "dsq"),
    ("w_v", 2): ("embed", "dsq"),
    ("w_g", 2): ("embed", "dsq"),
    ("w_o", 2): ("embed", "dsq"),
    ("w_lora_a", 2): ("embed", None),
    ("w_lora_b", 2): (None, "dsq"),
    ("conv_w", 2): (None, "embed"),
    ("lm_head", 2): ("embed", "vocab"),
    ("value_head", 2): ("embed", None),
    ("fc_w", 2): ("embed", "ffn"),
}


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve(logical: Tuple[Optional[str], ...], shape: Tuple[int, ...],
            mesh) -> P:
    """Greedy divisibility-aware assignment of mesh axes to dims."""
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    spec = []
    for dim, name in zip(shape, logical):
        assigned = None
        for cand in MESH_MAP.get(name, ((),)):
            cand = tuple(a for a in cand if a in sizes)
            if not cand:
                continue
            total = 1
            for a in cand:
                total *= sizes[a]
            if any(a in used for a in cand):
                continue
            if dim % total == 0 and dim >= total:
                assigned = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        spec.append(assigned)
    # trim trailing Nones for tidiness
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def _leaf_logical(name: str, ndim: int, stacked: bool):
    base_ndim = ndim - (1 if stacked else 0)
    rule = PARAM_RULES.get((name, base_ndim))
    if rule is None:
        # norms, biases, scalars, per-head vectors: replicate
        rule = (None,) * base_ndim
    return ((None,) + rule) if stacked else rule


def param_pspecs(abstract_params, mesh) -> Any:
    """PartitionSpec pytree matching an (abstract) param pytree.

    Params under a 'blocks' subtree are scan-stacked: their leading dim is
    the block index and stays unsharded.
    """

    def walk(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        stacked = "blocks" in names or (
            "encoder" in names and "layers" in names)
        name = names[-1] if names else ""
        logical = _leaf_logical(name, leaf.ndim, stacked)
        return resolve(logical, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(walk, abstract_params)


def opt_state_pspecs(opt_state_abstract, pspecs, mesh) -> Any:
    """Optimizer state mirrors params: any subtree whose structure matches
    the param tree gets the param specs; scalars are replicated."""
    flat_p, treedef_p = jax.tree_util.tree_flatten(pspecs)

    def match(sub):
        try:
            return jax.tree_util.tree_structure(sub) == treedef_p
        except Exception:
            return False

    def walk(sub):
        if isinstance(sub, dict):
            return {k: (jax.tree.map(lambda _, s: s, v, pspecs)
                        if match(v) else walk(v))
                    for k, v in sub.items()}
        if isinstance(sub, (tuple, list)):
            t = type(sub)
            return t(walk(v) for v in sub)
        return P()

    if match(opt_state_abstract):
        return pspecs
    return walk(opt_state_abstract)


def dg_state_pspecs(dg_abstract, pspecs, mesh):
    """Specs for DelayedGradState(params, params_prev, opt_state, step)."""
    from repro.core.delayed_grad import DelayedGradState
    return DelayedGradState(
        params=pspecs,
        params_prev=pspecs,
        opt_state=opt_state_pspecs(dg_abstract.opt_state, pspecs, mesh),
        step=P(),
    )


# ------------------------------------------------------------- activations
def batch_pspec(mesh, batch_size: int) -> Optional[Any]:
    """The mesh axes to shard a batch dim over (or None to replicate)."""
    sizes = _mesh_axis_sizes(mesh)
    for cand in MESH_MAP["batch"]:
        cand = tuple(a for a in cand if a in sizes)
        if not cand:
            continue
        total = 1
        for a in cand:
            total *= sizes[a]
        if batch_size % total == 0 and batch_size >= total:
            return cand if len(cand) > 1 else cand[0]
    return None


def batch_specs(batch_abstract, mesh) -> Any:
    """Input batch dict: dim 0 is batch (except mrope_positions (3,B,S))."""

    def walk(path, leaf):
        names = [str(getattr(k, "key", k)) for k in path]
        name = names[-1] if names else ""
        if name == "mrope_positions":
            b = batch_pspec(mesh, leaf.shape[1])
            return P(None, b, *([None] * (leaf.ndim - 2)))
        b = batch_pspec(mesh, leaf.shape[0]) if leaf.ndim else None
        return P(b, *([None] * (leaf.ndim - 1))) if leaf.ndim else P()

    return jax.tree_util.tree_map_with_path(walk, batch_abstract)


def _kv_cache_spec(shape, mesh, stacked) -> P:
    """shape = (B, S, KV, Dh). Assign axes by priority: batch -> data/pod;
    kv_heads -> model; head_dim -> model; seq -> any remaining axes."""
    sizes = _mesh_axis_sizes(mesh)
    B, S, KV, Dh = shape
    used: set = set()
    spec = [None, None, None, None]
    b = batch_pspec(mesh, B)
    if b is not None:
        spec[0] = b
        used.update(b if isinstance(b, tuple) else (b,))
    if "model" in sizes and "model" not in used:
        if KV % sizes["model"] == 0:
            # head-parallel decode attention: zero collectives
            spec[2] = "model"
            used.add("model")
        elif S % sizes["model"] == 0:
            # seq-sharded cache: decode attention pays only a small
            # softmax-stats reduction, vs head_dim sharding which
            # all-reduces the full (B,H,S) score tensor per layer
            spec[1] = "model"
            used.add("model")
        elif Dh % sizes["model"] == 0:
            spec[3] = "model"
            used.add("model")
    # sequence dim: any remaining axes whose product divides S
    if spec[1] is None:
        rem = [a for a in sizes if a not in used and S % sizes[a] == 0]
        if rem:
            spec[1] = tuple(rem) if len(rem) > 1 else rem[0]
    elif spec[1] == "model":
        rem = [a for a in sizes if a not in used and
               (S // sizes["model"]) % sizes[a] == 0]
        if rem:
            spec[1] = tuple(["model"] + rem)
    while spec and spec[-1] is None:
        spec.pop()
    out = P(*spec)
    return P(None, *out) if stacked else out


def cache_pspecs(cache_abstract, cfg, mesh) -> Any:
    """Decode caches: shard batch over data when divisible; shard the
    per-head dims over model (kv_heads first, head_dim fallback); RWKV/RGLRU
    recurrent states shard heads/channels over model."""

    def walk(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        stacked = "blocks" in names
        name = names[-1]
        shape = leaf.shape[1:] if stacked else leaf.shape
        if name in ("k", "v"):
            # priority resolution: batch first, then kv-head/model (cheap
            # compute layout), then the sequence dim soaks up whatever
            # axes are left — without this, archs whose kv_heads and
            # head_dim don't divide the model axis (h2o: kv=8, dh=120)
            # replicate a multi-GB cache on all 16 model chips.
            return _kv_cache_spec(shape, mesh, stacked)
        if name == "state":         # rwkv (B,H,N,N)
            logical = ("batch", "heads", None, None)
        elif name == "h":           # rglru (B,D)
            logical = ("batch", "dsq")
        elif name == "conv":        # (B,W-1,D)
            logical = ("batch", None, "dsq")
        elif name == "xprev":       # (B,1,D)
            logical = ("batch", None, "dsq")
        else:
            logical = ("batch",) + (None,) * (len(shape) - 1)
        spec = resolve(logical, shape, mesh)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(walk, cache_abstract)
