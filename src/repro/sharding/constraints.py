"""Logical activation-sharding constraints (MaxText-style).

GSPMD propagates shardings from params/inputs, but through long remat'd
scan chains it can settle on a batch-replicated layout for activations —
catastrophic at train_4k scale. ``constrain(x, *logical)`` pins the layout
at key points (residual stream, attention tiles, MoE dispatch) using the
same divisibility-aware resolution as the param rules.

No-op when no mesh is active (host RL runtimes, smoke tests on 1 device).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.sharding import rules


def _active_mesh():
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if am is None or not getattr(am, "axis_names", ()):
        return None
    return am


def _axis_sizes(am):
    return {a: am.shape[a] for a in am.axis_names}


def constrain(x, *logical):
    """Apply with_sharding_constraint(resolve(logical)) if a mesh is set."""
    am = _active_mesh()
    if am is None:
        return x
    sizes = _axis_sizes(am)
    used = set()
    spec = []
    for dim, name in zip(x.shape, logical):
        assigned = None
        for cand in rules.MESH_MAP.get(name, ((),)):
            cand = tuple(a for a in cand if a in sizes)
            if not cand or any(a in used for a in cand):
                continue
            total = 1
            for a in cand:
                total *= sizes[a]
            if total > 1 and dim % total == 0 and dim >= total:
                assigned = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        spec.append(assigned)
    if not any(s is not None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
