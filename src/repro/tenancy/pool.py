"""TenantPool: many concurrent ExperimentSpecs time-sliced over one
device pool, with every tenant's results bit-exact to its solo run.

HTS-RL's determinism contract makes preemption free: a runtime's
``state()`` capsule at an interval boundary IS a checkpoint, and
``run(n)`` equals any partition into ``run_from`` segments bit-exactly
(core/engine.py, tests/test_continuation.py). The pool multiplexes N
independent tenants over that contract — suspend ≡ capsule capture,
resume ≡ ``run_from`` — so multiplexing is *invisible* to each tenant:
final params AND episode-return streams equal the solo run's, at any
weights, any quanta, any interleaving, including across mid-pool
eviction/re-admission and one tenant's injected fault storm
(tests/test_tenancy.py; DESIGN.md §13).

Scheduling is **stride fair-share** over exact rationals: tenant i
carries a pass value p_i; each grant of ``q`` intervals charges
``q / weight_i`` to p_i, and the next grant goes to the runnable
tenant with the smallest ``(p_i, admission index)``. Over any long
window an active tenant therefore receives device intervals in
proportion to its weight (Jain index ~1.0 in benchmarks/tenancy_bench),
and the schedule is a pure function of (admission order, weights,
quanta, interval counts, and the caller's lifecycle-op sequence) — no
wall-clock input anywhere, so it replays bit-exactly.

Execution may OVERLAP adjacent grants of *different* tenants
(``max_concurrency`` slices in flight; a tenant's own slices are always
serialized on its capsule chain). Tenants are independent sessions —
separate runtimes, separate buffers, separate PRNG streams — so overlap
changes wall-clock time only, never results: the aggregate-throughput
win (a sleep-bound host tenant hides behind a compute-bound mesh
tenant) costs nothing in determinism. ``max_concurrency=1`` degrades
to strictly sequential time-slicing with identical results.

Fault domains are per-tenant: each session carries its own
``FaultInjector`` (repro.api.build), and the pool supervises each
tenant separately — a failed slice is replayed from that tenant's
slice-boundary capsule (run_from copies on restore, so the capsule
survives the crashed attempt untouched) with the tenant's own
backoff/max_restarts policy. Other tenants never see it: their capsule
chains, schedules, and streams are untouched by construction.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import evaluate
from repro.core.engine import TrainState
from repro.tenancy.config import TenancyConfig

ACTIVE, PAUSED, EVICTED, DONE = "active", "paused", "evicted", "done"


def capsule_params(state: TrainState, params_template):
    """The policy parameters inside a live capsule: the capsule's
    leading leaves in flatten order (the same prefix contract as
    ``checkpoint.io.restore_prefix``, applied in memory), shape-checked
    against the template loudly."""
    leaves = jax.tree_util.tree_leaves(state)
    tdef = jax.tree_util.tree_structure(params_template)
    tleaves = jax.tree_util.tree_leaves(params_template)
    if len(leaves) < len(tleaves):
        raise ValueError(
            f"capsule has {len(leaves)} leaves, params need "
            f"{len(tleaves)}")
    for i, (have, want) in enumerate(zip(leaves, tleaves)):
        if tuple(have.shape) != tuple(want.shape):
            raise ValueError(
                f"capsule leaf {i} shape {tuple(have.shape)} != params "
                f"leaf shape {tuple(want.shape)}")
    return jax.tree_util.tree_unflatten(tdef, leaves[:len(tleaves)])


@dataclass
class TenantResult:
    """One tenant's view of a pool run — the same reporting surface a
    solo ``Session.run`` + ``core.trainer.TrainReport`` would give."""
    name: str
    params: Any                  # final (reporting) params; None until done
    state: Optional[TrainState]  # mid-stream capsule at the last boundary
    intervals: int               # completed intervals
    target: int                  # the spec's interval budget
    steps: int
    wall_time: float             # device-occupancy: sum of slice walls
    sps: float
    rewards: np.ndarray          # (intervals, alpha, n_envs)
    dones: np.ndarray
    episode_returns: np.ndarray
    restarts: int
    status: str


class _Tenant:
    """Pool-internal per-tenant record: session + capsule chain +
    scheduler and reporting state."""

    def __init__(self, name: str, session, weight: int, quantum: int,
                 index: int):
        self.name = name
        self.session = session
        self.weight = int(weight)
        self.quantum = int(quantum)
        self.index = index              # admission order (tie-break)
        self.status = ACTIVE
        self.passv = Fraction(0)        # stride pass value
        self.target = int(session.spec.intervals)
        self.granted = 0                # intervals granted (schedule side)
        self.done = 0                   # intervals completed (result side)
        self.state: TrainState = session.state()   # slice-boundary capsule
        self.stream = evaluate.ReturnStream(session.cfg.n_envs)
        self.rewards: List[np.ndarray] = []
        self.dones: List[np.ndarray] = []
        self.steps = 0
        self.wall = 0.0
        self.params = None              # final reporting params
        self.consec = 0                 # consecutive failed slices
        self.restarts = 0
        self.last_saved = 0             # intervals at last checkpoint

    # ----------------------------------------------------------- result
    def result(self) -> TenantResult:
        cfg = self.session.cfg
        empty = np.zeros((0, cfg.alpha, cfg.n_envs), np.float32)
        return TenantResult(
            name=self.name,
            params=self.params,
            state=self.state,
            intervals=self.done,
            target=self.target,
            steps=self.steps,
            wall_time=self.wall,
            sps=self.steps / max(self.wall, 1e-9),
            rewards=(np.concatenate(self.rewards) if self.rewards
                     else empty),
            dones=np.concatenate(self.dones) if self.dones else empty,
            episode_returns=self.stream.returns,
            restarts=self.restarts,
            status=self.status,
        )


class TenantPool:
    """Admit N independent experiment specs into one device pool and
    time-slice between them at interval granularity.

        pool = Session.pool([spec_a, spec_b])        # or TenantPool(...)
        results = pool.run()                         # join on completion
        results["t0"].params                         # == solo run's, bit-exact

    * ``specs`` — ExperimentSpecs, spec dicts, or already-built
      Sessions. Each is admitted in order; per-tenant ``weight``/
      ``quantum``/``name`` come from the spec's ``tenancy`` block
      (overridable with the ``weights``/``names`` arguments, aligned by
      position — the CLI's ``--weight`` flags).
    * ``max_concurrency`` — how many slices may execute concurrently
      (different tenants only; 1 = strictly sequential). Results are
      bit-identical for every value — overlap is a wall-clock-only
      optimization.
    * ``on_slice`` — reporting callback ``(name, intervals_done,
      RunResult)`` after each slice commits, in grant order — the
      deterministic hook tests use to drive mid-run ``pause``/
      ``evict``/``readmit``.

    Lifecycle: ``admit`` (mid-run too), ``pause``/``resume``,
    ``evict``/``readmit`` — all take effect at slice boundaries (the
    only places a tenant's capsule exists). ``run`` drives the schedule
    until no tenant is runnable and returns ``{name: TenantResult}``
    for every tenant ever admitted (paused/evicted ones report their
    partial streams and ``status``).
    """

    def __init__(self, specs=(), weights=None, names=None,
                 max_concurrency: int = 2,
                 on_slice: Optional[Callable[[str, int, Any], None]] = None,
                 **build_overrides):
        if max_concurrency < 1:
            raise ValueError(
                f"max_concurrency must be >= 1, got {max_concurrency}")
        self.max_concurrency = int(max_concurrency)
        self.on_slice = on_slice
        self._build_overrides = build_overrides
        self._tenants: Dict[str, _Tenant] = {}
        self._order: List[str] = []     # admission order
        self.trace: List[Tuple[str, int, int]] = []  # (name, start, n)
        self._pending: deque = deque()  # (tenant, n, final, future)
        self._ex: Optional[ThreadPoolExecutor] = None
        specs = list(specs)
        weights = list(weights) if weights is not None else [None] * len(specs)
        names = list(names) if names is not None else [None] * len(specs)
        if len(weights) != len(specs) or len(names) != len(specs):
            raise ValueError(
                f"weights/names must align with specs: got {len(specs)} "
                f"spec(s), {len(weights)} weight(s), {len(names)} name(s)")
        for spec, w, nm in zip(specs, weights, names):
            self.admit(spec, weight=w, name=nm)

    # -------------------------------------------------------- admission
    def admit(self, spec, weight: Optional[int] = None,
              name: Optional[str] = None) -> str:
        """Admit one tenant (a spec, spec dict, or built Session).
        Returns the tenant name. New tenants start at the minimum
        active pass value, so a late arrival shares fairly from its
        admission onward instead of replaying the pool's history."""
        from repro import api
        if isinstance(spec, api.Session):
            session = spec
        else:
            if isinstance(spec, dict):
                spec = api.from_dict(spec)
            session = api.build(spec, **self._build_overrides)
        ten = session.spec.tenancy
        name = name or ten.name or f"t{len(self._order)}"
        if name in self._tenants:
            raise ValueError(
                f"tenant name {name!r} already admitted; names must be "
                f"unique (set tenancy.name per spec)")
        t = _Tenant(name, session, weight or ten.weight, ten.quantum,
                    index=len(self._order))
        t.passv = self._min_active_pass()
        self._tenants[name] = t
        self._order.append(name)
        return name

    def _get(self, name: str) -> _Tenant:
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(f"no tenant {name!r}; admitted: "
                           f"{self._order}") from None

    def _min_active_pass(self) -> Fraction:
        active = [t.passv for t in self._tenants.values()
                  if t.status == ACTIVE and t.granted < t.target]
        return min(active) if active else Fraction(0)

    # -------------------------------------------------------- lifecycle
    def pause(self, name: str) -> None:
        """Stop granting slices to a tenant (takes effect at the next
        grant decision; an in-flight slice still commits)."""
        t = self._get(name)
        if t.status not in (ACTIVE,):
            raise ValueError(f"cannot pause tenant {name!r} in status "
                             f"{t.status!r}")
        t.status = PAUSED

    def resume(self, name: str) -> None:
        """Resume a paused tenant. Its pass value is advanced to the
        current minimum active pass, so it resumes sharing from NOW
        rather than bursting to repay its paused time."""
        t = self._get(name)
        if t.status != PAUSED:
            raise ValueError(f"cannot resume tenant {name!r} in status "
                             f"{t.status!r}")
        t.status = ACTIVE
        t.passv = max(t.passv, self._min_active_pass())

    def evict(self, name: str) -> TenantResult:
        """Remove a tenant from scheduling and return its partial
        result. The capsule chain is retained: ``readmit`` continues it
        bit-exactly (preemption ≡ checkpoint round-trip, so evict +
        readmit is invisible to the tenant's final results)."""
        t = self._get(name)
        if t.status == DONE:
            raise ValueError(f"tenant {name!r} already completed")
        t.status = EVICTED
        return t.result()

    def readmit(self, name: str) -> None:
        """Re-admit an evicted tenant; it continues from its capsule."""
        t = self._get(name)
        if t.status != EVICTED:
            raise ValueError(f"cannot readmit tenant {name!r} in status "
                             f"{t.status!r}")
        t.status = ACTIVE
        t.passv = max(t.passv, self._min_active_pass())

    # -------------------------------------------------------- scheduler
    def _next(self) -> Optional[_Tenant]:
        """The stride decision: runnable tenant with the smallest
        (pass, admission index). Pure function of scheduler state."""
        best = None
        for name in self._order:
            t = self._tenants[name]
            if t.status != ACTIVE or t.granted >= t.target:
                continue
            if best is None or (t.passv, t.index) < (best.passv, best.index):
                best = t
        return best

    def _grant(self, t: _Tenant) -> Tuple[int, bool]:
        """Charge one grant to the tenant's pass and advance its
        schedule-side interval count."""
        n = min(t.quantum, t.target - t.granted)
        start = t.granted
        t.granted += n
        t.passv += Fraction(n, t.weight)
        self.trace.append((t.name, start, n))
        return n, t.granted >= t.target

    # -------------------------------------------------------- execution
    def _exec_slice(self, t: _Tenant, n: int, final: bool):
        """Run one slice (worker thread; per-tenant serialized). The
        tenant's own fault policy supervises: a failed attempt is
        replayed from the slice-boundary capsule — which survives the
        crash untouched, because run_from copies on restore — after the
        tenant's backoff. Injected events fire at most once, so the
        replay proceeds cleanly (repro.faults)."""
        plan = t.session.spec.faults
        while True:
            try:
                t0 = time.perf_counter()
                out = t.session.run_from(t.state, n, finalize=final)
                state = t.session.state()
                t.consec = 0
                return out, state, time.perf_counter() - t0
            except Exception as e:
                if plan.max_restarts <= 0 or t.consec >= plan.max_restarts:
                    raise
                t.consec += 1
                t.restarts += 1
                delay = min(plan.backoff * (2 ** (t.consec - 1)),
                            plan.backoff_cap)
                print(f"[pool] tenant {t.name!r} slice at interval "
                      f"{t.done} failed ({type(e).__name__}: {e}); "
                      f"replay {t.consec}/{plan.max_restarts} after "
                      f"{delay:.3f}s backoff", flush=True)
                time.sleep(delay)

    def _commit(self) -> None:
        """Apply the oldest in-flight slice, in grant order (so
        ``on_slice`` ordering is deterministic)."""
        t, n, final, fut = self._pending.popleft()
        out, state, wall = fut.result()   # re-raises exhausted failures
        t.state = state
        t.done += n
        t.wall += wall
        t.steps += out.steps
        if out.rewards.size:
            t.rewards.append(out.rewards)
            t.dones.append(out.dones)
            t.stream.extend(out.rewards, out.dones)
        if final:
            t.params = out.params
            t.status = DONE
        self._maybe_checkpoint(t, final)
        if self.on_slice is not None:
            self.on_slice(t.name, t.done, out)

    def _wait_tenant(self, t: _Tenant) -> None:
        """Serialize a tenant's capsule chain: commit pending slices (in
        grant order) until this tenant has none in flight."""
        while any(p[0] is t for p in self._pending):
            self._commit()

    def _maybe_checkpoint(self, t: _Tenant, final: bool) -> None:
        """Per-tenant periodic checkpointing, riding the trainer's
        capsule format (core/trainer.py): a pool tenant's checkpoints
        are indistinguishable from a solo Trainer's, so the same
        ``--resume`` / ``Session.serve`` machinery consumes them."""
        ck = t.session.spec.checkpoint
        if not ck.dir:
            return
        due = ck.every and (t.done - t.last_saved) >= ck.every
        if not (due or (final and t.done > t.last_saved)):
            return
        from repro.core import trainer as trainer_mod
        ckpt_io = trainer_mod.ckpt_io
        meta = trainer_mod.checkpoint_metadata(
            t.session.runtime, t.done, t.stream)
        import os
        ckpt_io.save(os.path.join(ck.dir, f"step_{t.done:08d}"),
                     t.state, metadata=meta)
        trainer_mod.prune_checkpoints(ck.dir, ck.keep)
        t.last_saved = t.done

    # -------------------------------------------------------------- run
    def step(self) -> bool:
        """Issue and commit ONE schedule grant synchronously. Returns
        False when no tenant is runnable. The unit tests' microscope;
        ``run`` is the production loop."""
        t = self._next()
        if t is None:
            return False
        n, final = self._grant(t)
        out, state, wall = self._exec_slice(t, n, final)
        from concurrent.futures import Future
        fut: Future = Future()
        fut.set_result((out, state, wall))
        self._pending.append((t, n, final, fut))
        self._commit()
        return True

    def run(self) -> Dict[str, TenantResult]:
        """Drive the schedule until no tenant is runnable (every active
        tenant reached its interval target); join and return every
        tenant's result. Grants are issued in deterministic stride
        order; execution overlaps up to ``max_concurrency`` slices of
        distinct tenants."""
        if self.max_concurrency == 1:
            while self.step():
                pass
            return self.results()
        ex = ThreadPoolExecutor(max_workers=self.max_concurrency,
                                thread_name_prefix="tenant-slice")
        try:
            while True:
                t = self._next()
                if t is None:
                    # a pending commit may finish a tenant or a
                    # lifecycle callback may readmit one — drain one
                    # commit and re-check before declaring completion
                    if self._pending:
                        self._commit()
                        continue
                    break
                # serialize this tenant's capsule chain, then respect
                # the in-flight bound (committing oldest-first)
                self._wait_tenant(t)
                while len(self._pending) >= self.max_concurrency:
                    self._commit()
                if t.status != ACTIVE or t.granted >= t.target:
                    continue    # a commit's callback changed its state
                n, final = self._grant(t)
                fut = ex.submit(self._exec_slice, t, n, final)
                self._pending.append((t, n, final, fut))
            return self.results()
        finally:
            ex.shutdown(wait=True)

    def results(self) -> Dict[str, TenantResult]:
        while self._pending:
            self._commit()
        return {name: self._tenants[name].result()
                for name in self._order}

    # ------------------------------------------------------------ serve
    def serve(self, serve=None, start: bool = True):
        """Multi-model serving over the pool: one ``PolicyServer``
        answering requests for EVERY tenant's policy, routed by model
        id (= tenant name) into per-model padding groups batched in one
        dispatcher loop (repro.serve.server). Each model keeps its own
        seed master (the tenant's ``hts.seed``), so every (model, obs,
        seed) request answers bit-identically to that tenant's
        single-model server regardless of cross-model batch
        composition (tests/test_tenancy.py).

        Parameters are each tenant's CURRENT capsule params (mid-pool
        serving serves what has been trained so far; a finished tenant
        serves its final params). ``serve`` overrides the admission/
        dispatch config (default: the first tenant's serve block)."""
        from repro.serve import PolicyServer
        if not self._order:
            raise ValueError("cannot serve an empty pool")
        first = self._tenants[self._order[0]]
        srv_cfg = serve if serve is not None else first.session.spec.serve
        server = None
        for name in self._order:
            t = self._tenants[name]
            s = t.session
            _, obs0 = s.env.reset(jax.random.key(0))
            # a finished tenant serves its FINAL reporting params (the
            # trailing finalize pass is in t.params but not the capsule,
            # whose job is exact continuation); mid-stream tenants serve
            # the capsule at the last slice boundary
            if t.status == DONE and t.params is not None:
                params = t.params
            else:
                params = capsule_params(t.state, s.params)
            if server is None:
                server = PolicyServer(
                    s.policy.apply, params, obs_like=np.asarray(obs0),
                    serve=srv_cfg, seed=s.cfg.seed, model=name)
            else:
                server.add_model(
                    name, s.policy.apply, params,
                    obs_like=np.asarray(obs0),
                    max_batch=s.spec.serve.max_batch, seed=s.cfg.seed)
        return server.start() if start else server

    # ------------------------------------------------------------- misc
    def tenants(self) -> List[str]:
        return list(self._order)

    def status(self, name: str) -> str:
        return self._get(name).status

    def schedule_counts(self) -> Dict[str, int]:
        """Granted intervals per tenant — what fairness assertions and
        the Jain index in benchmarks/tenancy_bench.py consume."""
        counts: Dict[str, int] = {name: 0 for name in self._order}
        for name, _start, n in self.trace:
            counts[name] += n
        return counts
