"""TenancyConfig: the ``tenancy`` block of an ExperimentSpec.

One spec = one *tenant* when admitted into a ``TenantPool``
(repro.tenancy.pool): this block carries everything the fair-share
scheduler needs to know about the spec — and nothing about the device
pool itself, which is a property of the pool, not of any one tenant.

  * ``weight``  — fair-share weight: over any long window of the
    schedule, an active tenant receives device intervals in proportion
    to its weight (stride scheduling; DESIGN.md §13). Weight changes
    WHEN a tenant's intervals run, never what they compute — a
    tenant's results are bit-exact to its solo run at any weight.
  * ``quantum`` — intervals per schedule grant: how many intervals the
    tenant runs each time it is picked before the pool preempts it at
    the next slice boundary (capsule capture). Larger quanta amortize
    per-slice dispatch overhead at the cost of coarser interleaving;
    the schedule charges a grant's full ``quantum/weight`` to the
    tenant's pass, so fairness is preserved for any mix of quanta.
  * ``name``    — optional stable tenant id (reports, the serving
    model id, eviction handles). Defaults to ``t<admission index>``
    at admission.

Validated eagerly at construction like every other spec block; popped
from ``workload_fingerprint`` (scheduling share changes wall-clock
interleaving, never what a training number means).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

_FIELDS = ("weight", "quantum", "name")


@dataclass(frozen=True)
class TenancyConfig:
    weight: int = 1
    quantum: int = 1
    name: Optional[str] = None

    def __post_init__(self):
        if int(self.weight) != self.weight or self.weight < 1:
            raise ValueError(
                f"tenancy.weight must be an integer >= 1, got "
                f"{self.weight!r}")
        if int(self.quantum) != self.quantum or self.quantum < 1:
            raise ValueError(
                f"tenancy.quantum must be an integer >= 1, got "
                f"{self.quantum!r}")
        if self.name is not None and (not isinstance(self.name, str)
                                      or not self.name):
            raise ValueError(
                f"tenancy.name must be a non-empty string (or null), "
                f"got {self.name!r}")

    @property
    def is_default(self) -> bool:
        return self == TenancyConfig()

    def canonical(self) -> dict:
        return {"weight": int(self.weight), "quantum": int(self.quantum),
                "name": self.name}

    @staticmethod
    def of(value) -> "TenancyConfig":
        if isinstance(value, TenancyConfig):
            return value
        if value is None:
            return TenancyConfig()
        if isinstance(value, dict):
            unknown = set(value) - set(_FIELDS)
            if unknown:
                raise ValueError(
                    f"unknown tenancy field(s) {sorted(unknown)}; "
                    f"known: {list(_FIELDS)}")
            return TenancyConfig(**value)
        raise TypeError(f"tenancy must be a dict or TenancyConfig, got "
                        f"{type(value).__name__}")
