"""Multi-tenant scheduling: many ExperimentSpecs sharing one device
pool, each bit-exact to its solo run.

  * ``TenancyConfig`` — the per-spec ``tenancy`` block (weight, quantum,
    name) consumed by the scheduler.
  * ``TenantPool``    — admission, deterministic stride fair-share over
    interval-boundary capsules, lifecycle (pause/resume/evict/readmit),
    per-tenant fault domains, multi-model serving.
  * ``TenantResult``  — one tenant's report (params, streams, sps).

Entry points: ``repro.api.Session.pool([...])`` and
``python -m repro.launch.pool --spec a.json --spec b.json``.
Contract: DESIGN.md §13.
"""
from repro.tenancy.config import TenancyConfig
from repro.tenancy.pool import TenantPool, TenantResult, capsule_params

__all__ = ["TenancyConfig", "TenantPool", "TenantResult",
           "capsule_params"]
