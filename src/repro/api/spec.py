"""ExperimentSpec: the declarative description of one experiment.

One typed, JSON-round-trippable value names everything a run needs —
environment x policy x optimizer x algorithm x runtime x HTSConfig
knobs x checkpoint policy — each axis resolved through its registry
(repro.envs / repro.models / repro.optim / repro.algorithms /
repro.core.engine) at ``repro.api.build`` time:

    spec = ExperimentSpec(env="catch", policy="mlp", runtime="mesh",
                          hts={"alpha": 8, "n_envs": 16})
    session = api.build(spec)
    out = session.run(400)

``dumps``/``loads`` round-trip the spec through its *canonical* JSON
form (every field explicit, keys sorted): ``build(loads(dumps(spec)))``
constructs bit-identically to ``build(spec)`` (tests/test_api.py).
That canonical form is also the benchmark suite's workload fingerprint
(``workload_fingerprint``): two SPS records are comparable exactly when
their spec JSONs match (benchmarks/check_sps.py prints the field-level
diff when they don't).

Validation is eager and loud: unknown field names, ``staleness < 1``,
``alpha < 1`` and friends raise at construction/``loads`` time with the
offending field named — never a silent default. Registry-name existence
(is there an env called "catch"?) is checked at ``build`` time, where
the registries are consulted anyway.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Union

from repro.core.batch import BatchConfig
from repro.core.engine import HTSConfig
from repro.faults import FaultPlan
from repro.serve.config import ServeConfig
from repro.tenancy.config import TenancyConfig

# HTSConfig knobs a spec may set. ``algorithm`` is excluded: it is a
# first-class spec axis (``ExperimentSpec.algorithm``), and allowing it
# in both places would invite the two disagreeing silently.
_HTS_FIELDS = tuple(f for f in HTSConfig._fields if f != "algorithm")


def _jsonable(value, where: str):
    """Reject values that would not survive a JSON round-trip (function
    objects, device arrays, Mesh handles...) with the field named."""
    try:
        return json.loads(json.dumps(value))
    except (TypeError, ValueError):
        raise TypeError(
            f"{where} is not JSON-serializable: {value!r}; pass live "
            f"objects (meshes, callables) as build(spec, ...) overrides "
            f"instead of putting them in the spec") from None


@dataclass(frozen=True)
class ComponentSpec:
    """A registry name plus construction kwargs."""
    name: str
    kwargs: Dict[str, Any] = field(default_factory=dict)

    def canonical(self) -> dict:
        return {"name": self.name,
                "kwargs": _jsonable(dict(self.kwargs), self.name)}

    @staticmethod
    def of(value: Union[str, dict, "ComponentSpec"],
           where: str) -> "ComponentSpec":
        if isinstance(value, ComponentSpec):
            return value
        if isinstance(value, str):
            return ComponentSpec(value)
        if isinstance(value, dict):
            unknown = set(value) - {"name", "kwargs"}
            if unknown:
                raise ValueError(
                    f"unknown {where} field(s) {sorted(unknown)}; a "
                    f"component is {{'name': ..., 'kwargs': {{...}}}}")
            if "name" not in value:
                raise ValueError(f"{where} needs a 'name'")
            return ComponentSpec(value["name"],
                                 dict(value.get("kwargs", {})))
        raise TypeError(f"{where} must be a name, dict, or "
                        f"ComponentSpec, got {type(value).__name__}")


@dataclass(frozen=True)
class CheckpointSpec:
    """Checkpoint/eval policy for ``Session.fit`` (core/trainer.py)."""
    dir: Optional[str] = None
    every: int = 0               # intervals per segment (0: one segment)
    keep: int = 3                # most-recent checkpoints retained

    def canonical(self) -> dict:
        return {"dir": self.dir, "every": int(self.every),
                "keep": int(self.keep)}

    @staticmethod
    def of(value) -> "CheckpointSpec":
        if isinstance(value, CheckpointSpec):
            return value
        if value is None:
            return CheckpointSpec()
        if isinstance(value, dict):
            unknown = set(value) - {"dir", "every", "keep"}
            if unknown:
                raise ValueError(
                    f"unknown checkpoint field(s) {sorted(unknown)}; "
                    f"known: ['dir', 'every', 'keep']")
            return CheckpointSpec(**value)
        raise TypeError(f"checkpoint must be a dict or CheckpointSpec, "
                        f"got {type(value).__name__}")


@dataclass(frozen=True)
class ExperimentSpec:
    env: ComponentSpec = field(default_factory=lambda: ComponentSpec("catch"))
    policy: ComponentSpec = field(default_factory=lambda: ComponentSpec("mlp"))
    optimizer: ComponentSpec = field(
        default_factory=lambda: ComponentSpec("rmsprop", {"lr": 7e-4}))
    algorithm: str = "a2c"
    runtime: ComponentSpec = field(default_factory=lambda: ComponentSpec("mesh"))
    hts: Dict[str, Any] = field(default_factory=dict)  # HTSConfig knobs
    params_seed: int = 0         # PRNG key for policy.init
    intervals: int = 100         # default run length (Session.run())
    checkpoint: CheckpointSpec = field(default_factory=CheckpointSpec)
    # serving policy for Session.serve() (repro.serve): dispatch width,
    # admission bound, dispatcher wait. Validated eagerly by ServeConfig
    # itself; popped from workload_fingerprint (it changes serving
    # latency, never what a training number means).
    serve: ServeConfig = field(default_factory=ServeConfig)
    # chaos schedule + recovery policy (repro.faults, DESIGN.md §11):
    # one seeded FaultPlan spans training (host pool sites, trainer
    # checkpoint site) and serving (dispatcher site) — Session.build
    # arms ONE shared FaultInjector from it. Popped from
    # workload_fingerprint: by the recovery guarantee, faults change
    # wall time, never what a result means.
    faults: FaultPlan = field(default_factory=FaultPlan)
    # batch geometry (repro.core.batch, DESIGN.md §12):
    # global_batch (= hts.n_envs) factorized as
    # micro_batch x grad_accumulation x n_replicas. Validated eagerly
    # against hts.n_envs here; threaded into the runtime by
    # Session.build. Default (all None/1) reproduces the legacy
    # runtime-determined geometry exactly — and is popped from
    # workload_fingerprint so committed baselines stay comparable.
    batch: BatchConfig = field(default_factory=BatchConfig)
    # multi-tenant scheduling block (repro.tenancy, DESIGN.md §13):
    # fair-share weight, grant quantum, and tenant name consumed when
    # this spec is admitted into a TenantPool. Popped from
    # workload_fingerprint always — by the multiplexing-determinism
    # contract, scheduling share changes WHEN intervals run, never what
    # they compute.
    tenancy: TenancyConfig = field(default_factory=TenancyConfig)

    def __post_init__(self):
        object.__setattr__(self, "env", ComponentSpec.of(self.env, "env"))
        object.__setattr__(self, "policy",
                           ComponentSpec.of(self.policy, "policy"))
        object.__setattr__(self, "optimizer",
                           ComponentSpec.of(self.optimizer, "optimizer"))
        object.__setattr__(self, "runtime",
                           ComponentSpec.of(self.runtime, "runtime"))
        object.__setattr__(self, "hts", dict(self.hts))
        object.__setattr__(self, "checkpoint",
                           CheckpointSpec.of(self.checkpoint))
        object.__setattr__(self, "serve", ServeConfig.of(self.serve))
        object.__setattr__(self, "faults", FaultPlan.of(self.faults))
        object.__setattr__(self, "batch", BatchConfig.of(self.batch))
        object.__setattr__(self, "tenancy", TenancyConfig.of(self.tenancy))
        self._validate()

    def _validate(self) -> None:
        unknown = set(self.hts) - set(_HTS_FIELDS)
        if unknown:
            hint = (" (set spec.algorithm, not hts['algorithm'])"
                    if "algorithm" in unknown else "")
            raise ValueError(
                f"unknown HTSConfig knob(s) {sorted(unknown)}{hint}; "
                f"known: {sorted(_HTS_FIELDS)}")
        cfg = self.hts_config()
        if cfg.alpha < 1:
            raise ValueError(f"alpha must be >= 1, got {cfg.alpha}")
        if cfg.n_envs < 1:
            raise ValueError(f"n_envs must be >= 1, got {cfg.n_envs}")
        if cfg.staleness < 1:
            raise ValueError(
                f"staleness must be >= 1, got {cfg.staleness}")
        if cfg.env_backend not in ("host", "device"):
            raise ValueError(
                f"unknown env_backend {cfg.env_backend!r}; choose 'host' "
                f"(vmapped scalar envs) or 'device' (device-resident "
                f"batched port)")
        if cfg.env_backend == "device":
            # spec-time, not trace-time: an env without a device port
            # (football, token — their step logic is host-side) must
            # fail here with the supported pairs spelled out, not deep
            # inside runtime construction or jit tracing
            from repro.envs.device import (device_port_names,
                                           has_device_port)
            if not has_device_port(self.env.name):
                raise ValueError(
                    f"env {self.env.name!r} has no device-resident port, "
                    f"so hts['env_backend']='device' is unsupported for "
                    f"it; envs with device ports: "
                    f"{sorted(device_port_names())}. Use the default "
                    f"env_backend='host' for {self.env.name!r}.")
        # geometry checks need the global batch (n_envs): divisibility
        # and the power-of-two alignment of the bit-exactness contract,
        # rejected spec-side with the offending batch.<field> named and
        # the nearest valid factorization suggested (repro.core.batch)
        self.batch.resolve(cfg.n_envs)
        if self.intervals < 0:
            raise ValueError(
                f"intervals must be >= 0, got {self.intervals}")
        if self.checkpoint.every < 0 or self.checkpoint.keep < 0:
            raise ValueError(
                f"checkpoint.every/keep must be >= 0, got "
                f"{self.checkpoint.every}/{self.checkpoint.keep}")

    # ------------------------------------------------------ serialization
    def hts_config(self) -> HTSConfig:
        return HTSConfig(algorithm=self.algorithm, **self.hts)

    def canonical(self) -> dict:
        """The fully-explicit JSON form: every field present (including
        defaults), component kwargs verified JSON-round-trippable. Equal
        specs have equal canonical dicts and equal ``dumps`` strings."""
        return {
            "env": self.env.canonical(),
            "policy": self.policy.canonical(),
            "optimizer": self.optimizer.canonical(),
            "algorithm": self.algorithm,
            "runtime": self.runtime.canonical(),
            "hts": _jsonable(dict(self.hts), "hts"),
            "params_seed": int(self.params_seed),
            "intervals": int(self.intervals),
            "checkpoint": self.checkpoint.canonical(),
            "serve": self.serve.canonical(),
            "faults": self.faults.canonical(),
            "batch": self.batch.canonical(),
            "tenancy": self.tenancy.canonical(),
        }

    def replace(self, **changes) -> "ExperimentSpec":
        return dataclasses.replace(self, **changes)


_SPEC_FIELDS = tuple(f.name for f in dataclasses.fields(ExperimentSpec))


def from_dict(d: dict) -> ExperimentSpec:
    if not isinstance(d, dict):
        raise TypeError(f"spec must be a JSON object, got "
                        f"{type(d).__name__}")
    unknown = set(d) - set(_SPEC_FIELDS)
    if unknown:
        raise ValueError(f"unknown spec field(s) {sorted(unknown)}; "
                         f"known: {sorted(_SPEC_FIELDS)}")
    return ExperimentSpec(**d)


def dumps(spec: ExperimentSpec, indent: Optional[int] = None) -> str:
    """Canonical JSON serialization (sorted keys, every field explicit).
    ``loads(dumps(spec))`` == ``spec``."""
    return json.dumps(spec.canonical(), sort_keys=True, indent=indent)


def loads(s: str) -> ExperimentSpec:
    return from_dict(json.loads(s))


def load(path: str) -> ExperimentSpec:
    with open(path) as f:
        return from_dict(json.load(f))


def save(spec: ExperimentSpec, path: str) -> None:
    with open(path, "w") as f:
        f.write(dumps(spec, indent=2) + "\n")


def workload_fingerprint(spec: ExperimentSpec) -> dict:
    """Everything about the spec that changes what a throughput or
    learning-curve number *means* — the canonical form minus run length
    and checkpoint policy (recorded separately by the bench harness).
    benchmarks/check_sps.py compares records by this dict and prints a
    field-level diff on mismatch."""
    fp = spec.canonical()
    fp.pop("intervals")
    fp.pop("checkpoint")
    # the serve block shapes request latency, not the training workload;
    # keeping it out preserves comparability with every committed
    # pre-serve record (benchmarks/serve_bench.py re-adds it to ITS
    # records, where max_batch does change what a QPS number means)
    fp.pop("serve")
    # faults likewise: the recovery guarantee (DESIGN.md §11) is exactly
    # that a faulted run's results MEAN the same as the fault-free
    # run's — only wall time differs, and the bench harness records
    # that separately (benchmarks/recovery_bench.py)
    fp.pop("faults")
    # DEFAULT batch geometry is popped so every committed pre-BatchConfig
    # record stays byte-comparable; a NON-default geometry stays in —
    # replica count and accumulation change the execution schedule, so
    # check_sps must never compare SPS across geometries (the
    # determinism contract makes the RESULTS equal, not the timings)
    if spec.batch.is_default:
        fp.pop("batch")
    # tenancy is popped ALWAYS: by the multiplexing-determinism contract
    # (DESIGN.md §13) a tenant's results are bit-exact to its solo run
    # at any weight/quantum — scheduling share changes when intervals
    # run, never what they compute, so pooled and solo records of the
    # same workload must stay comparable
    fp.pop("tenancy")
    return fp


def diff_canonical(a: dict, b: dict, prefix: str = "") -> list:
    """Field-level differences between two canonical spec dicts, as
    ``path: a_value != b_value`` strings (recursing into nested
    objects) — what check_sps prints instead of an opaque
    "fingerprint differs"."""
    out = []
    if isinstance(a, dict) and isinstance(b, dict):
        for key in sorted(set(a) | set(b)):
            path = f"{prefix}.{key}" if prefix else key
            if key not in a:
                out.append(f"{path}: <absent> != {b[key]!r}")
            elif key not in b:
                out.append(f"{path}: {a[key]!r} != <absent>")
            else:
                out.extend(diff_canonical(a[key], b[key], path))
    elif a != b:
        out.append(f"{prefix or '<root>'}: {a!r} != {b!r}")
    return out
