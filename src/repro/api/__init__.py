"""repro.api — the declarative experiment surface.

One ``ExperimentSpec`` (env x policy x optimizer x algorithm x runtime
x HTSConfig knobs x checkpoint policy, every axis a registry name) and
one verb:

    from repro import api

    spec = api.ExperimentSpec(env="catch", runtime="mesh",
                              hts={"alpha": 8, "n_envs": 16})
    session = api.build(spec)
    out = session.run(400)                  # engine RunResult

    api.save(spec, "spec.json")             # canonical JSON
    session = api.build(api.load("spec.json"))   # bit-identical rebuild

    server = session.serve()                # policy-as-a-service
    server.act(obs, seed=7)                 # (repro.serve, DESIGN.md §10)

Every surface in the repo — examples/, benchmarks/, the unified CLI
(``python -m repro.launch.run --spec spec.json``), the LLM launcher
(repro.launch.train) and the checkpointing trainer — consumes this one
API instead of hand-wiring env/policy/optimizer/runtime construction.
See spec.py for serialization + validation, session.py for build and
the Session surface.
"""
from repro.api.session import Session, build, runtime_names  # noqa: F401
from repro.api.spec import (  # noqa: F401
    CheckpointSpec, ComponentSpec, ExperimentSpec, diff_canonical,
    dumps, from_dict, load, loads, save, workload_fingerprint)
from repro.core.batch import BatchConfig  # noqa: F401
from repro.faults import FaultEvent, FaultPlan  # noqa: F401
from repro.serve.config import ServeConfig  # noqa: F401
