"""``build(spec) -> Session``: resolve every ExperimentSpec axis through
its registry and wrap the constructed runtime in one driving surface.

``build`` is where declarative turns concrete — and where validation
lives: unknown registry names, ``staleness < 1``, a non-Env workload
under an Env runtime, a vocab-mismatched token stream all fail HERE
with the offending field named, not three layers down with a shape
error (and never a silent default).

``Session`` wraps the engine contract (``run``/``state``/``run_from``,
core/engine.py) and adds:

  * ``fit`` — checkpointed training through core/trainer.Trainer, using
    the spec's CheckpointSpec;
  * ``on_interval`` observers — a reporting-only streaming hook: every
    observer receives one metrics dict per completed interval
    (``{"interval": j, "rewards": (alpha, n_envs), "dones": ...}``,
    plus any runtime extras such as the stream runtime's loss stats).
    Runtimes with a live coordinator (host, stream) deliver metrics
    mid-run; fused scan runtimes deliver them from the RunResult's
    metric streams right after the program returns. Either way the
    observer sees the SAME sequence — and the training computation is
    untouched (the goldens of tests/test_goldens.py do not move).

Live objects that cannot ride in a JSON spec (a ``jax.sharding.Mesh``,
a custom ``HostConfig``) are passed as ``build(spec, mesh=...)``
overrides: they reach the runtime constructor verbatim, after —
and taking precedence over — the spec's own runtime kwargs.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import numpy as np
import jax

from repro import algorithms, envs, models, optim
from repro.api import spec as spec_mod
from repro.api.spec import ExperimentSpec
from repro.core import engine
from repro.core.engine import HTSConfig, RunResult, TrainState
from repro.envs.interfaces import Env

# runtimes constructed outside the engine registry (different workload
# contract: a TokenStream, not an Env — see core/stream_runtime.py)
_STREAM_RUNTIME = "stream"


def runtime_names() -> list:
    return sorted(set(engine.runtime_names()) | {_STREAM_RUNTIME})


def _decode_steptime(value, where: str):
    """JSON -> StepTimeModel for HostConfig duration fields; floats pass
    through (constant durations)."""
    if isinstance(value, dict):
        from repro.envs.steptime import StepTimeModel
        unknown = set(value) - {"shape", "rate", "base"}
        if unknown:
            raise ValueError(
                f"unknown StepTimeModel field(s) {sorted(unknown)} in "
                f"{where}; known: ['shape', 'rate', 'base']")
        return StepTimeModel(**value)
    return value


def _decode_runtime_kwargs(name: str, kwargs: Dict[str, Any]) -> dict:
    """Rehydrate the JSON-able runtime kwargs a spec carries into the
    config objects the runtime constructors take (HostConfig /
    AsyncConfig / StepTimeModel)."""
    out = dict(kwargs)
    if name == "host":
        host = out.get("host")
        if isinstance(host, dict):
            from repro.core.host_runtime import HostConfig
            host = dict(host)
            for key in ("step_time", "learner_time"):
                if key in host:
                    host[key] = _decode_steptime(host[key],
                                                 f"runtime.kwargs.host.{key}")
            try:
                out["host"] = HostConfig(**host)
            except TypeError as e:
                raise ValueError(f"bad host runtime kwargs: {e}") from None
    elif name == "async":
        acfg = out.get("acfg")
        if isinstance(acfg, dict):
            from repro.core.baselines import AsyncConfig
            try:
                out["acfg"] = AsyncConfig(**acfg)
            except TypeError as e:
                raise ValueError(f"bad async runtime kwargs: {e}") from None
    return out


def build(spec: ExperimentSpec, **runtime_overrides) -> "Session":
    """Construct the experiment a spec describes. ``runtime_overrides``
    are merged over the spec's runtime kwargs (for live objects — a
    Mesh, a HostConfig — that cannot ride in JSON)."""
    if not isinstance(spec, ExperimentSpec):
        raise TypeError(
            f"build takes an ExperimentSpec (got {type(spec).__name__}); "
            f"parse JSON with repro.api.loads/load first")

    # resolve every axis through its registry — unknown names raise
    # KeyError listing what IS registered
    rt_name = spec.runtime.name
    if rt_name != _STREAM_RUNTIME:
        try:
            engine.get_runtime(rt_name)    # existence check
        except KeyError:
            raise KeyError(f"unknown runtime {rt_name!r}; "
                           f"registered: {runtime_names()}") from None
    algorithms.get_algorithm(spec.algorithm)
    env_factory = envs.get_env_factory(spec.env.name)
    try:
        env = env_factory(**spec.env.kwargs)
    except TypeError as e:
        raise ValueError(
            f"bad env kwargs for {spec.env.name!r}: {e}") from None
    # workload/runtime pairing — validated BEFORE the policy is sized to
    # the env, so the error names the actual mismatch
    from repro.data.pipeline import TokenStream
    if rt_name == _STREAM_RUNTIME:
        if not isinstance(env, TokenStream):
            raise ValueError(
                f"the 'stream' runtime consumes a TokenStream workload "
                f"(env 'token_stream'), got env {spec.env.name!r} -> "
                f"{type(env).__name__}")
    elif not isinstance(env, Env):
        from repro.envs.device import DeviceEnv
        if isinstance(env, DeviceEnv):
            # "catch_device" etc. are selection OUTPUTS, not workloads:
            # the backend axis lives in the config so every runtime
            # (and the bit-exactness contract) sees one env identity
            raise ValueError(
                f"env {spec.env.name!r} is a device-resident port, not "
                f"a workload; name the host env "
                f"(env={env.host_name!r}) and select the port with "
                f"hts={{'env_backend': 'device'}}")
        raise ValueError(
            f"runtime {rt_name!r} consumes an Env workload, got env "
            f"{spec.env.name!r} -> {type(env).__name__} (the "
            f"'token_stream' source pairs only with runtime 'stream')")
    try:
        policy = models.get_policy(spec.policy.name, env,
                                   **spec.policy.kwargs)
    except TypeError as e:
        raise ValueError(
            f"bad policy kwargs for {spec.policy.name!r}: {e}") from None
    except AttributeError as e:
        raise ValueError(
            f"policy {spec.policy.name!r} could not be sized to env "
            f"{spec.env.name!r}: {e} (the token stream pairs with "
            f"config-backed policies like 'backbone')") from None
    try:
        opt = optim.get_optimizer(spec.optimizer.name,
                                  **spec.optimizer.kwargs)
    except TypeError as e:
        raise ValueError(
            f"bad optimizer kwargs for {spec.optimizer.name!r}: "
            f"{e}") from None
    cfg = spec.hts_config()
    params = policy.init(jax.random.key(spec.params_seed))

    rkw = _decode_runtime_kwargs(rt_name, spec.runtime.kwargs)
    rkw.update(runtime_overrides)

    # batch geometry (spec.batch, DESIGN.md §12) threads into the
    # runtimes that honor the scale-out determinism contract: host and
    # mesh reproduce any factorization in-process, sharded sizes its
    # replica axis from it, stream maps grad_accumulation onto its
    # learner microbatches. The baselines and the serving entry have no
    # geometry to factorize — a non-default batch there is a spec
    # error, named loudly rather than silently ignored.
    _BATCH_RUNTIMES = ("host", "mesh", "sharded")
    if rt_name in _BATCH_RUNTIMES:
        rkw.setdefault("batch", spec.batch)
    elif rt_name == _STREAM_RUNTIME:
        rkw.setdefault("batch", spec.batch)
    elif not spec.batch.is_default:
        raise ValueError(
            f"runtime {rt_name!r} does not implement the batch-geometry "
            f"contract; non-default spec.batch pairs with "
            f"{sorted(_BATCH_RUNTIMES + (_STREAM_RUNTIME,))}")

    # ONE injector spans every surface of the session — host runtime
    # pools, Trainer checkpoint writes, the serve dispatcher — so a
    # single FaultPlan schedules chaos across training AND serving
    # (DESIGN.md §11). Trivial plan (no events, no supervision): no
    # injector, zero overhead anywhere.
    injector = None
    if spec.faults.events or spec.faults.max_restarts:
        from repro.faults import FaultInjector
        injector = FaultInjector(spec.faults)
    if injector is not None and rt_name == "host":
        # the one training runtime with live fault sites (worker pools)
        rkw.setdefault("faults", injector)

    if rt_name == _STREAM_RUNTIME:
        from repro.core.stream_runtime import StreamRuntime
        if policy.config is None:
            raise ValueError(
                f"the 'stream' runtime needs a config-backed policy "
                f"(e.g. 'backbone'), got {spec.policy.name!r}")
        if env.vocab != policy.config.vocab_size:
            raise ValueError(
                f"token stream vocab={env.vocab} != model "
                f"vocab_size={policy.config.vocab_size}; make "
                f"env.kwargs.vocab match the policy config")
        runtime = StreamRuntime(
            lambda: env_factory(**spec.env.kwargs), params, opt, cfg,
            model_config=policy.config, **rkw)
    else:
        if policy.apply is None:
            raise ValueError(
                f"policy {spec.policy.name!r} has no per-step apply "
                f"function; it pairs only with the 'stream' runtime")
        if rt_name in engine.SERVING_RUNTIMES:
            # the serving entry is the one factory that consumes the
            # spec's serve block (dispatch width / admission bound)
            rkw.setdefault("serve", spec.serve)
            if injector is not None:
                rkw.setdefault("faults", injector)
        runtime = engine.make_runtime(rt_name, env, policy.apply, params,
                                      opt, cfg, **rkw)
    return Session(spec, runtime, env, policy, params, opt, cfg,
                   faults=injector)


class Session:
    """One constructed experiment: the spec, its resolved pieces, and
    the engine-contract driving surface (plus observers and ``fit``)."""

    def __init__(self, spec: ExperimentSpec, runtime, env, policy,
                 params, opt, cfg: HTSConfig, faults=None):
        self.spec = spec
        self.runtime = runtime
        self.env = env
        self.policy = policy
        self.params = params      # initial parameters (policy.init)
        self.opt = opt
        self.cfg = cfg
        self.faults = faults      # the session-wide FaultInjector (or None)
        self._observers: List[Callable[[dict], None]] = []

    # ------------------------------------------------------- observers
    def on_interval(self, fn: Callable[[dict], None]):
        """Register a reporting-only per-interval metrics observer.
        Usable as a decorator; returns ``fn``."""
        self._observers.append(fn)
        return fn

    def remove_observer(self, fn) -> None:
        self._observers.remove(fn)

    def _emit(self, interval: int, metrics: dict) -> None:
        payload = {"interval": int(interval), **metrics}
        # iterate a snapshot: an observer that removes itself mid-
        # dispatch (the one-shot-observer pattern) must not shift its
        # successor out of this interval's iteration
        for fn in list(self._observers):
            fn(payload)

    def _dispatch_from_result(self, out: RunResult, start: int) -> None:
        """Post-hoc observer dispatch from the RunResult's metric
        streams (fused runtimes have no per-interval coordinator)."""
        for i, metrics in out.interval_metrics():
            self._emit(start + i, metrics)

    def _run_observed(self, fn: Callable[[], RunResult],
                      start: int) -> RunResult:
        live = self._observers and hasattr(self.runtime, "on_interval")
        if live:
            self.runtime.on_interval = self._emit
        try:
            out = fn()
        finally:
            if live:
                self.runtime.on_interval = None
        if self._observers and not live:
            self._dispatch_from_result(out, start)
        return out

    # -------------------------------------------------- engine contract
    def run(self, n_intervals: Optional[int] = None) -> RunResult:
        n = self.spec.intervals if n_intervals is None else n_intervals
        return self._run_observed(lambda: self.runtime.run(n), start=0)

    def state(self) -> TrainState:
        return self.runtime.state()

    def run_from(self, state: TrainState, n_intervals: int,
                 finalize: bool = True) -> RunResult:
        return self._run_observed(
            lambda: self.runtime.run_from(state, n_intervals, finalize),
            start=int(state.interval))

    # ------------------------------------------------------------- fit
    def fit(self, n_intervals: Optional[int] = None,
            resume: bool = False, on_segment=None):
        """Checkpointed training per the spec's CheckpointSpec
        (core/trainer.Trainer). Observers receive every interval's
        metrics, across segments and resumes."""
        from repro.core.trainer import Trainer
        ck = self.spec.checkpoint
        trainer = Trainer(self.runtime, checkpoint_dir=ck.dir,
                          ckpt_every=ck.every, keep=ck.keep,
                          on_segment=on_segment,
                          on_interval=(self._emit if self._observers
                                       else None),
                          faults=self.faults)
        n = self.spec.intervals if n_intervals is None else n_intervals
        return trainer.fit(n, resume=resume)

    # ------------------------------------------------------------ serve
    def serve(self, checkpoint: Optional[str] = None, start: bool = True):
        """Policy-as-a-service (repro.serve, DESIGN.md §10): a started
        ``PolicyServer`` answering action requests for this session's
        policy through a continuous-batching dispatch loop configured by
        ``spec.serve``.

        Parameters come from a ``TrainState`` checkpoint capsule:
        ``checkpoint`` names one explicitly (the ``step_NNNNNNNN`` base
        path, no suffix); otherwise the newest complete capsule under
        ``spec.checkpoint.dir`` is used; with neither, the session's
        initial parameters are served (smoke tests, untrained-baseline
        comparisons). Works under any runtime — the capsule's leading
        leaves ARE the policy params for every runtime and staleness
        (checkpoint.io.restore_prefix) — but ``runtime="serve"`` builds
        a session that can ONLY serve, for deployments that should
        never accidentally train."""
        from repro.checkpoint import io as ckpt_io
        from repro.serve import PolicyServer
        if checkpoint is None and self.spec.checkpoint.dir:
            checkpoint = ckpt_io.latest(self.spec.checkpoint.dir)
        params = self.params
        if checkpoint is not None:
            params = ckpt_io.restore_prefix(checkpoint, self.params)
        if hasattr(self.runtime, "server"):      # the serve runtime
            return self.runtime.server(params=params, start=start)
        _, obs0 = self.env.reset(jax.random.key(0))
        server = PolicyServer(self.policy.apply, params,
                              obs_like=np.asarray(obs0),
                              serve=self.spec.serve, seed=self.cfg.seed,
                              faults=self.faults)
        return server.start() if start else server

    # ------------------------------------------------------------ pool
    @staticmethod
    def pool(specs, weights=None, names=None, max_concurrency: int = 2,
             on_slice=None, **build_overrides):
        """Admit several specs (or built Sessions) into one
        ``repro.tenancy.TenantPool`` sharing this process's device pool:

            pool = Session.pool([spec_a, spec_b], weights=[2, 1])
            results = pool.run()          # {name: TenantResult}

        Deterministic weighted fair-share time-slicing at interval
        granularity; every tenant's final params and episode streams
        are bit-exact to its solo ``run`` (DESIGN.md §13). See
        ``TenantPool`` for lifecycle (pause/evict/readmit) and
        multi-model ``pool.serve()``."""
        from repro.tenancy import TenantPool
        return TenantPool(specs, weights=weights, names=names,
                          max_concurrency=max_concurrency,
                          on_slice=on_slice, **build_overrides)

    # ------------------------------------------------------------ misc
    def describe(self) -> str:
        return spec_mod.dumps(self.spec, indent=2)
