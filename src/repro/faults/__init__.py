"""Deterministic fault injection + self-healing supervision (DESIGN.md
§11): ``FaultPlan`` declares a seeded chaos schedule, ``FaultInjector``
fires it at logical ``(site, interval)`` points across training and
serving, and the supervisor (core/trainer.Trainer) recovers bit-exactly
from whatever it breaks."""
from repro.faults.plan import (SITES, FaultEvent, FaultInjector,
                               FaultPlan, InjectedFault)

__all__ = ["SITES", "FaultEvent", "FaultInjector", "FaultPlan",
           "InjectedFault"]
