"""Deterministic fault injection: the chaos schedule and its injector.

HTS-RL's determinism contract (DESIGN.md §3) keys every computation to
*logical* coordinates — ``(seed, env_id, step)`` for rollouts,
``(server seed, request seed)`` for serving — never to wall-clock time
or thread identity. Fault injection rides the same discipline: a
``FaultPlan`` is a declarative schedule of ``(site, interval)`` events,
and components poll the shared ``FaultInjector`` at exactly those
logical points (the host coordinator at interval j's learner dispatch,
executor/actor/stepper worker threads at interval j's requests, the
trainer after checkpoint ``intervals`` is written, the serve dispatcher
at dispatch index d). Two consequences:

* **replayable chaos** — the same spec + the same plan produces the
  same faults at the same logical points, every run, on any machine;
* **provable recovery** — because the supervisor (core/trainer.Trainer)
  restores a ``TrainState`` capsule and ``run_from`` is bit-exact, the
  recovered run's final parameters and episode-return stream can be
  asserted EQUAL to the fault-free run's (tests/test_faults.py), not
  merely "close".

Events fire **at most once** per injector lifetime: after the
supervisor restores and replays interval j, the event that killed
interval j the first time is spent, so the replay proceeds cleanly —
which is exactly the semantics of a real transient fault. Persistent
faults are modeled by listing the same ``(site, interval)`` event
several times (each listing fires once).

Sites and kinds:

  =============  =======================  ===========================
  site           where it fires           kinds
  =============  =======================  ===========================
  actor          host actor thread        exc  (thread death)
  executor       host executor thread     exc  (thread death)
  stepper        host stepper thread      exc  (thread death)
  env_step       host env-step dispatch   exc  (env raises mid-step)
  learner        host learner dispatch    exc | nan (grads -> NaN)
  checkpoint     Trainer._save, after     truncate (corrupt the just-
                 the write completes       written npz in place)
  dispatcher     serve dispatch d         exc  (dispatcher death)
  =============  =======================  ===========================

The plan also carries the recovery policy (``max_restarts``,
``backoff``, ``backoff_cap``) — per the staleness-constrained-rollout
observation that recovery policy belongs in the pipeline contract, not
bolted on afterwards. ``max_restarts=0`` (the default) disables
supervision entirely: today's fail-loud semantics, unchanged.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

SITES = ("actor", "executor", "stepper", "env_step", "learner",
         "checkpoint", "dispatcher")

# kinds each site supports; first entry is the default
_SITE_KINDS = {
    "actor": ("exc",),
    "executor": ("exc",),
    "stepper": ("exc",),
    "env_step": ("exc",),
    "learner": ("exc", "nan"),
    "checkpoint": ("truncate",),
    "dispatcher": ("exc",),
}


class InjectedFault(RuntimeError):
    """The exception an ``exc``-kind event raises at its site. A
    RuntimeError subclass so it rides the same propagation paths a real
    component failure does (pool-guard re-raise, dispatcher failure) and
    the same supervisor catches both."""

    def __init__(self, event: "FaultEvent"):
        super().__init__(
            f"injected fault: site={event.site!r} "
            f"interval={event.interval} kind={event.kind!r}")
        self.event = event


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fire ``kind`` at ``(site, interval)``.

    ``interval`` is the site's logical clock: the global training
    interval j for the host/trainer sites, the checkpoint's cumulative
    interval count for ``checkpoint``, the dispatch index for
    ``dispatcher``.
    """
    site: str
    interval: int
    kind: str = ""          # "" -> the site's default kind

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; known sites: "
                f"{list(SITES)}")
        if self.interval < 0:
            raise ValueError(
                f"fault interval must be >= 0, got {self.interval} "
                f"(site {self.site!r})")
        kinds = _SITE_KINDS[self.site]
        if self.kind == "":
            object.__setattr__(self, "kind", kinds[0])
        elif self.kind not in kinds:
            raise ValueError(
                f"site {self.site!r} supports kind(s) {list(kinds)}, "
                f"got {self.kind!r}")

    def canonical(self) -> dict:
        return {"site": self.site, "interval": int(self.interval),
                "kind": self.kind}

    @staticmethod
    def of(value) -> "FaultEvent":
        if isinstance(value, FaultEvent):
            return value
        if isinstance(value, dict):
            unknown = set(value) - {"site", "interval", "kind"}
            if unknown:
                raise ValueError(
                    f"unknown fault event field(s) {sorted(unknown)}; "
                    f"an event is {{'site': ..., 'interval': ..., "
                    f"'kind': ...}}")
            missing = {"site", "interval"} - set(value)
            if missing:
                raise ValueError(
                    f"fault event needs {sorted(missing)} "
                    f"(got {sorted(value)})")
            return FaultEvent(value["site"], int(value["interval"]),
                              value.get("kind", ""))
        if isinstance(value, (tuple, list)) and 2 <= len(value) <= 3:
            return FaultEvent(*value)
        raise TypeError(
            f"a fault event is a dict, FaultEvent, or (site, interval"
            f"[, kind]) tuple, got {type(value).__name__}")


@dataclass(frozen=True)
class FaultPlan:
    """The spec-level chaos schedule + recovery policy (the ``faults``
    block of an ExperimentSpec). JSON-round-trippable like every other
    spec axis; validated eagerly at construction.

    * ``events``       — the fault schedule (each fires once, in listing
      order for duplicates).
    * ``seed``         — provenance marker for generated plans
      (``FaultPlan.generate``); inert for hand-written ones.
    * ``max_restarts`` — how many CONSECUTIVE failed segments the
      supervisor absorbs before re-raising (0 = no supervision:
      failures propagate exactly as before this layer existed).
    * ``backoff``      — seconds slept before restart #1; doubles each
      consecutive restart, capped at ``backoff_cap``.
    """
    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0
    max_restarts: int = 0
    backoff: float = 0.05
    backoff_cap: float = 2.0

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(FaultEvent.of(e) for e in self.events))
        if self.max_restarts < 0:
            raise ValueError(
                f"faults.max_restarts must be >= 0, got "
                f"{self.max_restarts}")
        if self.backoff < 0:
            raise ValueError(
                f"faults.backoff must be >= 0, got {self.backoff}")
        if self.backoff_cap < self.backoff:
            raise ValueError(
                f"faults.backoff_cap ({self.backoff_cap}) must be >= "
                f"faults.backoff ({self.backoff})")

    def canonical(self) -> dict:
        return {"events": [e.canonical() for e in self.events],
                "seed": int(self.seed),
                "max_restarts": int(self.max_restarts),
                "backoff": float(self.backoff),
                "backoff_cap": float(self.backoff_cap)}

    @staticmethod
    def of(value) -> "FaultPlan":
        if isinstance(value, FaultPlan):
            return value
        if value is None:
            return FaultPlan()
        if isinstance(value, dict):
            known = {"events", "seed", "max_restarts", "backoff",
                     "backoff_cap"}
            unknown = set(value) - known
            if unknown:
                raise ValueError(
                    f"unknown faults field(s) {sorted(unknown)}; "
                    f"known: {sorted(known)}")
            kw = dict(value)
            kw["events"] = tuple(FaultEvent.of(e)
                                 for e in kw.get("events", ()))
            return FaultPlan(**kw)
        raise TypeError(f"faults must be a dict or FaultPlan, got "
                        f"{type(value).__name__}")

    @staticmethod
    def generate(seed: int, n_intervals: int, n_events: int = 3,
                 sites: Sequence[str] = ("actor", "executor", "stepper",
                                         "env_step", "learner"),
                 max_restarts: int = 0, **kw) -> "FaultPlan":
        """A seeded random schedule: ``n_events`` faults at distinct
        intervals drawn from ``[1, n_intervals)``, sites round-robined
        through a seeded shuffle. Same seed -> same plan, so a CI chaos
        leg pins one number and replays the identical storm."""
        import numpy as np
        if n_intervals < 2:
            raise ValueError(
                f"generate needs n_intervals >= 2, got {n_intervals}")
        for s in sites:
            if s not in SITES:
                raise ValueError(f"unknown fault site {s!r}; known "
                                 f"sites: {list(SITES)}")
        rng = np.random.RandomState(seed)
        n_events = min(n_events, n_intervals - 1)
        ivals = np.sort(rng.choice(
            np.arange(1, n_intervals), size=n_events, replace=False))
        order = rng.permutation(len(sites))
        events = tuple(
            FaultEvent(sites[order[i % len(sites)]], int(j))
            for i, j in enumerate(ivals))
        restarts = max_restarts if max_restarts else n_events
        return FaultPlan(events=events, seed=seed,
                         max_restarts=restarts, **kw)


class FaultInjector:
    """The live, thread-safe side of a FaultPlan: components call
    ``fire(site, interval)`` (raise ``exc``-kind events, return others)
    or ``poll`` (never raises) at their logical injection points.

    Every event fires AT MOST ONCE per injector lifetime (the armed
    list shrinks), so a supervisor replaying interval j after recovery
    does not re-trip the fault that killed it — a transient fault, by
    construction. ``fired`` records what actually fired, in order, for
    reports and the recovery benchmark.

    One injector is shared across every surface of a Session (host
    runtime pools, Trainer checkpoint writes, the serve dispatcher), so
    a single plan spans training AND serving.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = FaultPlan.of(plan)
        self._armed: List[FaultEvent] = list(self.plan.events)
        self.fired: List[FaultEvent] = []
        self._lock = threading.Lock()

    def poll(self, site: str, interval: int) -> Optional[FaultEvent]:
        """Consume and return the first armed event at ``(site,
        interval)``, or None. Never raises."""
        with self._lock:
            for i, ev in enumerate(self._armed):
                if ev.site == site and ev.interval == int(interval):
                    del self._armed[i]
                    self.fired.append(ev)
                    return ev
        return None

    def fire(self, site: str, interval: int) -> Optional[FaultEvent]:
        """Like ``poll``, but ``exc``-kind events raise InjectedFault at
        the call site (the common case: simulate a component death
        exactly where a real one would surface). Non-exc kinds are
        returned for the caller to apply (NaN the grads, truncate the
        file)."""
        ev = self.poll(site, interval)
        if ev is not None and ev.kind == "exc":
            raise InjectedFault(ev)
        return ev

    @property
    def armed(self) -> Tuple[FaultEvent, ...]:
        with self._lock:
            return tuple(self._armed)
