"""Loop-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*,
which under scan-over-layers understates FLOPs/bytes by the layer count.
This walker parses the post-optimization HLO text, recovers trip counts
from ``backend_config={"known_trip_count":...}`` (with a fallback to the
loop condition's compare-against-constant), and accumulates:

  * flops: 2 * prod(dot output dims) * prod(contracting dims)  (+ convs)
  * bytes: operand + output bytes of top-level instructions (HBM-traffic
    proxy under the assumption one fusion = one pass over its operands)
  * transcendentals: elements of exp/log/tanh/... ops

Also detects the XLA:CPU float-normalization artifact: f32 buffers that
are whole-array converts of bf16 values (the CPU backend cannot execute
bf16 math, so it stashes upcast copies). These don't exist on the TPU
pipeline; their sizes are reported so the dry-run can publish a
TPU-adjusted peak-memory estimate alongside the raw number.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "u4": 1, "s4": 1,
}

TRANSCENDENTAL = ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                  "logistic", "sine", "cosine", "exp(")

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_info(txt: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _nbytes(txt: str) -> int:
    total = 0
    for dt, shape in _shape_info(txt):
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


def _nelems(txt: str) -> int:
    total = 0
    for _, shape in _shape_info(txt):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    upcast_f32_bytes: float = 0.0       # CPU float-normalization artifacts

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + \
                v * mult


COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


class HloCostModel:
    def __init__(self, hlo: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        cur = None
        for line in hlo.splitlines():
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(1)
                self.comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
            elif cur is not None:
                self.comps[cur].append(line)
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
        if m:
            self.entry = m.group(1)
        self._symtabs: Dict[str, Dict[str, str]] = {}
        self._cache: Dict[str, Costs] = {}
        self.upcast_f32_bytes = 0.0
        self._find_upcasts(hlo)

    # -------------------------------------------------------------- utils
    def _symtab(self, comp: str) -> Dict[str, str]:
        if comp in self._symtabs:
            return self._symtabs[comp]
        tab: Dict[str, str] = {}
        for line in self.comps.get(comp, []):
            m = _DEF_RE.match(line)
            if m:
                tab[m.group(1)] = m.group(2)
        self._symtabs[comp] = tab
        return tab

    def _trip_count(self, line: str, cond: Optional[str]) -> int:
        m = _TRIP_RE.search(line)
        if m:
            return int(m.group(1))
        if cond and cond in self.comps:
            c = re.search(r"constant\((\d+)\)", "\n".join(self.comps[cond]))
            if c:
                return int(c.group(1))
        return 1

    def _dot_flops(self, comp: str, line: str) -> float:
        m = _DEF_RE.match(line)
        if not m:
            return 0.0
        out_elems = _nelems(m.group(2))
        # contracting dims from lhs operand shape
        ops = re.match(r"\s*%?([\w\.\-]+)", m.group(4))
        cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        if not ops or not cd:
            return 2.0 * out_elems          # fallback
        lhs_shape_txt = self._symtab(comp).get(ops.group(1), "")
        info = _shape_info(lhs_shape_txt)
        if not info:
            return 2.0 * out_elems
        _, lhs_shape = info[0]
        k = 1
        for d in cd.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                k *= lhs_shape[int(d)]
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: str, line: str) -> float:
        m = _DEF_RE.match(line)
        if not m:
            return 0.0
        out_elems = _nelems(m.group(2))
        ops = [o.group(1) for o in
               re.finditer(r"%?([\w\.\-]+)", m.group(4))][:2]
        if len(ops) < 2:
            return 2.0 * out_elems
        rhs_txt = self._symtab(comp).get(ops[1], "")
        info = _shape_info(rhs_txt)
        if not info:
            return 2.0 * out_elems
        _, ks = info[0]
        k = 1
        for d in ks[:-1]:                   # all but output-feature dim
            k *= d
        return 2.0 * out_elems * k

    def _fusion_read_bytes(self, comp: str) -> int:
        """Bytes a fusion actually reads: a parameter consumed only via
        dynamic-slice contributes the slice size, not the whole buffer
        (the stacked scan residuals are read one slice per iteration)."""
        lines = self.comps.get(comp, [])
        total = 0
        params = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if m and m.group(3) == "parameter":
                params[m.group(1)] = m.group(2)
        for pname, pshape in params.items():
            slice_bytes = None
            whole = False
            for line in lines:
                if f"%{pname}" in line and f"%{pname} =" not in line:
                    dm = re.match(
                        r"\s*(?:ROOT )?%?[\w\.\-]+ = (\S+) "
                        r"dynamic-slice\(%" + re.escape(pname), line)
                    if dm:
                        b = _nbytes(dm.group(1))
                        slice_bytes = (slice_bytes or 0) + b
                    else:
                        whole = True
            if whole or slice_bytes is None:
                total += _nbytes(pshape)
            else:
                total += slice_bytes
        return total

    def _find_upcasts(self, hlo: str) -> None:
        """f32 whole-tensor converts of bf16 values > 256 MB: CPU
        float-normalization stash artifacts (absent on TPU)."""
        seen = set()
        for line in hlo.splitlines():
            m = re.match(
                r"\s*(?:ROOT )?%?([\w\.\-]+) = f32\[([\d,]+)\][^=]*"
                r"(convert|fusion)\(", line)
            if not m:
                continue
            name, dims, kind = m.groups()
            if kind == "fusion" and "convert" not in name:
                continue
            n = 1
            for d in dims.split(","):
                n *= int(d)
            b = n * 4
            if b > 256e6 and dims not in seen:
                seen.add(dims)
                self.upcast_f32_bytes += b / 2   # f32 copy minus bf16 size

    # ------------------------------------------------------------ walking
    def comp_costs(self, comp: str, count_bytes: bool = True) -> Costs:
        key = (comp, count_bytes)
        if key in self._cache:
            return self._cache[key]
        total = Costs()
        self._cache[key] = total            # break cycles
        for line in self.comps.get(comp, []):
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, out_shape, op, rest = m.groups()
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all"):
                continue
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                if bm:
                    trips = self._trip_count(line,
                                             cm.group(1) if cm else None)
                    total.add(self.comp_costs(bm.group(1), count_bytes),
                              trips)
                continue
            if op in ("call", "fusion", "conditional", "custom-call",
                      "async-start", "map", "reduce", "sort", "scatter",
                      "select-and-scatter", "reduce-window"):
                # fused computations never touch HBM internally: count
                # only their flops/transcendentals, not bytes
                inner_bytes = count_bytes and op not in ("fusion",)
                for cal in re.findall(
                        r"(?:calls|to_apply|branch_computations)=\{?%?"
                        r"([\w\.\-, %]+)", line):
                    for c in re.split(r"[,\s%]+", cal):
                        if c in self.comps:
                            total.add(self.comp_costs(c, inner_bytes), 1.0)
            coll = None
            for cname in COLLECTIVES:
                if op.startswith(cname):
                    coll = cname
                    break
            if coll and not op.endswith("-done"):
                mult = 2.0 if coll == "all-reduce" else 1.0
                total.collective_bytes[coll] = \
                    total.collective_bytes.get(coll, 0.0) + \
                    _nbytes(out_shape) * mult
                continue
            if op == "dot":
                total.flops += self._dot_flops(comp, line)
            elif op == "convolution":
                total.flops += self._conv_flops(comp, line)
            elif any(t in op for t in TRANSCENDENTAL):
                total.transcendentals += _nelems(out_shape)
            # bytes: output + operand traffic for compute ops
            if count_bytes and op == "dynamic-update-slice":
                # in-place slice write: traffic = 2x the updated slice,
                # not the whole buffer
                onames = re.findall(r"%([\w\.\-]+)", rest)
                if len(onames) >= 2:
                    shp = self._symtab(comp).get(onames[1])
                    if shp:
                        total.bytes += 2 * _nbytes(shp)
            elif count_bytes and op == "dynamic-slice":
                total.bytes += 2 * _nbytes(out_shape)
            elif count_bytes and op == "fusion":
                total.bytes += _nbytes(out_shape)
                cm = re.search(r"calls=%?([\w\.\-]+)", line)
                if cm and cm.group(1) in self.comps:
                    total.bytes += self._fusion_read_bytes(cm.group(1))
            elif count_bytes and op in (
                    "dot", "convolution", "copy", "convert",
                    "broadcast", "reduce", "transpose", "concatenate",
                    "pad", "slice", "reverse", "scatter", "gather",
                    "select-n", "add", "multiply", "subtract", "divide",
                    "maximum", "minimum", "exponential", "tanh", "rsqrt",
                    "iota", "compare", "select"):
                total.bytes += _nbytes(out_shape)
                # operands: look up each named operand's shape
                for o in re.finditer(r"%([\w\.\-]+)", rest.split(
                        ", calls=")[0].split(", to_apply=")[0]):
                    shp = self._symtab(comp).get(o.group(1))
                    if shp:
                        total.bytes += _nbytes(shp)
        return total

    def entry_costs(self) -> Costs:
        if not self.entry:
            return Costs()
        c = Costs()
        c.add(self.comp_costs(self.entry))
        c.upcast_f32_bytes = self.upcast_f32_bytes
        return c


def analyze(hlo: str) -> Costs:
    return HloCostModel(hlo).entry_costs()
