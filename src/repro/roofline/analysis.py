"""Three-term roofline from a compiled dry-run artifact.

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = collective_bytes_per_chip / link_bw

``cost_analysis()`` reports post-SPMD per-device flops (MAC=2 convention)
and bytes. Collective bytes are NOT in cost_analysis: we parse the
post-optimization HLO (``compiled.as_text()``) and sum the *output* tensor
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (all-reduce counted twice: it moves ~2x its size in
a ring). Ops inside while-loop bodies (scan-over-layers) are multiplied by
the loop trip count, which we recover from the loop's induction-variable
compare against a constant.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "token": 0, "opaque": 0,
    "u4": 1, "s4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_CALL_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w\.\-]+)")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->", line)
        if m and "{" in line:
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _loop_trip_count(body_lines: List[str], cond_name: str,
                     comps: Dict[str, List[str]]) -> int:
    """Best-effort trip count from the condition's compare-with-constant."""
    for line in comps.get(cond_name, []):
        m = re.search(r"compare\(.*\).*direction=LT", line)
        if m:
            c = re.search(r"constant\((\d+)\)", "\n".join(comps[cond_name]))
            if c:
                return int(c.group(1))
    c = re.search(r"constant\((\d+)\)", "\n".join(comps.get(cond_name, [])))
    return int(c.group(1)) if c else 1


def parse_collectives(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    # find while loops in entry and their (body, trip count)
    entry = None
    for name in comps:
        if re.search(r"^main|entry", name) or name.endswith(".1"):
            pass
    # entry computation: the one marked ENTRY in the original text
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    entry = m.group(1) if m else next(iter(comps), None)

    stats = CollectiveStats()

    def scan_comp(name: str, multiplier: int, seen):
        if name in seen or name not in comps:
            return
        seen = seen | {name}
        for line in comps[name]:
            stripped = line.strip()
            op = None
            for cname in COLLECTIVES:
                if re.search(rf"=\s*(\([^)]*\)|\S+)\s+{cname}(-start|-done)?\(",
                             line):
                    op = cname
                    break
            if op and "-done(" not in line:
                lhs = line.split(f" {op}")[0]
                b = _shape_bytes(lhs)
                mult = 2 if op == "all-reduce" else 1
                stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + \
                    b * mult * multiplier
                stats.count_by_op[op] = stats.count_by_op.get(op, 0) + \
                    multiplier
            if " while(" in line:
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                if bm:
                    trips = _loop_trip_count(comps.get(bm.group(1), []),
                                             cm.group(1) if cm else "", comps)
                    scan_comp(bm.group(1), multiplier * max(trips, 1), seen)
            else:
                for cal in _CALL_RE.findall(line):
                    if cal in comps and not any(
                            c in line for c in COLLECTIVES):
                        scan_comp(cal, multiplier, seen)

    if entry:
        scan_comp(entry, 1, frozenset())
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float            # 6 * N_active * tokens, global
    useful_flops_ratio: float     # model_flops / (HLO flops * chips)
    peak_memory_per_chip: float
    collective_detail: Dict[str, float] = field(default_factory=dict)
    note: str = ""

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def build_roofline(arch, shape, mesh_name, chips, cost, collectives,
                   model_flops, peak_memory) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collectives.total_bytes
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    coll_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    ratio = model_flops / max(flops * chips, 1.0)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        collective_bytes_per_chip=coll, compute_s=compute_s,
        memory_s=memory_s, collective_s=coll_s, bottleneck=bottleneck,
        model_flops=model_flops, useful_flops_ratio=ratio,
        peak_memory_per_chip=peak_memory,
        collective_detail=dict(collectives.bytes_by_op),
    )


def count_params(cfg) -> float:
    """Total and active parameter counts (analytic, from the config)."""
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    dh = cfg.resolved_head_dim
    attn = D * dh * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    gate = 1 if cfg.mlp_kind != "swiglu" else 2
    mlp_dense = D * F * (gate + 1)
    total = active = 0.0
    for (mixer, ffn) in cfg.layer_kinds:
        if mixer in ("attn_full", "attn_local"):
            total += attn
            active += attn
        elif mixer == "rglru":
            total += 6 * D * D
            active += 6 * D * D
        elif mixer == "rwkv":
            total += 5 * D * D + D * D
            active += 5 * D * D + D * D
        if ffn == "moe":
            e_mlp = D * cfg.d_ff * 3
            total += cfg.n_experts * e_mlp + D * cfg.n_experts
            active += cfg.top_k * e_mlp + D * cfg.n_experts
            if cfg.shared_expert:
                total += e_mlp
                active += e_mlp
        else:
            total += mlp_dense
            active += mlp_dense
    emb = V * D
    total += emb * 2          # embed + untied lm head
    active += emb * 2
    if cfg.is_encoder_decoder:
        enc = cfg.n_enc_layers * (attn + mlp_dense)
        xattn = cfg.n_layers * attn
        total += enc + xattn
        active += enc + xattn
    return total, active


def model_flops_for(cfg, shape_kind: str, seq_len: int, batch: int) -> float:
    """6*N_active*tokens for training; 2*N_active*tokens for inference
    forward (prefill); decode: 2*N_active per token * batch."""
    _, active = count_params(cfg)
    if shape_kind == "train":
        return 6.0 * active * seq_len * batch
    if shape_kind == "prefill":
        return 2.0 * active * seq_len * batch
    return 2.0 * active * batch       # one decoded token per request
