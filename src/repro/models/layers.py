"""Shared layers: norms, RoPE / M-RoPE, MLPs, embeddings, softcap.

Everything is functional: ``init_*(key, cfg, ...) -> params`` and
``apply(params, x, ...) -> y``. Params are nested dicts of jnp arrays.
Matmul weights live in ``cfg.dtype`` (bf16 by default); norm scales and
router weights stay f32 for stability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, dim: int):
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm_kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"] + params["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)                 # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    ang = ang[..., None, :]                                 # (..., S, 1, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections=(2, 3, 3)) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, Dh); positions: (3, B, S) -- temporal/height/width streams.
    The head_dim/2 frequency slots are split into ``sections`` (proportional
    1/4-3/8-3/8 split like Qwen2-VL's [16,24,24] for Dh=128), each rotated by
    its own position stream.
    """
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                  # (half,)
    total = sum(sections)
    bounds, acc = [], 0
    for s in sections[:-1]:
        acc += int(half * s / total)
        bounds.append(acc)
    slot = jnp.zeros((half,), jnp.int32)
    for i, b in enumerate(bounds):
        slot = jnp.where(jnp.arange(half) >= b, i + 1, slot)
    # pick the position stream per frequency slot: (B, S, half)
    pos = jnp.moveaxis(positions, 0, -1).astype(jnp.float32)  # (B, S, 3)
    pos_per_slot = pos[..., slot]                              # (B, S, half)
    ang = pos_per_slot * freqs                                 # (B, S, half)
    ang = ang[..., None, :]                                    # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------- precision-gated dots
_PG_CACHE: dict = {}


def _make_pg_dot(transpose_w: bool):
    """matmul whose WEIGHT gradient is cast to the weight dtype (bf16)
    before leaving the backward pass — the cast lands *before* the
    data-axis partial-sum all-reduce GSPMD inserts, halving gradient
    communication bytes (standard mixed-precision practice; opt-in via
    ModelConfig.grad_comm_bf16)."""

    @jax.custom_vjp
    def dot(x, w):
        return jnp.einsum("...d,fd->...f" if transpose_w else "...d,df->...f",
                          x, w)

    def fwd(x, w):
        return dot(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        if transpose_w:
            dx = jnp.einsum("...f,fd->...d", g, w)
            dw = jnp.einsum("...f,...d->fd", g, x)
        else:
            dx = jnp.einsum("...f,df->...d", g, w)
            dw = jnp.einsum("...d,...f->df", x, g)
        return dx.astype(x.dtype), dw.astype(w.dtype)

    dot.defvjp(fwd, bwd)
    return dot


def pg_dot(x, w, *, transpose_w: bool = False, enable: bool = False):
    if not enable:
        return jnp.einsum("...d,fd->...f" if transpose_w else "...d,df->...f",
                          x, w)
    key = transpose_w
    if key not in _PG_CACHE:
        _PG_CACHE[key] = _make_pg_dot(transpose_w)
    return _PG_CACHE[key](x, w)


# ---------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    dt = cdtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = cfg.d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "w_in": (jax.random.normal(k1, (cfg.d_model, d_ff)) * scale_in).astype(dt),
        "w_out": (jax.random.normal(k2, (d_ff, cfg.d_model)) * scale_out).astype(dt),
    }
    if cfg.mlp_kind == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (cfg.d_model, d_ff)) * scale_in).astype(dt)
    return p


def apply_mlp(params, x, cfg: ModelConfig):
    pg = getattr(cfg, "grad_comm_bf16", False)
    h = pg_dot(x, params["w_in"], enable=pg)
    if cfg.mlp_kind == "swiglu":
        g = pg_dot(x, params["w_gate"], enable=pg)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return pg_dot(h, params["w_out"], enable=pg)


# ---------------------------------------------------------------- embed
def init_embed(key, cfg: ModelConfig):
    dt = cdtype(cfg)
    emb = (jax.random.normal(key, (cfg.vocab_size, cfg.d_model)) *
           cfg.d_model ** -0.5).astype(dt)
    return {"table": emb}


def apply_embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)
