"""RWKV-6 "Finch" time-mix with data-dependent decay. [arXiv:2404.05892]

Per head (dim N), state S in R^{N x N}:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with data-dependent decay w_t = exp(-exp(w0 + tanh(x W_w1) W_w2))
(the low-rank "Finch" decay). Token-shift lerp on r/k/v/w/g inputs.

Simplifications vs. the released model (documented, not silent): the
token-shift lerp coefficients are static per-channel (Finch makes them
data-dependent via a second LoRA); output gating uses SiLU as in the
paper. The recurrence itself — the part that matters for the system —
is exact.

Sequence mode is a ``lax.scan`` over time (this is also what the official
CUDA kernel does — the recurrence is inherently sequential in t); the
Pallas kernel (``repro.kernels.wkv6``) tiles (B*H) over the grid with the
time loop in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.constraints import constrain

DECAY_RANK = 64


def init_rwkv6(key, cfg: ModelConfig):
    dt = layers.cdtype(cfg)
    D = cfg.d_model
    H = cfg.n_heads
    N = cfg.resolved_head_dim
    assert H * N == D, "rwkv6 requires n_heads * head_dim == d_model"
    ks = jax.random.split(key, 10)
    s = D ** -0.5
    return {
        "mu": 0.5 * jnp.ones((5, D), jnp.float32),        # shift lerp r,k,v,w,g
        "w_r": (jax.random.normal(ks[0], (D, D)) * s).astype(dt),
        "w_k": (jax.random.normal(ks[1], (D, D)) * s).astype(dt),
        "w_v": (jax.random.normal(ks[2], (D, D)) * s).astype(dt),
        "w_g": (jax.random.normal(ks[3], (D, D)) * s).astype(dt),
        "w_o": (jax.random.normal(ks[4], (D, D)) * s).astype(dt),
        "w0": jnp.full((D,), -6.0, jnp.float32),          # slow decay init
        "w_lora_a": (jax.random.normal(ks[5], (D, DECAY_RANK)) * s).astype(jnp.float32),
        "w_lora_b": (jax.random.normal(ks[6], (DECAY_RANK, D)) *
                     DECAY_RANK ** -0.5).astype(jnp.float32),
        "u": (jax.random.normal(ks[7], (H, N)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones((H, N), jnp.float32),        # per-head groupnorm
    }


def _token_shift(x, mu, x_prev=None):
    """lerp(x, shift(x), mu) for 5 streams. x: (B,S,D); mu: (5,D)."""
    if x_prev is None:
        xs = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xs = jnp.concatenate([x_prev.astype(x.dtype), x], axis=1)[:, :-1]
    return x[None] + mu[:, None, None, :].astype(x.dtype) * (xs - x)[None]


def wkv6_ref(r, k, v, w, u, s0=None, chunk: int = 64):
    """Reference WKV6 recurrence (also the Pallas oracle).

    r,k,v,w: (B, T, H, N) — w is the *decay* in (0,1), f32.
    u: (H, N). s0: (B, H, N, N) or None. Returns (o (B,T,H,N), sT).

    The time loop is split into checkpointed chunks: differentiating a
    plain T-step scan stores the (B,H,N,N) state every step (PBs at
    train_4k scale); with chunking the backward stores only chunk-boundary
    states and rematerializes inside each chunk.
    """
    B, T, H, N = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    s_init = (jnp.zeros((B, H, N, N), jnp.float32) if s0 is None
              else s0.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp                              # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,N,N)
        o = jnp.einsum("bhn,bhnm->bhm", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, o

    chunk = min(chunk, T)
    while T % chunk:
        chunk -= 1
    n_chunks = T // chunk

    def chunk_fn(s, xs_chunk):
        return jax.lax.scan(step, s, xs_chunk)

    chunk_fn = jax.checkpoint(chunk_fn)

    xs = tuple(constrain(
        jnp.moveaxis(t, 1, 0).reshape(n_chunks, chunk, B, H, N),
        None, None, "batch", "heads", None) for t in (rf, kf, vf, wf))

    def outer(s, xs_c):
        return chunk_fn(s, xs_c)

    sT, o = jax.lax.scan(outer, s_init, xs)
    o = o.reshape(T, B, H, N)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype), sT


def _project(params, x, cfg: ModelConfig, x_prev=None):
    """token shift + projections. Returns r,k,v,w (B,S,H,N), g (B,S,D)."""
    B, S, D = x.shape
    H, N = cfg.n_heads, cfg.resolved_head_dim
    xr, xk, xv, xw, xg = _token_shift(x, params["mu"], x_prev)
    r = (xr @ params["w_r"]).reshape(B, S, H, N)
    k = (xk @ params["w_k"]).reshape(B, S, H, N)
    v = (xv @ params["w_v"]).reshape(B, S, H, N)
    g = jax.nn.silu(xg @ params["w_g"])
    dec = params["w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ params["w_lora_a"]) @ params["w_lora_b"]
    w = jnp.exp(-jnp.exp(dec)).reshape(B, S, H, N)        # (0,1) f32
    return r, k, v, w, g


def _head_norm(params, o):
    """per-head rms groupnorm. o: (B,S,H,N) f32."""
    ms = jnp.mean(jnp.square(o), axis=-1, keepdims=True)
    return o * jax.lax.rsqrt(ms + 1e-6) * params["ln_scale"]


def apply_rwkv6_block(params, x, cfg: ModelConfig, cache=None):
    """x: (B,S,D). cache: {"state": (B,H,N,N) f32, "xprev": (B,1,D)}.

    Returns (y, new_cache)."""
    B, S, D = x.shape
    x_prev = cache["xprev"] if cache is not None else None
    s0 = cache["state"] if cache is not None else None
    r, k, v, w, g = _project(params, x, cfg, x_prev)
    o, sT = wkv6_ref(r, k, v, w, params["u"], s0)
    o = _head_norm(params, o.astype(jnp.float32))
    o = (o.reshape(B, S, D).astype(x.dtype) * g)
    y = o @ params["w_o"]
    new_cache = {"state": sT, "xprev": x[:, -1:]}
    return y, new_cache


def init_rwkv6_cache(cfg: ModelConfig, batch: int):
    H, N = cfg.n_heads, cfg.resolved_head_dim
    return {
        "state": jnp.zeros((batch, H, N, N), jnp.float32),
        "xprev": jnp.zeros((batch, 1, cfg.d_model), layers.cdtype(cfg)),
    }
