"""Policy registry — resolve a (init, apply) policy pair by name, sized
to an environment, so experiment specs (repro.api.ExperimentSpec) can
name their model instead of hand-wiring init/apply/wrapper plumbing.

A ``Policy`` bundles:

  * ``init(key) -> params``    — parameter construction (the key is the
    spec's ``params_seed``; everything else — obs shape, action count —
    was closed over from the env at ``get_policy`` time);
  * ``apply(params, obs) -> (logits, value)`` — the function every
    runtime's actor and learner call;
  * ``config``                 — the backing model config when one
    exists (``ModelConfig`` for the ``backbone`` entry, ``None`` for
    the small policies); the ``stream`` runtime reads it.

Built-ins:

  mlp       obs-flattening 2-layer tanh MLP (the canonical copy of the
            wrapper formerly duplicated across examples/benchmarks/
            tests: obs of any rank is flattened to (B, -1) before the
            MLP — the paper's "extracted map" vector policy)
  cnn       the paper's conv trunk (configs.paper_cnn), kwargs override
            CNNPolicyConfig fields (conv_sizes, conv_strides, hidden...)
  token     embedding policy over an integer-token observation
  backbone  any assigned LLM architecture (configs.base.get_config) as
            the policy/value network; kwargs: arch, reduced, plus
            ModelConfig field overrides (n_layers, d_model, ...)

    from repro import models
    pol = models.get_policy("mlp", env1)
    params = pol.init(jax.random.key(0))
    out = engine.make_runtime("mesh", env1, pol.apply, params, opt, cfg)
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional


class Policy(NamedTuple):
    name: str
    init: Callable            # key -> params
    apply: Optional[Callable]  # (params, obs) -> (logits (B,A), value (B,))
    config: Any = None        # backing ModelConfig, when one exists


_REGISTRY: Dict[str, Callable[..., Policy]] = {}


def register_policy(name: str):
    """Factory decorator over a ``(env, **kwargs) -> Policy`` callable."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_policy(name: str, env, **kwargs) -> Policy:
    """Build a registered policy sized to ``env``:
    ``get_policy("mlp", env1, hidden=128)``."""
    _load_builtins()
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; "
                       f"registered: {policy_names()}") from None
    return factory(env, **kwargs)


def policy_names():
    _load_builtins()
    return sorted(_REGISTRY)


# ------------------------------------------------------------- built-ins
_BUILTINS_LOADED = False


def _load_builtins() -> None:
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True

    import numpy as np

    @register_policy("mlp")
    def _mlp(env, hidden: int = 128) -> Policy:
        from repro.models.cnn_policy import (apply_mlp_policy,
                                             init_mlp_policy)
        obs_dim = int(np.prod(env.obs_shape))

        def apply(params, obs):
            # THE obs-flattening wrapper (single canonical copy): image
            # or vector observations alike become (B, obs_dim)
            return apply_mlp_policy(params, obs.reshape(obs.shape[0], -1))

        return Policy(
            "mlp",
            lambda key: init_mlp_policy(key, obs_dim, env.n_actions,
                                        hidden),
            apply)

    @register_policy("cnn")
    def _cnn(env, **overrides) -> Policy:
        import dataclasses

        from repro.configs.paper_cnn import CNNPolicyConfig
        from repro.models.cnn_policy import apply_cnn, init_cnn
        # JSON round-trips deliver tuple fields as lists
        overrides = {k: tuple(v) if isinstance(v, list) else v
                     for k, v in overrides.items()}
        ccfg = dataclasses.replace(
            CNNPolicyConfig(obs_shape=env.obs_shape,
                            n_actions=env.n_actions), **overrides)
        return Policy(
            "cnn",
            lambda key: init_cnn(key, ccfg, env.n_actions, env.obs_shape),
            lambda params, obs: apply_cnn(params, obs, ccfg),
            config=ccfg)

    @register_policy("token")
    def _token(env, hidden: int = 128) -> Policy:
        from repro.models.cnn_policy import (apply_token_policy,
                                             init_token_policy)
        return Policy(
            "token",
            lambda key: init_token_policy(key, env.n_actions, hidden),
            apply_token_policy)

    @register_policy("backbone")
    def _backbone(env, arch: str = "starcoder2-3b", reduced: bool = False,
                  **overrides) -> Policy:
        import dataclasses

        from repro.configs.base import get_config
        from repro.models import backbone
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        # apply=None: the backbone is consumed by the LLM-scale learner
        # (core/stream_runtime.py reads .config), not by the per-step
        # actor interface of the small policies
        return Policy(
            "backbone",
            lambda key: backbone.init_params(cfg, key),
            None,
            config=cfg)
