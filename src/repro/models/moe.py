"""Mixture-of-Experts FFN (GShard/Switch-style capacity dispatch).

Top-k routing with deterministic tie-breaking (stable argsort on
(-logit, expert_index)), grouped einsum dispatch so the one-hot dispatch
tensor stays O(tokens * group_size * top_k * capacity_factor) instead of
O(tokens^2 / E) — the grouping is what makes the 1M-token train_4k shape
shardable over the ``data`` mesh axis with experts on ``model``.

Determinism note (HTS-RL): the paper requires *full determinism*; router
top-k uses jax.lax.top_k which breaks ties by lowest index —
deterministic across runs and actor counts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.constraints import constrain


def init_moe(key, cfg: ModelConfig):
    dt = layers.cdtype(cfg)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    s_in, s_out = D ** -0.5, F ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (D, E)) * s_in).astype(jnp.float32),
        "w_in": (jax.random.normal(ks[1], (E, D, F)) * s_in).astype(dt),
        "w_gate": (jax.random.normal(ks[2], (E, D, F)) * s_in).astype(dt),
        "w_out": (jax.random.normal(ks[3], (E, F, D)) * s_out).astype(dt),
    }
    if cfg.shared_expert:
        p["shared"] = layers.init_mlp(ks[4], cfg, d_ff=F)
    return p


def apply_moe(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    G = cfg.moe_group_size
    T = B * S
    xt = x.reshape(T, D)
    # pad token count to a multiple of the group size
    n_groups = -(-T // G)
    pad = n_groups * G - T
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(n_groups, G, D)
    xg = constrain(xg, "batch", None, None)

    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (n, G, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                # (n, G, K)
    if K > 1:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(G * K * cfg.capacity_factor / E))
    # position of each (token, k) inside its expert's capacity slots
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)        # (n,G,K,E)
    flat = onehot.reshape(n_groups, G * K, E)
    slot = jnp.cumsum(flat, axis=1) - flat                       # (n,G*K,E)
    slot = (slot * flat).sum(-1).reshape(n_groups, G, K)         # (n,G,K)
    keep = slot < cap
    # dispatch/combine tensors: (n, G, E, cap)
    disp = (jax.nn.one_hot(gate_idx, E, dtype=x.dtype)[..., None] *
            jax.nn.one_hot(jnp.where(keep, slot, cap), cap + 1,
                           dtype=x.dtype)[..., :cap][..., None, :])
    disp = disp.sum(axis=2)                                      # (n,G,E,cap)
    comb = (gate_vals[..., None, None].astype(x.dtype) *
            jax.nn.one_hot(gate_idx, E, dtype=x.dtype)[..., None] *
            jax.nn.one_hot(jnp.where(keep, slot, cap), cap + 1,
                           dtype=x.dtype)[..., :cap][..., None, :]).sum(axis=2)

    xin = jnp.einsum("ngec,ngd->necd", disp, xg)                 # (n,E,cap,D)
    xin = constrain(xin, "batch", "experts", None, None)

    def expert_ffn(xin_, w_in, w_gate, w_out):
        h = jnp.einsum("necd,edf->necf", xin_, w_in)
        if w_gate is not None:
            g = jnp.einsum("necd,edf->necf", xin_, w_gate)
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
        return jnp.einsum("necf,efd->necd", h, w_out)

    # sub-checkpoint: the (n,E,cap,F) hidden tensor is the largest MoE
    # transient; rematerializing it inside the (already remat'd) block
    # backward halves the simultaneous expert-FFN residency.
    eo = jax.checkpoint(expert_ffn)(xin, params["w_in"],
                                    params.get("w_gate"), params["w_out"])
    eo = constrain(eo, "batch", "experts", None, None)
    y = jnp.einsum("ngec,necd->ngd", comb, eo)                   # (n,G,D)

    if cfg.shared_expert and "shared" in params:
        y = y + layers.apply_mlp(params["shared"], xg, cfg)

    y = y.reshape(n_groups * G, D)[:T].reshape(B, S, D)

    # Switch-style load-balance auxiliary loss
    me = probs.mean(axis=(0, 1))                                 # (E,)
    ce = jax.nn.one_hot(gate_idx[..., 0], E).mean(axis=(0, 1))   # top-1 frac
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight
    return y, aux
