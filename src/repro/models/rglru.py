"""RecurrentGemma / Griffin recurrent block: temporal conv + RG-LRU.

RG-LRU recurrence (per channel):
    r_t = sigmoid(x_t W_a + b_a)               (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)               (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)     (data-dependent decay)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Sequence mode uses ``jax.lax.associative_scan`` (log-depth linear
recurrence — the TPU-native replacement for a GPU sequential kernel);
decode mode is the O(1) single-step update. A chunked Pallas kernel
(``repro.kernels.lru_scan``) implements the same recurrence with explicit
VMEM tiling for the train/prefill shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.constraints import constrain


def init_rglru(key, cfg: ModelConfig):
    dt = layers.cdtype(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 7)
    s = D ** -0.5
    # Lambda init so that a^c in [0.9, 0.999] at r=1 (Griffin appendix)
    lam = jax.random.uniform(ks[0], (D,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    a_param = jnp.log(jnp.expm1(-jnp.log(lam) / (2 * cfg.rglru_c)))  # softplus^-1
    return {
        "w_x_branch": (jax.random.normal(ks[1], (D, D)) * s).astype(dt),
        "w_gate_branch": (jax.random.normal(ks[2], (D, D)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, D)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((D,), jnp.float32),
        "w_a": (jax.random.normal(ks[4], (D, D)) * s).astype(jnp.float32),
        "b_a": jnp.zeros((D,), jnp.float32),
        "w_i": (jax.random.normal(ks[5], (D, D)) * s).astype(jnp.float32),
        "b_i": jnp.zeros((D,), jnp.float32),
        "lambda_param": a_param,
        "w_out": (jax.random.normal(ks[6], (D, D)) * s).astype(dt),
    }


def _gates(params, x, cfg: ModelConfig):
    """a_t (decay) and gated input, both f32. x: (..., D)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"] + params["b_a"])
    i = jax.nn.sigmoid(xf @ params["w_i"] + params["b_i"])
    log_a = -cfg.rglru_c * jax.nn.softplus(params["lambda_param"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) * (i * xf)
    return a, gated


def _combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def rglru_scan(params, x, cfg: ModelConfig, h0=None, chunk: int = 512):
    """x: (B, S, D) -> (y, h_last). Associative linear recurrence, chunked
    into checkpointed segments (the associative-scan backward otherwise
    stores O(S log S) full-width intermediates)."""
    B, S, D = x.shape
    a, b = _gates(params, x, cfg)                       # (B,S,D) f32
    if h0 is not None:
        # fold the initial state in as a virtual step 0 contribution
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    n_chunks = S // chunk

    def chunk_fn(h, ab):
        a_c, b_c = ab                                   # (chunk, B, D)
        b_c = b_c.at[0].add(a_c[0] * h)
        _, hs = jax.lax.associative_scan(_combine, (a_c, b_c), axis=0)
        return hs[-1], hs

    chunk_fn = jax.checkpoint(chunk_fn)
    a_t = constrain(jnp.moveaxis(a, 1, 0).reshape(n_chunks, chunk, B, D),
                    None, None, "batch", "dsq")
    b_t = constrain(jnp.moveaxis(b, 1, 0).reshape(n_chunks, chunk, B, D),
                    None, None, "batch", "dsq")
    h_last, hs = jax.lax.scan(chunk_fn, jnp.zeros((B, D), jnp.float32),
                              (a_t, b_t))
    h = jnp.moveaxis(hs.reshape(S, B, D), 0, 1)
    return h.astype(x.dtype), h_last


def rglru_step(params, x, cfg: ModelConfig, h):
    """One decode step. x: (B, 1, D); h: (B, D) f32."""
    a, b = _gates(params, x[:, 0], cfg)
    h_new = a * h + b
    return h_new.astype(x.dtype)[:, None], h_new


def _causal_conv(params, x, cfg: ModelConfig, conv_cache=None):
    """Depthwise causal temporal conv, width cfg.conv_width.

    x: (B,S,D). conv_cache: (B, width-1, D) previous inputs (decode)."""
    W = cfg.conv_width
    if conv_cache is not None:
        xc = jnp.concatenate([conv_cache.astype(x.dtype), x], axis=1)
    else:
        xc = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = jnp.zeros_like(x, dtype=jnp.float32)
    S = x.shape[1]
    for i in range(W):
        y = y + xc[:, i:i + S].astype(jnp.float32) * params["conv_w"][i].astype(jnp.float32)
    y = y + params["conv_b"]
    new_cache = xc[:, -(W - 1):] if W > 1 else None
    return y.astype(x.dtype), new_cache


def apply_rglru_block(params, x, cfg: ModelConfig, cache=None):
    """Griffin recurrent block. x: (B,S,D).

    cache: {"h": (B,D) f32, "conv": (B, width-1, D)} or None.
    Returns (y, new_cache)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["w_gate_branch"]))
    u = jnp.einsum("bsd,de->bse", x, params["w_x_branch"])
    conv_cache = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(params, u, cfg, conv_cache)
    if cache is not None and x.shape[1] == 1:
        y, h_last = rglru_step(params, u, cfg, cache["h"])
    else:
        h0 = cache["h"] if cache is not None else None
        y, h_last = rglru_scan(params, u, cfg, h0)
    out = jnp.einsum("bse,ed->bsd", gate * y, params["w_out"])
    new_cache = {"h": h_last, "conv": new_conv} if new_conv is not None else {
        "h": h_last}
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int):
    return {
        "h": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_model),
                          layers.cdtype(cfg)),
    }
