"""Composable decoder / encoder-decoder backbone with scan-over-layers.

Layers are grouped into repeating *blocks* (one full mixer/ffn cycle, e.g.
gemma2's (local, global) or recurrentgemma's (rglru, rglru, local)); the
block stack is executed with ``jax.lax.scan`` over stacked parameters so
the HLO size — and therefore compile time on this 1-core container — is
O(1) in depth. Layers left over when n_layers % cycle != 0 (e.g.
recurrentgemma's 38 = 12*3 + 2) are applied unrolled at the end.

Three entry points:
  forward(...)      full-sequence hidden states (train / encoder)
  prefill(...)      full sequence + populated decode caches
  decode_step(...)  one token against caches (serve)

Modality frontends are stubs per the assignment carve-out: ``audio_embeds``
(whisper) and ``patch_embeds`` (qwen2-vl) arrive as precomputed embeddings.
Whisper cross-attention recomputes encoder K/V from the (small, 1500-frame)
encoder output each step instead of caching it — trades 2*S_enc*D*KV*Dh
FLOPs per step for not carrying a per-layer cross cache; at whisper scale
this is <2% of the step cost.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ATTN_FULL, ATTN_LOCAL, RGLRU,
                                RWKV, FFN_MOE)
from repro.models import attention, layers, moe, rglru, rwkv6
from repro.sharding.constraints import constrain


@jax.custom_jvp
def _residual_barrier(x):
    # optimization_barrier has no differentiation rule on the pinned jax;
    # the barrier only constrains scheduling, so its JVP is the identity.
    return jax.lax.optimization_barrier(x)


@_residual_barrier.defjvp
def _residual_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return jax.lax.optimization_barrier(x), t


# ------------------------------------------------------------- layer init
def _init_layer(key, cfg: ModelConfig, mixer_kind: str, ffn_kind: str,
                cross: bool):
    ks = jax.random.split(key, 6)
    p = {"norm1": layers.init_norm(cfg, cfg.d_model),
         "norm2": layers.init_norm(cfg, cfg.d_model)}
    if mixer_kind in (ATTN_FULL, ATTN_LOCAL):
        p["mixer"] = attention.init_attention(ks[0], cfg)
    elif mixer_kind == RGLRU:
        p["mixer"] = rglru.init_rglru(ks[0], cfg)
    elif mixer_kind == RWKV:
        p["mixer"] = rwkv6.init_rwkv6(ks[0], cfg)
    else:
        raise ValueError(mixer_kind)
    if cross:
        p["norm_x"] = layers.init_norm(cfg, cfg.d_model)
        p["xattn"] = attention.init_attention(ks[1], cfg)
    if ffn_kind == FFN_MOE:
        p["ffn"] = moe.init_moe(ks[2], cfg)
    else:
        p["ffn"] = layers.init_mlp(ks[2], cfg)
    return p


def _init_layer_cache(cfg: ModelConfig, mixer_kind: str, batch: int,
                      max_len: int):
    if mixer_kind == ATTN_LOCAL:
        return attention.init_cache(cfg, batch, max_len,
                                    window=cfg.window)
    if mixer_kind == ATTN_FULL:
        return attention.init_cache(cfg, batch, max_len)
    if mixer_kind == RGLRU:
        return rglru.init_rglru_cache(cfg, batch)
    if mixer_kind == RWKV:
        return rwkv6.init_rwkv6_cache(cfg, batch)
    raise ValueError(mixer_kind)


def _apply_layer(p, x, cfg: ModelConfig, kinds, *, positions=None,
                 mrope_positions=None, causal=True, cache=None,
                 cache_pos=None, enc_out=None):
    mixer_kind, ffn_kind = kinds
    h = layers.apply_norm(p["norm1"], x, cfg)
    if mixer_kind in (ATTN_FULL, ATTN_LOCAL):
        out, new_mc = attention.attend(
            p["mixer"], h, cfg, mixer_kind=mixer_kind, positions=positions,
            mrope_positions=mrope_positions, causal=causal, cache=cache,
            cache_pos=cache_pos)
    elif mixer_kind == RGLRU:
        out, new_mc = rglru.apply_rglru_block(p["mixer"], h, cfg, cache=cache)
    elif mixer_kind == RWKV:
        out, new_mc = rwkv6.apply_rwkv6_block(p["mixer"], h, cfg, cache=cache)
    else:
        raise ValueError(mixer_kind)
    x = x + out
    if "xattn" in p and enc_out is not None:
        h = layers.apply_norm(p["norm_x"], x, cfg)
        out, _ = attention.attend(p["xattn"], h, cfg, mixer_kind=ATTN_FULL,
                                  causal=False, kv_override=enc_out)
        x = x + out
    h = layers.apply_norm(p["norm2"], x, cfg)
    if ffn_kind == FFN_MOE:
        if cfg.moe_impl == "dropless":
            from repro.models.moe_dropless import apply_moe_dropless
            out, aux = apply_moe_dropless(p["ffn"], h, cfg)
        else:
            out, aux = moe.apply_moe(p["ffn"], h, cfg)
    else:
        out = layers.apply_mlp(p["ffn"], h, cfg)
        aux = jnp.zeros((), jnp.float32)
    return x + out, new_mc, aux


# ------------------------------------------------------------- blocks
def _block_layout(cfg: ModelConfig):
    cyc = cfg.cycle_len
    n_blocks = cfg.n_layers // cyc
    rem = cfg.n_layers % cyc
    kinds = cfg.layer_kinds
    return cyc, n_blocks, kinds[:cyc], kinds[n_blocks * cyc:]


def init_params(cfg: ModelConfig, key) -> dict:
    cyc, n_blocks, block_kinds, rem_kinds = _block_layout(cfg)
    cross = cfg.is_encoder_decoder
    k_embed, k_blocks, k_rem, k_head, k_enc, k_vp = jax.random.split(key, 6)

    def init_block(k):
        ks = jax.random.split(k, cyc)
        return {f"l{i}": _init_layer(ks[i], cfg, *block_kinds[i], cross)
                for i in range(cyc)}

    params = {
        "embed": layers.init_embed(k_embed, cfg),
        "blocks": jax.vmap(init_block)(jax.random.split(k_blocks, n_blocks)),
        "final_norm": layers.init_norm(cfg, cfg.d_model),
        "lm_head": (jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size))
                    * cfg.d_model ** -0.5).astype(layers.cdtype(cfg)),
        "value_head": jnp.zeros((cfg.d_model, 1), jnp.float32),
    }
    if rem_kinds:
        ks = jax.random.split(k_rem, len(rem_kinds))
        params["rem"] = [
            _init_layer(ks[i], cfg, *rem_kinds[i], cross)
            for i in range(len(rem_kinds))]
    if cfg.is_encoder_decoder:
        kse = jax.random.split(k_enc, cfg.n_enc_layers + 1)

        def init_enc_layer(k):
            return _init_layer(k, cfg, ATTN_FULL, "dense", cross=False)

        params["encoder"] = {
            "layers": jax.vmap(init_enc_layer)(kse[:-1]),
            "final_norm": layers.init_norm(cfg, cfg.d_model),
        }
    return params


def abstract_params(cfg: ModelConfig):
    """Param ShapeDtypeStructs without allocating (for the dry-run)."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.random.key(0))


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int):
    cyc, n_blocks, block_kinds, rem_kinds = _block_layout(cfg)

    def one_block():
        return {f"l{i}": _init_layer_cache(cfg, block_kinds[i][0], batch,
                                           max_len)
                for i in range(cyc)}

    blk = one_block()
    stacked = jax.tree.map(
        lambda a: jnp.zeros((n_blocks,) + a.shape, a.dtype), blk)
    cache = {"blocks": stacked}
    if rem_kinds:
        cache["rem"] = [
            _init_layer_cache(cfg, rk[0], batch, max_len) for rk in rem_kinds]
    return cache


# ------------------------------------------------------------- encoder
def _run_encoder(params, cfg: ModelConfig, audio_embeds):
    enc = params["encoder"]

    def body(x, lp):
        x, _, _ = _apply_layer(lp, x, cfg, (ATTN_FULL, "dense"), causal=False)
        return x, None

    x, _ = jax.lax.scan(body, audio_embeds, enc["layers"])
    return layers.apply_norm(enc["final_norm"], x, cfg)


# ------------------------------------------------------------- main paths
def _embed_inputs(params, cfg: ModelConfig, tokens, patch_embeds):
    x = layers.apply_embed(params["embed"], tokens) * math.sqrt(cfg.d_model)
    x = x.astype(layers.cdtype(cfg))
    if cfg.vision_prefix and patch_embeds is not None:
        x = jax.lax.dynamic_update_slice(
            x, patch_embeds.astype(x.dtype), (0, 0, 0))
    return x


def forward(params, cfg: ModelConfig, tokens, *, positions=None,
            mrope_positions=None, patch_embeds=None, audio_embeds=None,
            enc_out=None, cache=None, cache_pos=None, remat=False):
    """Full-sequence (cache=None), prefill (cache given, S>1) or decode
    (cache given, S==1, cache_pos given).

    Returns (hidden (B,S,D), new_cache, aux_loss)."""
    cyc, n_blocks, block_kinds, rem_kinds = _block_layout(cfg)
    if enc_out is None and cfg.is_encoder_decoder and audio_embeds is not None:
        enc_out = _run_encoder(params, cfg, audio_embeds)

    x = _embed_inputs(params, cfg, tokens, patch_embeds)
    x = constrain(x, "batch", "seq_model", None)
    lkw = dict(positions=positions, mrope_positions=mrope_positions,
               cache_pos=cache_pos, enc_out=enc_out)

    def apply_block(x, bp, bc):
        x = constrain(x, "batch", "seq_model", None)
        aux = jnp.zeros((), jnp.float32)
        new_bc = {}
        for i in range(cyc):
            lc = bc[f"l{i}"] if bc is not None else None
            x, nmc, a = _apply_layer(bp[f"l{i}"], x, cfg, block_kinds[i],
                                     cache=lc, **lkw)
            new_bc[f"l{i}"] = nmc
            aux = aux + a
        return x, new_bc, aux

    if cache is None:
        def blk(x, bp):
            # barrier: keeps XLA from hoisting the residual's bf16->f32
            # conversion (first op of the norm) out of the backward loop,
            # which would materialize a second, f32 copy of the entire
            # stacked per-block residual.
            x = _residual_barrier(x)
            y, _, a = apply_block(x, bp, None)
            return y, a

        if remat:
            # per-block rematerialization: the backward pass recomputes the
            # block instead of storing its intermediates — mandatory for
            # the 80-layer x 1M-token training shapes.
            blk = jax.checkpoint(blk)

        def body(carry, bp):
            x, aux = carry
            x, a = blk(x, bp)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
        new_cache = None
    else:
        def body(carry, xs):
            x, aux = carry
            bp, bc = xs
            x, nbc, a = apply_block(x, bp, bc)
            return (x, aux + a), nbc

        (x, aux), new_blocks = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": new_blocks}

    if rem_kinds:
        new_rem = []
        for i, lp in enumerate(params["rem"]):
            lc = cache["rem"][i] if cache is not None else None
            x, nmc, a = _apply_layer(lp, x, cfg, rem_kinds[i], cache=lc, **lkw)
            new_rem.append(nmc)
        if cache is not None:
            new_cache["rem"] = new_rem

    x = layers.apply_norm(params["final_norm"], x, cfg)
    return x, new_cache, (aux if cache is None else jnp.zeros((), jnp.float32))


def logits_and_value(params, cfg: ModelConfig, hidden):
    """(policy/LM logits (B,S,V) f32, value (B,S) f32)."""
    logits = jnp.einsum("bsd,dv->bsv", hidden,
                        params["lm_head"]).astype(jnp.float32)
    logits = layers.softcap(logits, cfg.final_softcap)
    value = jnp.einsum("bsd,dk->bsk", hidden.astype(jnp.float32),
                       params["value_head"])[..., 0]
    return logits, value


# ------------------------------------------------------------- serve API
def prefill(params, cfg: ModelConfig, tokens, max_len: int, **kw):
    """Build decode caches from a full prompt. Returns (logits_last, value_last, cache)."""
    B, S = tokens.shape
    cache = init_decode_cache(cfg, B, max_len)
    hidden, cache, _ = forward(params, cfg, tokens, cache=cache, **kw)
    logits, value = logits_and_value(params, cfg, hidden[:, -1:])
    return logits[:, 0], value[:, 0], cache


def decode_step(params, cfg: ModelConfig, token, cache, pos, *,
                mrope_positions=None, audio_embeds=None, enc_out=None):
    """token: (B,1) int32; pos: scalar int32 position. Returns
    (logits (B,V), value (B,), new_cache)."""
    B = token.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1))
    hidden, new_cache, _ = forward(
        params, cfg, token, positions=positions,
        mrope_positions=mrope_positions, audio_embeds=audio_embeds,
        enc_out=enc_out, cache=cache, cache_pos=pos)
    logits, value = logits_and_value(params, cfg, hidden)
    return logits[:, 0], value[:, 0], new_cache
