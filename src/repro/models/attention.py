"""GQA attention: full / sliding-window / local-global, RoPE / M-RoPE / NoPE.

The full-sequence path (train / prefill) is a blocked flash-style
attention written in pure jnp (``lax.scan`` over query and key blocks with
an online softmax). This keeps the peak live score tensor at
(B, H, q_block, k_block) instead of (B, H, S, S) — mandatory for the 32k
prefill shape to fit the per-device memory budget, and it doubles as the
oracle structure mirrored by the Pallas kernel in
``repro.kernels.flash_attention``.

Decode (one query token against a cache) uses a direct einsum — the score
tensor is (B, H, 1, S), which is small even at S=512k.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.constraints import constrain

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig):
    dt = layers.cdtype(cfg)
    dh = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = cfg.d_model ** -0.5
    return {
        "wq": (jax.random.normal(k1, (cfg.d_model, cfg.n_heads, dh)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (cfg.d_model, cfg.n_kv_heads, dh)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (cfg.d_model, cfg.n_kv_heads, dh)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (cfg.n_heads, dh, cfg.d_model))
               * (cfg.n_heads * dh) ** -0.5).astype(dt),
    }


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _tile_mask(qpos, kpos, k_valid, causal: bool, window: int):
    mask = k_valid[None, :]
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    return mask                       # (qb, kb)


def _tile_penalty(qpos, kpos, k_valid, causal: bool, window: int):
    """(qb, kb) f32 additive mask: 0 where attendable, NEG_INF where not.

    Kept at (qb, kb) — never broadcast to the full (B,G,R,qb,kb) tile — so
    when scan partial-eval hoists this data-independent value out of the
    backward, the stacked residual is a few MB of per-tile penalties, not
    an O(S^2 * B * H) constant broadcast."""
    mask = _tile_mask(qpos, kpos, k_valid, causal, window)
    return jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)


def _flash_fwd(qp, kp, vp, q_pos, k_pos, k_valid, causal, window, cap,
               scale):
    """qp: (B,nq,qb,G,R,Dh); kp/vp: (B,nk,kb,G,Dh).

    Returns out (nq,B,G,R,qb,Dh) f32 and lse (nq,B,G,R,qb) f32."""
    B, nq, q_block, G, R, Dh = qp.shape
    nk, k_block = kp.shape[1], kp.shape[2]

    def q_step(_, qi):
        qblk = qp[:, qi]
        qpos = q_pos[qi]

        def k_step(carry, ki):
            m, l, acc = carry
            kblk, vblk = kp[:, ki], vp[:, ki]
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if cap:
                s = layers.softcap(s, cap)
            pen = _tile_penalty(qpos, k_pos[ki], k_valid[ki], causal,
                                window)
            s = s + pen[None, None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, G, R, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, R, q_block), jnp.float32)
        a0 = jnp.zeros((B, G, R, q_block, Dh), jnp.float32)
        if causal:
            hi = (qi * q_block + q_block + k_block - 1) // k_block
            hi = jnp.minimum(hi, nk)
        else:
            hi = nk
        if window and causal:
            # sliding window: only ~window/k_block kv blocks can be
            # visible to this q block — iterate exactly those (the trip
            # count itself shrinks: 8x fewer iterations for h2o's
            # 4096-window 32k prefill, honest in both wall-clock and the
            # HLO cost model). Only valid with causal masking: a
            # non-causal window still admits unbounded future keys.
            lo = jnp.maximum((qi * q_block - window) // k_block, 0)
            nk_win = min(nk, (window + q_block) // k_block + 1)
            ks = lo + jnp.arange(nk_win)
        elif window:
            lo = jnp.maximum((qi * q_block - window) // k_block, 0)
            ks = jnp.arange(nk)
        else:
            lo = 0
            ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            lambda c, ki: jax.lax.cond((ki < hi) & (ki >= lo), k_step,
                                       lambda c2, _ki: (c2, None), c, ki),
            (m0, l0, a0), ks)
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    return outs, lses


def _make_flash(causal: bool, window: int, cap: float, q_block: int,
                k_block: int):
    """Flash attention with a flash backward (custom_vjp): the backward
    pass recomputes each (q_block x k_block) probability tile from
    (q, k, v, lse) instead of storing O(S^2) score tensors — without this,
    differentiating the forward scans stores every tile and the train_4k
    shapes need TBs per chip."""

    def fwd_public(qp, kp, vp, q_pos, k_pos, k_valid, scale):
        outs, _ = _flash_fwd(qp, kp, vp, q_pos, k_pos, k_valid, causal,
                             window, cap, scale)
        return outs

    @jax.custom_vjp
    def flash(qp, kp, vp, q_pos, k_pos, k_valid, scale):
        return fwd_public(qp, kp, vp, q_pos, k_pos, k_valid, scale)

    def flash_fwd(qp, kp, vp, q_pos, k_pos, k_valid, scale):
        outs, lses = _flash_fwd(qp, kp, vp, q_pos, k_pos, k_valid, causal,
                                window, cap, scale)
        return outs, (qp, kp, vp, outs, lses, q_pos, k_pos, k_valid, scale)

    def _tile_ds(qblk, kblk, dout_q, vblk, lse_q, Dvec, qpos, kpos, kval,
                 scale):
        """Recompute one probability tile and its score gradient."""
        s_pre = jnp.einsum("bqgrd,bkgd->bgrqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
        s = layers.softcap(s_pre, cap) if cap else s_pre
        pen = _tile_penalty(qpos, kpos, kval, causal, window)
        # exp(NEG_INF - lse) underflows to exactly 0 -> masked entries drop
        p = jnp.exp(s + pen[None, None, None] - lse_q[..., None])
        dp = jnp.einsum("bgrqd,bkgd->bgrqk", dout_q, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - Dvec[..., None])
        if cap:
            ds = ds * (1.0 - jnp.square(s / cap))
        ds = ds * scale
        return p, ds

    def flash_bwd(res, douts):
        qp, kp, vp, outs, lses, q_pos, k_pos, k_valid, scale = res
        # Tie the recompute to the cotangent: without this barrier, the
        # scan-transpose partial-eval notices that the probability tiles
        # depend only on primal residuals, hoists their recomputation into
        # the *forward* pass, and stacks every (q,k) tile as a scan
        # residual — exactly the O(S^2) memory the flash backward exists
        # to avoid.
        (douts, qp, kp, vp, outs, lses, q_pos, k_pos, k_valid) = \
            jax.lax.optimization_barrier(
                (douts, qp, kp, vp, outs, lses, q_pos, k_pos, k_valid))
        B, nq, q_block, G, R, Dh = qp.shape
        nk, k_block = kp.shape[1], kp.shape[2]
        # D_i = rowsum(dout * out): (nq, B, G, R, qb)
        Dv = jnp.sum(douts * outs, axis=-1)

        def q_pass(_, qi):
            qblk = qp[:, qi]
            dout_q = douts[qi]
            lse_q = lses[qi]
            D_q = Dv[qi]
            qpos = q_pos[qi]

            def k_step(dq_acc, ki):
                p, ds = _tile_ds(qblk, kp[:, ki], dout_q, vp[:, ki], lse_q,
                                 D_q, qpos, k_pos[ki], k_valid[ki], scale)
                dq_acc = dq_acc + jnp.einsum(
                    "bgrqk,bkgd->bqgrd", ds, kp[:, ki],
                    preferred_element_type=jnp.float32)
                return dq_acc, None

            if causal:
                hi = (qi * q_block + q_block + k_block - 1) // k_block
                hi = jnp.minimum(hi, nk)
            else:
                hi = nk
            if window and causal:
                lo = jnp.maximum((qi * q_block - window) // k_block, 0)
                nk_win = min(nk, (window + q_block) // k_block + 1)
                ks = lo + jnp.arange(nk_win)
            elif window:
                lo = jnp.maximum((qi * q_block - window) // k_block, 0)
                ks = jnp.arange(nk)
            else:
                lo = 0
                ks = jnp.arange(nk)
            dq0 = jnp.zeros((B, q_block, G, R, Dh), jnp.float32)
            dq, _ = jax.lax.scan(
                lambda c, ki: jax.lax.cond(
                    (ki < hi) & (ki >= lo), k_step,
                    lambda c2, _ki: (c2, None), c, ki),
                dq0, ks)
            return None, dq

        _, dq = jax.lax.scan(q_pass, None, jnp.arange(nq))
        dq = jnp.moveaxis(dq, 0, 1)          # (B, nq, qb, G, R, Dh)

        def kv_pass(_, ki):
            kblk, vblk = kp[:, ki], vp[:, ki]
            kpos = k_pos[ki]
            kval = k_valid[ki]

            def q_step(carry, qi):
                dk_acc, dv_acc = carry
                p, ds = _tile_ds(qp[:, qi], kblk, douts[qi], vblk, lses[qi],
                                 Dv[qi], q_pos[qi], kpos, kval, scale)
                dv_acc = dv_acc + jnp.einsum(
                    "bgrqk,bgrqd->bkgd", p, douts[qi],
                    preferred_element_type=jnp.float32)
                dk_acc = dk_acc + jnp.einsum(
                    "bgrqk,bqgrd->bkgd", ds, qp[:, qi],
                    preferred_element_type=jnp.float32)
                return (dk_acc, dv_acc), None

            if causal:
                lo = (ki * k_block) // q_block
            else:
                lo = 0
            if window and causal:
                # queries past ki*kb + window can't see this kv block
                # (causal only: non-causal windows admit future queries)
                hi_q = jnp.minimum(
                    (ki * k_block + k_block - 1 + window) // q_block + 1,
                    nq)
                nq_win = min(nq, (window + k_block) // q_block + 2)
                qs = jnp.maximum(hi_q - nq_win, 0) + jnp.arange(nq_win)
            else:
                hi_q = nq
                qs = jnp.arange(nq)
            z = jnp.zeros((B, k_block, G, Dh), jnp.float32)
            (dk, dv), _ = jax.lax.scan(
                lambda c, qi: jax.lax.cond(
                    (qi >= lo) & (qi < hi_q), q_step,
                    lambda c2, _qi: (c2, None), c, qi),
                (z, z), qs)
            return None, (dk, dv)

        _, (dk, dv) = jax.lax.scan(kv_pass, None, jnp.arange(nk))
        dk = jnp.moveaxis(dk, 0, 1)
        dv = jnp.moveaxis(dv, 0, 1)
        return (dq.astype(qp.dtype), dk.astype(kp.dtype),
                dv.astype(vp.dtype), None, None, None, None)

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


_FLASH_CACHE: dict = {}


def blocked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      cap: float = 0.0, q_offset=0,
                      q_block: int = 512, k_block: int = 1024,
                      kv_len: Optional[jnp.ndarray] = None,
                      tp_mode: str = "auto"):
    """Flash-style blocked attention with a flash backward.

    q: (B, Sq, H, Dh); k, v: (B, Sk, KV, Dh). GQA handled by grouping query
    heads (no materialized KV repeat). Returns (B, Sq, H, Dh).

    window > 0 masks keys older than ``window`` positions behind the query.
    kv_len (optional scalar) masks keys at positions >= kv_len.
    """
    B, Sq, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = KV
    R = H // KV
    scale = Dh ** -0.5

    q_block = min(q_block, Sq)
    k_block = min(k_block, Sk)
    nq = -(-Sq // q_block)
    nk = -(-Sk // k_block)
    qp = _pad_to(q, nq * q_block, 1).reshape(B, nq, q_block, G, R, Dh)
    kp = _pad_to(k, nk * k_block, 1).reshape(B, nk, k_block, G, Dh)
    vp = _pad_to(v, nk * k_block, 1).reshape(B, nk, k_block, G, Dh)

    q_pos = (jnp.arange(nq * q_block) + q_offset).reshape(nq, q_block)
    k_pos = jnp.arange(nk * k_block).reshape(nk, k_block)
    k_valid = (k_pos < (Sk if kv_len is None else kv_len))

    if tp_mode == "replicate":
        qp = constrain(qp, "batch")
        kp = constrain(kp, "batch")
        vp = constrain(vp, "batch")
    else:
        qp = constrain(qp, "batch", None, None, "kv_heads", None,
                       "head_dim")
        kp = constrain(kp, "batch", None, None, "kv_heads", "head_dim")
        vp = constrain(vp, "batch", None, None, "kv_heads", "head_dim")

    key = (causal, window, cap, q_block, k_block)
    if key not in _FLASH_CACHE:
        _FLASH_CACHE[key] = _make_flash(*key)
    outs = _FLASH_CACHE[key](qp, kp, vp, q_pos, k_pos, k_valid, scale)

    out = jnp.moveaxis(outs.astype(q.dtype), 0, 1)        # (B,nq,G,R,qb,Dh)
    out = jnp.transpose(out, (0, 1, 4, 2, 3, 5)).reshape(
        B, nq * q_block, H, Dh)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, *, pos, window: int = 0,
                     cap: float = 0.0):
    """One-token attention against a cache.

    q: (B, 1, H, Dh); caches: (B, S, KV, Dh); pos: scalar index of the
    current token (cache entries at >= pos+1 are invalid).
    """
    B, _, H, Dh = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    R = H // KV
    qg = q.reshape(B, KV, R, Dh)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, k_cache,
                   preferred_element_type=jnp.float32) * Dh ** -0.5
    if cap:
        s = layers.softcap(s, cap)
    kpos = jnp.arange(S)
    mask = kpos <= pos
    if window:
        mask = mask & (kpos > pos - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


def attend(params, x, cfg: ModelConfig, *, mixer_kind: str,
           positions=None, mrope_positions=None, causal=True,
           cache=None, cache_pos=None, kv_override=None):
    """Full attention layer: qkv proj, rope, blocked/decode attention, out proj.

    cache: dict(k, v) of (B, S_cache, KV, Dh) -> decode/one-step mode when
    x has sequence length 1 and cache_pos is given. Returns (out, new_cache).
    kv_override: (B, S_enc, d_model) encoder states for cross-attention.
    """
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    window = cfg.window if mixer_kind == "attn_local" else 0
    use_rope = (cfg.rope_on_global or mixer_kind == "attn_local")

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    kin = x if kv_override is None else kv_override
    k = jnp.einsum("bsd,dhk->bshk", kin, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kin, params["wv"])

    if positions is None:
        positions = jnp.arange(S)[None, :]
    if use_rope and kv_override is None:
        if cfg.mrope and mrope_positions is not None:
            q = layers.apply_mrope(q, mrope_positions, cfg.rope_theta)
            k = layers.apply_mrope(k, mrope_positions, cfg.rope_theta)
        else:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)

    def _full_attn(q_, k_, v_, causal_, window_):
        # TP head-repeat: materialize GQA so attention is head-parallel
        # (applies to the compute path only — caches keep GQA size)
        if cfg.attn_tp_repeat:
            R_ = cfg.n_heads // cfg.n_kv_heads
            if R_ > 1 and k_.shape[2] != cfg.n_heads:
                k_ = jnp.repeat(k_, R_, axis=2)
                v_ = jnp.repeat(v_, R_, axis=2)
        if cfg.use_pallas_attention:
            from repro.kernels.flash_attention import ops as fa_ops
            return fa_ops.attend(q_, k_, v_, causal=causal_,
                                 window=window_, cap=cfg.attn_softcap)
        return blocked_attention(
            q_, k_, v_, causal=causal_, window=window_,
            cap=cfg.attn_softcap,
            tp_mode="replicate" if cfg.attn_replicate_tp else "auto")

    if kv_override is not None:
        # cross-attention: bidirectional, no cache (encoder kv recomputed —
        # see backbone docstring for the cost note)
        if S == 1:
            out = decode_attention(q, k, v, pos=k.shape[1] - 1,
                                   cap=cfg.attn_softcap)
        else:
            out = _full_attn(q, k, v, False, 0)
        new_cache = cache
    elif cache is not None and cache_pos is not None and S == 1:
        # decode: write current k/v into the cache, attend over it.
        # Ring mode (local layers, cache length == window): the write slot
        # is pos % window and no extra window masking is needed — entries
        # age out by being overwritten.
        W = cache["k"].shape[1]
        ring = bool(window) and W == window
        slot = jax.lax.rem(cache_pos, W) if ring else cache_pos
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        new_cache = {"k": kc, "v": vc}
        out = decode_attention(
            q, kc, vc,
            pos=jnp.minimum(cache_pos, W - 1) if ring else cache_pos,
            window=0 if ring else window, cap=cfg.attn_softcap)
    else:
        out = _full_attn(q, k, v, causal, window)
        new_cache = cache
        if cache is not None:
            # prefill: populate cache
            W = cache["k"].shape[1]
            ring = bool(window) and W == window
            if ring and S >= W:
                # last W entries land at slots (abs_pos % W): a roll
                kc = jnp.roll(k[:, -W:], shift=S % W, axis=1)
                vc = jnp.roll(v[:, -W:], shift=S % W, axis=1)
                new_cache = {"k": kc.astype(cache["k"].dtype),
                             "v": vc.astype(cache["v"].dtype)}
            else:
                kc = jax.lax.dynamic_update_slice(cache["k"], k,
                                                  (0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(cache["v"], v,
                                                  (0, 0, 0, 0))
                new_cache = {"k": kc, "v": vc}

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               window: int = 0):
    """window > 0 with cfg.ring_cache -> ring cache of exactly ``window``
    entries (local-attention layers never need more)."""
    dh = cfg.resolved_head_dim
    dt = dtype or layers.cdtype(cfg)
    length = max_len
    if window and cfg.ring_cache and window < max_len:
        length = window
    return {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, dh), dt),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, dh), dt),
    }
