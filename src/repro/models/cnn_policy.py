"""The paper's policy network (appendix F): conv 32x8x8/4, conv 64x4x4/2,
conv 64x3x3/1, fc 512, then policy + value heads. Also a small MLP policy
for vector observations (mini-football "extracted map") and a tabular
embedding policy for the token env.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_cnn import CNNPolicyConfig


def _conv_out(n, k, s):
    return (n - k) // s + 1


def init_cnn(key, cfg: CNNPolicyConfig, n_actions: int,
             obs_shape: Tuple[int, ...]):
    ks = jax.random.split(key, 8)
    h, w, cin = obs_shape
    params = {}
    for i, (f, k, s) in enumerate(zip(cfg.conv_filters, cfg.conv_sizes,
                                      cfg.conv_strides)):
        fan_in = k * k * cin
        params[f"conv{i}_w"] = jax.random.normal(
            ks[i], (k, k, cin, f)) * math.sqrt(2.0 / fan_in)
        params[f"conv{i}_b"] = jnp.zeros((f,))
        h, w, cin = _conv_out(h, k, s), _conv_out(w, k, s), f
    flat = h * w * cin
    params["fc_w"] = jax.random.normal(ks[5], (flat, cfg.hidden)) * \
        math.sqrt(2.0 / flat)
    params["fc_b"] = jnp.zeros((cfg.hidden,))
    params["pi_w"] = jax.random.normal(ks[6], (cfg.hidden, n_actions)) * 0.01
    params["pi_b"] = jnp.zeros((n_actions,))
    params["v_w"] = jax.random.normal(ks[7], (cfg.hidden, 1)) * 1.0
    params["v_b"] = jnp.zeros((1,))
    return params


def apply_cnn(params, obs, cfg: CNNPolicyConfig):
    """obs: (B, H, W, C) -> (logits (B, A), value (B,))."""
    x = obs.astype(jnp.float32)
    for i, s in enumerate(cfg.conv_strides):
        x = jax.lax.conv_general_dilated(
            x, params[f"conv{i}_w"], window_strides=(s, s), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + params[f"conv{i}_b"]
        x = jax.nn.relu(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc_w"] + params["fc_b"])
    logits = x @ params["pi_w"] + params["pi_b"]
    value = (x @ params["v_w"] + params["v_b"])[:, 0]
    return logits, value


def init_mlp_policy(key, obs_dim: int, n_actions: int, hidden: int = 128):
    ks = jax.random.split(key, 4)
    return {
        "w1": jax.random.normal(ks[0], (obs_dim, hidden)) * math.sqrt(2.0 / obs_dim),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(ks[1], (hidden, hidden)) * math.sqrt(2.0 / hidden),
        "b2": jnp.zeros((hidden,)),
        "pi_w": jax.random.normal(ks[2], (hidden, n_actions)) * 0.01,
        "pi_b": jnp.zeros((n_actions,)),
        "v_w": jax.random.normal(ks[3], (hidden, 1)),
        "v_b": jnp.zeros((1,)),
    }


def apply_mlp_policy(params, obs):
    x = obs.astype(jnp.float32)
    if x.ndim == 1:
        x = x[None]
    x = jax.nn.tanh(x @ params["w1"] + params["b1"])
    x = jax.nn.tanh(x @ params["w2"] + params["b2"])
    logits = x @ params["pi_w"] + params["pi_b"]
    value = (x @ params["v_w"] + params["v_b"])[:, 0]
    return logits, value


def init_token_policy(key, vocab: int, hidden: int = 128):
    ks = jax.random.split(key, 3)
    return {
        "embed": jax.random.normal(ks[0], (vocab, hidden)) * 0.1,
        "w": jax.random.normal(ks[1], (hidden, hidden)) * math.sqrt(2.0 / hidden),
        "b": jnp.zeros((hidden,)),
        "pi_w": jax.random.normal(ks[2], (hidden, vocab)) * 0.01,
        "pi_b": jnp.zeros((vocab,)),
        "v_w": jnp.zeros((hidden, 1)),
        "v_b": jnp.zeros((1,)),
    }


def apply_token_policy(params, obs):
    """obs: (B,) int32 tokens."""
    x = params["embed"][obs]
    x = jax.nn.tanh(x @ params["w"] + params["b"])
    logits = x @ params["pi_w"] + params["pi_b"]
    value = (x @ params["v_w"] + params["v_b"])[:, 0]
    return logits, value
