"""Dropless MoE via sort + ``jax.lax.ragged_dot`` under ``shard_map``.

The capacity-based GShard dispatch (models/moe.py) drops tokens when an
expert overflows its capacity slots and burns FLOPs on padding. The
dropless formulation routes *every* token:

    1. top-k expert choice per token (deterministic tie-break),
    2. stable sort of the (token, k) pairs by expert id,
    3. one grouped matmul per weight via ``ragged_dot``
       (lhs (M, D), rhs (E, D, F), group_sizes (E,)),
    4. unsort + combine with the gate weights.

Under SPMD a global sort would all-to-all the whole token stream, so the
sort/ragged_dot runs **per data shard** inside ``shard_map`` (each shard
routes its own tokens through replicated-or-gathered expert weights —
expert weights are gathered once per layer instead of tokens being
permuted globally). This is the Megablocks-style trade: dispatch-tensor
free, no capacity hyperparameter, exact top-k semantics.

Selectable per-config with ``moe_impl="dropless"`` (default "capacity" is
the paper-era GShard formulation, kept as the baseline).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.constraints import _active_mesh


def _dropless_local(x, router_w, w_in, w_gate, w_out, *, n_experts: int,
                    top_k: int, mlp_kind: str, aux_weight: float):
    """One shard's tokens through all experts. x: (T, D) bf16."""
    T, D = x.shape
    E, K = n_experts, top_k
    logits = x.astype(jnp.float32) @ router_w                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # (T, K)
    if K > 1:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_expert = gate_idx.reshape(-1)                         # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_expert, stable=True)              # (T*K,)
    sorted_tokens = flat_token[order]
    xs = x[sorted_tokens]                                      # (T*K, D)
    group_sizes = jnp.bincount(flat_expert, length=E).astype(jnp.int32)

    h = jax.lax.ragged_dot(xs, w_in, group_sizes)              # (T*K, F)
    if w_gate is not None:
        g = jax.lax.ragged_dot(xs, w_gate, group_sizes)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    eo = jax.lax.ragged_dot(h, w_out, group_sizes)             # (T*K, D)

    # unsort and combine with gates
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    eo = eo[inv].reshape(T, K, D)
    y = jnp.einsum("tkd,tk->td", eo.astype(jnp.float32),
                   gate_vals).astype(x.dtype)

    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(gate_idx[:, 0], E).mean(axis=0)
    aux = E * jnp.sum(me * ce) * aux_weight
    return y, aux


def apply_moe_dropless(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (y, aux). Routes per data shard under shard_map
    when a mesh is active; plain local computation otherwise."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    w_gate = params.get("w_gate")
    fn = functools.partial(
        _dropless_local, n_experts=cfg.n_experts, top_k=cfg.top_k,
        mlp_kind=cfg.mlp_kind, aux_weight=cfg.router_aux_weight)

    am = _active_mesh()
    data_axes = tuple(a for a in ("pod", "data")
                      if am is not None and a in am.axis_names)
    total = 1
    for a in data_axes:
        total *= am.shape[a]
    if am is not None and data_axes and (B * S) % total == 0:
        spec_tok = P(data_axes if len(data_axes) > 1 else data_axes[0])
        rep = P()

        @functools.partial(
            jax.shard_map, mesh=am,
            in_specs=(spec_tok, rep, rep, rep, rep),
            out_specs=(spec_tok, rep),
            check_vma=False)
        def sharded(xt_, rw, wi, wg, wo):
            y, aux = fn(xt_, rw, wi, wg, wo)
            return y, jax.lax.pmean(aux, data_axes)

        y, aux = sharded(xt, params["router"], params["w_in"], w_gate,
                         params["w_out"])
    else:
        y, aux = fn(xt, params["router"], params["w_in"], w_gate,
                    params["w_out"])

    y = y.reshape(B, S, D)
    if cfg.shared_expert and "shared" in params:
        y = y + layers.apply_mlp(params["shared"], x, cfg)
    return y, aux
