"""Open-loop Poisson load generator for a PolicyServer session.

Open-loop means arrivals follow their own clock regardless of
completions — the arrival process does not slow down when the server
falls behind, so queueing delay shows up IN the latency numbers instead
of silently throttling the load (closed-loop generators hide exactly
the overload behavior a p99 is supposed to expose). Latency for request
i runs from its SCHEDULED arrival to the resolution of its future:
admission wait + queue + dispatch + scatter.

Deterministic by construction: arrival gaps come from a seeded
generator, observations from the env's reset distribution under seeded
keys, and request seeds are the request index — replaying the generator
replays the exact action stream (the serving determinism contract,
DESIGN.md §10).

``repro.launch.serve --spec`` and ``benchmarks/serve_bench.py`` are
both thin wrappers over ``run``.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np
import jax


def run(spec, requests: int = 400, rate: float = 2000.0, seed: int = 0,
        checkpoint: Optional[str] = None, warmup: int = 64) -> dict:
    """Build ``spec``'s session, serve it (loading ``checkpoint`` or the
    spec's newest capsule), drive ``requests`` Poisson arrivals at
    ``rate`` req/s, and return::

        {"serve_qps": ..., "serve_p50_ms": ..., "serve_p99_ms": ...,
         "serve_mean_batch": ...}
    """
    from repro import api
    session = api.build(spec)
    server = session.serve(checkpoint=checkpoint)
    try:
        # distinct observations from the env's reset distribution,
        # pre-generated so generation cost never pollutes latency
        n_obs = min(max(requests, 1), 512)
        _, obs = jax.vmap(session.env.reset)(
            jax.random.split(jax.random.key(seed), n_obs))
        obs = np.asarray(obs)
        for i in range(min(warmup, requests)):      # steady-state warmup
            server.act(obs[i % n_obs], seed=1_000_000 + i)

        rng = np.random.RandomState(seed)
        arrive = np.cumsum(rng.exponential(1.0 / rate, size=requests))
        done_at = np.zeros(requests)
        futures = []
        t0 = time.perf_counter()
        for i in range(requests):
            delay = (t0 + arrive[i]) - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            fut = server.submit(obs[i % n_obs], seed=i)

            def _done(_fut, i=i):
                done_at[i] = time.perf_counter()
            fut.add_done_callback(_done)
            futures.append(fut)
        for fut in futures:
            fut.result(timeout=120)
        stats = server.stats()
    finally:
        server.stop()
    latency_ms = (done_at - (t0 + arrive)) * 1e3
    wall = max(float(done_at.max()) - t0, 1e-9)
    p50, p99 = np.percentile(latency_ms, [50, 99])
    return {
        "serve_qps": requests / wall,
        "serve_p50_ms": float(p50),
        "serve_p99_ms": float(p99),
        "serve_mean_batch": stats["mean_batch"],
    }
