"""Open-loop Poisson load generator for a PolicyServer session.

Open-loop means arrivals follow their own clock regardless of
completions — the arrival process does not slow down when the server
falls behind, so queueing delay shows up IN the latency numbers instead
of silently throttling the load (closed-loop generators hide exactly
the overload behavior a p99 is supposed to expose). Latency for request
i runs from its SCHEDULED arrival to the resolution of its future:
admission wait + queue + dispatch + scatter.

Deterministic by construction: arrival gaps come from a seeded
generator, observations from the env's reset distribution under seeded
keys, and request seeds are the request index — replaying the generator
replays the exact action stream (the serving determinism contract,
DESIGN.md §10).

Degradation-aware (DESIGN.md §11): with ``retry > 0`` submissions go
through ``submit(block=False)`` and an ``Overloaded`` shed is retried
up to ``retry`` times with seeded-jitter exponential backoff (jitter
decorrelates retry storms; the seed keeps the replay deterministic).
Requests the server sheds with a typed error (``Overloaded`` after
retries, ``DeadlineExceeded``, ``DispatcherError``) are COUNTED, not
crashed on — the paper-style numbers are computed over the answered
requests and the shed counts ride along in the result dict.

``repro.launch.serve --spec`` and ``benchmarks/serve_bench.py`` are
both thin wrappers over ``run``.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np
import jax


def run(spec, requests: int = 400, rate: float = 2000.0, seed: int = 0,
        checkpoint: Optional[str] = None, warmup: int = 64,
        retry: int = 0, retry_backoff_ms: float = 2.0) -> dict:
    """Build ``spec``'s session, serve it (loading ``checkpoint`` or the
    spec's newest capsule), drive ``requests`` Poisson arrivals at
    ``rate`` req/s, and return::

        {"serve_qps": ..., "serve_p50_ms": ..., "serve_p99_ms": ...,
         "serve_mean_batch": ..., "serve_shed": ..., "serve_restarts": ...}
    """
    from repro import api
    from repro.serve.server import (DeadlineExceeded, DispatcherError,
                                    Overloaded, ServerClosed)
    session = api.build(spec)
    server = session.serve(checkpoint=checkpoint)
    rng = np.random.RandomState(seed)

    def _submit(ob, request_seed):
        if not retry:
            return server.submit(ob, seed=request_seed)
        for attempt in range(retry + 1):
            try:
                return server.submit(ob, seed=request_seed, block=False)
            except Overloaded:
                if attempt == retry:
                    raise
                # exponential backoff with seeded jitter in [0.5, 1.5):
                # decorrelates a retry storm without losing replayability
                delay_ms = retry_backoff_ms * (2 ** attempt)
                time.sleep(delay_ms * (0.5 + rng.uniform()) / 1e3)

    try:
        # distinct observations from the env's reset distribution,
        # pre-generated so generation cost never pollutes latency
        n_obs = min(max(requests, 1), 512)
        _, obs = jax.vmap(session.env.reset)(
            jax.random.split(jax.random.key(seed), n_obs))
        obs = np.asarray(obs)
        for i in range(min(warmup, requests)):      # steady-state warmup
            try:
                server.act(obs[i % n_obs], seed=1_000_000 + i)
            except (Overloaded, DeadlineExceeded, DispatcherError):
                # a chaos plan may kill the dispatcher mid-warmup; the
                # typed error IS the degradation contract working, and
                # warmup requests are not measured — keep priming
                pass

        arrive = np.cumsum(rng.exponential(1.0 / rate, size=requests))
        done_at = np.zeros(requests)
        futures: list = [None] * requests
        shed = 0
        t0 = time.perf_counter()
        for i in range(requests):
            delay = (t0 + arrive[i]) - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            try:
                fut = _submit(obs[i % n_obs], i)
            except Overloaded:
                shed += 1       # retries exhausted: this request is shed
                continue

            def _done(_fut, i=i):
                done_at[i] = time.perf_counter()
            fut.add_done_callback(_done)
            futures[i] = fut
        answered = np.zeros(requests, bool)
        for i, fut in enumerate(futures):
            if fut is None:
                continue
            try:
                fut.result(timeout=120)
                answered[i] = True
            except (Overloaded, DeadlineExceeded, DispatcherError,
                    ServerClosed):
                shed += 1       # typed shed — counted, never hung
        stats = server.stats()
    finally:
        server.stop()
    latency_ms = (done_at - (t0 + arrive)) * 1e3
    ans_lat = latency_ms[answered]
    n_ans = int(answered.sum())
    wall = max(float(done_at[answered].max() if n_ans else 0.0) - t0, 1e-9)
    p50, p99 = (np.percentile(ans_lat, [50, 99]) if n_ans
                else (float("nan"), float("nan")))
    return {
        "serve_qps": n_ans / wall,
        "serve_p50_ms": float(p50),
        "serve_p99_ms": float(p99),
        "serve_mean_batch": stats["mean_batch"],
        "serve_shed": shed,
        "serve_restarts": stats["n_restarts"],
    }
