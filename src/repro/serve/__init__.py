"""Policy-as-a-service (DESIGN.md §10): serve trained policies through
the same batched-dispatch discipline that makes training fast.

  * ``ServeConfig``   — the spec block (max_batch / max_queue /
    timeout_ms), validated eagerly (repro.api.ExperimentSpec.serve);
  * ``PolicyServer``  — admission queue + persistent dispatcher thread
    gathering ready requests into one padded fixed-shape donated
    ``actor_forward`` dispatch, deterministic per-request seeding;
  * ``ServeRuntime``  — the ``runtime="serve"`` engine registry entry
    (imported lazily by the engine; constructing it through
    ``repro.api.build`` is the normal path: ``Session.serve()``).

Quickstart:

    spec = api.ExperimentSpec(runtime="serve", env="catch",
                              checkpoint={"dir": "ckpts"},
                              serve={"max_batch": 64})
    server = api.build(spec).serve()        # loads ckpts' latest capsule
    result = server.act(obs, seed=7)        # -> ActionResult
"""
from repro.serve.config import ServeConfig                      # noqa: F401
from repro.serve.server import (ActionResult, DeadlineExceeded,  # noqa: F401
                                DispatcherError, Overloaded,
                                PolicyServer, ServerClosed)
