"""ServeConfig: the serving-policy block of an ExperimentSpec.

Validated eagerly at construction (like every other spec axis —
repro.api.spec): a bad ``max_batch`` fails when the spec is built, with
the field named, never as a shape error inside the dispatcher thread.

  * ``max_batch``  — the fixed dispatch width: every admitted batch is
    padded to exactly this many rows, so the serving loop compiles ONE
    program shape (the batched-stepper discipline of DESIGN.md §2.1,
    turned toward inference).
  * ``max_queue``  — admission-queue bound. A full queue rejects
    (``PolicyServer.submit(block=False)``) or backpressures
    (``block=True``) instead of growing without bound.
  * ``timeout_ms`` — how long the dispatcher waits for the FIRST
    request of a batch before re-checking for shutdown. It is NOT a
    batch-fill delay: once one request is admitted, whatever else is
    already queued (up to ``max_batch``) rides the same dispatch and
    the batch leaves immediately — continuous batching, no artificial
    latency in exchange for occupancy.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 32
    max_queue: int = 1024
    timeout_ms: float = 20.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(
                f"serve.max_batch must be >= 1, got {self.max_batch}")
        if self.max_queue < 1:
            raise ValueError(
                f"serve.max_queue must be >= 1, got {self.max_queue}")
        if self.timeout_ms <= 0:
            raise ValueError(
                f"serve.timeout_ms must be > 0, got {self.timeout_ms}")

    def canonical(self) -> dict:
        return {"max_batch": int(self.max_batch),
                "max_queue": int(self.max_queue),
                "timeout_ms": float(self.timeout_ms)}

    @staticmethod
    def of(value) -> "ServeConfig":
        if isinstance(value, ServeConfig):
            return value
        if value is None:
            return ServeConfig()
        if isinstance(value, dict):
            unknown = set(value) - {"max_batch", "max_queue", "timeout_ms"}
            if unknown:
                raise ValueError(
                    f"unknown serve field(s) {sorted(unknown)}; known: "
                    f"['max_batch', 'max_queue', 'timeout_ms']")
            return ServeConfig(**value)
        raise TypeError(f"serve must be a dict or ServeConfig, got "
                        f"{type(value).__name__}")
