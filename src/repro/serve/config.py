"""ServeConfig: the serving-policy block of an ExperimentSpec.

Validated eagerly at construction (like every other spec axis —
repro.api.spec): a bad ``max_batch`` fails when the spec is built, with
the field named, never as a shape error inside the dispatcher thread.

  * ``max_batch``  — the fixed dispatch width: every admitted batch is
    padded to exactly this many rows, so the serving loop compiles ONE
    program shape (the batched-stepper discipline of DESIGN.md §2.1,
    turned toward inference).
  * ``max_queue``  — admission-queue bound. A full queue rejects
    (``PolicyServer.submit(block=False)`` raises the typed
    ``Overloaded``) or backpressures (``block=True``) instead of
    growing without bound.
  * ``timeout_ms`` — how long the dispatcher waits for the FIRST
    request of a batch before re-checking for shutdown. It is NOT a
    batch-fill delay: once one request is admitted, whatever else is
    already queued (up to ``max_batch``) rides the same dispatch and
    the batch leaves immediately — continuous batching, no artificial
    latency in exchange for occupancy.

Graceful-degradation policy (DESIGN.md §11):

  * ``deadline_ms`` — per-request deadline, measured from ADMISSION to
    the moment the dispatcher picks the request up. A request that
    waited longer is failed with ``DeadlineExceeded`` instead of being
    served stale — under overload the queue sheds its oldest work
    instead of serving every request late. 0 (default) disables.
  * ``max_restarts`` — how many CONSECUTIVE dispatcher failures the
    server absorbs by restarting the dispatch loop in place (in-flight
    batch failed with ``DispatcherError``, queued requests untouched,
    health stays green). 0 (default): a dispatcher death poisons the
    server — the pre-existing fail-loud semantics.
  * ``restart_backoff_ms`` — sleep before restart #1; doubles each
    consecutive restart (capped at 1000 ms).
"""
from __future__ import annotations

from dataclasses import dataclass

_FIELDS = ("max_batch", "max_queue", "timeout_ms", "deadline_ms",
           "max_restarts", "restart_backoff_ms")


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 32
    max_queue: int = 1024
    timeout_ms: float = 20.0
    deadline_ms: float = 0.0
    max_restarts: int = 0
    restart_backoff_ms: float = 10.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(
                f"serve.max_batch must be >= 1, got {self.max_batch}")
        if self.max_queue < 1:
            raise ValueError(
                f"serve.max_queue must be >= 1, got {self.max_queue}")
        if self.timeout_ms <= 0:
            raise ValueError(
                f"serve.timeout_ms must be > 0, got {self.timeout_ms}")
        if self.deadline_ms < 0:
            raise ValueError(
                f"serve.deadline_ms must be >= 0 (0 disables), got "
                f"{self.deadline_ms}")
        if self.max_restarts < 0:
            raise ValueError(
                f"serve.max_restarts must be >= 0, got "
                f"{self.max_restarts}")
        if self.restart_backoff_ms < 0:
            raise ValueError(
                f"serve.restart_backoff_ms must be >= 0, got "
                f"{self.restart_backoff_ms}")

    def canonical(self) -> dict:
        return {"max_batch": int(self.max_batch),
                "max_queue": int(self.max_queue),
                "timeout_ms": float(self.timeout_ms),
                "deadline_ms": float(self.deadline_ms),
                "max_restarts": int(self.max_restarts),
                "restart_backoff_ms": float(self.restart_backoff_ms)}

    @staticmethod
    def of(value) -> "ServeConfig":
        if isinstance(value, ServeConfig):
            return value
        if value is None:
            return ServeConfig()
        if isinstance(value, dict):
            unknown = set(value) - set(_FIELDS)
            if unknown:
                raise ValueError(
                    f"unknown serve field(s) {sorted(unknown)}; known: "
                    f"{list(_FIELDS)}")
            return ServeConfig(**value)
        raise TypeError(f"serve must be a dict or ServeConfig, got "
                        f"{type(value).__name__}")
