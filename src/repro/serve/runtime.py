"""The ``runtime="serve"`` engine entry.

A serving runtime shares the engine's construction contract — the
``factory(env, policy_apply, params, opt, cfg, **kwargs)`` signature,
registry resolution, spec-driven builds through ``repro.api`` — but NOT
its execution contract: it answers action requests, it does not run
training intervals. ``run``/``state``/``run_from`` therefore raise a
TypeError pointing at ``Session.serve()`` instead of pretending an
inference loop has interval semantics (``engine.training_runtime_names``
is the enumeration every training-only surface — the SPS sweep, the
equivalence/continuation matrices — iterates instead).
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.engine import HTSConfig, register_runtime
from repro.envs.interfaces import Env
from repro.serve.config import ServeConfig
from repro.serve.server import PolicyServer


@register_runtime("serve")
class ServeRuntime:
    name = "serve"

    def __init__(self, env: Env, policy_apply: Callable, params, opt,
                 cfg: HTSConfig, serve: Optional[ServeConfig] = None,
                 faults=None):
        self.env = env
        self.policy_apply = policy_apply
        self.params = params
        self.opt = opt                # unused: serving never updates
        self.cfg = cfg
        self.serve_config = serve if serve is not None else ServeConfig()
        self.faults = faults          # shared FaultInjector (or None)

    def init(self) -> None:
        pass

    # ------------------------------------------------ serving surface
    def server(self, params=None, start: bool = True) -> PolicyServer:
        """Build (and by default start) a PolicyServer over ``params``
        (default: the construction-time parameters — typically restored
        from a checkpoint capsule by Session.serve)."""
        import jax
        import numpy as np
        # obs template from the env's reset distribution: serving pads
        # with zero rows of exactly this shape/dtype
        _, obs0 = self.env.reset(jax.random.key(0))
        srv = PolicyServer(
            self.policy_apply,
            self.params if params is None else params,
            obs_like=np.asarray(obs0),
            serve=self.serve_config, seed=self.cfg.seed,
            faults=self.faults)
        return srv.start() if start else srv

    # ----------------------------------- training contract: refuse loud
    def _no_training(self, what: str):
        raise TypeError(
            f"the 'serve' runtime answers action requests, not training "
            f"intervals — {what} is not available; use Session.serve() "
            f"(or a training runtime: "
            f"{_training_names()})")

    def run(self, n_intervals: int):
        self._no_training("run")

    def state(self):
        self._no_training("state")

    def run_from(self, state, n_intervals: int, finalize: bool = True):
        self._no_training("run_from")


def _training_names():
    from repro.core import engine
    return engine.training_runtime_names()
