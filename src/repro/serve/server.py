"""PolicyServer: continuous-batching policy inference.

The serving mirror of the training hot path (DESIGN.md §2.1): where the
host runtime's stepper gathers ready (env, step, action) requests into
one padded fixed-shape dispatch, the serving loop gathers ready *action
requests* into one padded fixed-shape donated ``actor_forward``
dispatch:

  submit() --> admission queue --> dispatcher thread
                                     gather <= max_batch ready requests
                                     pad to exactly max_batch rows
                                     ONE jitted actor_forward (donated)
                                     scatter actions to futures

Determinism contract (the executor discipline of core/determinism.py,
turned toward inference): the sampling key for a request is a pure
function of ``(server seed, request seed)`` — ``request_key`` — and the
dispatched program is row-independent (``actor_forward`` is a vmapped
per-row computation), so the SAME request yields the SAME action
bit-exactly regardless of batch composition, padding, queue order, or
arrival timing (tests/test_serve.py). Padding rows are zero
observations whose sampled actions are simply discarded; they cannot
leak into real rows for the same reason batch composition cannot.

The dispatch is fixed-shape: every batch is padded to ``max_batch``
rows, so the serving loop compiles exactly one program, and the obs and
seed slabs are donated (they are rebuilt per dispatch; the params are
never donated — every dispatch reads them).

Multi-model serving (the pool half of repro.tenancy): one server can
hold SEVERAL policy capsules behind the same admission queue —
``add_model`` registers each under a model id with its own compiled +
warmed program, its own seed master, and its own padding width;
``submit(..., model=...)`` routes requests. The dispatcher gathers one
admission batch, groups it by model, and dispatches each group padded
to that model's width — several models ride one gather cycle, and
per-model request/row/QPS counters feed ``stats()``. Determinism is
per-model by construction: a model's rows are computed by ITS program
under ITS master key, and rows are independent, so every (model, obs,
seed) request answers bit-identically to a single-model server for
that model, regardless of cross-model batch composition
(tests/test_tenancy.py).

Failure discipline mirrors the host runtime's pools: a dispatcher death
fails every pending and future request with the original traceback
instead of hanging clients on futures that will never resolve.

Graceful degradation (DESIGN.md §11) — every shed request gets a TYPED
error, never a hung future:

  * ``Overloaded``        — admission queue full (``submit(block=False)``).
    Subclasses ``queue.Full``, so pre-taxonomy callers keep working.
  * ``DeadlineExceeded``  — the request waited in the queue longer than
    ``ServeConfig.deadline_ms`` before the dispatcher picked it up.
  * ``DispatcherError``   — the request was IN FLIGHT when the
    dispatcher failed and the server restarted the loop in place
    (``ServeConfig.max_restarts``); queued requests survive the restart
    untouched and the health probe stays green throughout.
  * ``ServerClosed``      — submitted to a stopped/closing/dead server,
    or still queued when ``close()`` tore the server down.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import determinism
from repro.core.rollout import actor_forward
from repro.faults import FaultInjector, FaultPlan
from repro.serve.config import ServeConfig

_SHUTDOWN = object()


class ServerClosed(RuntimeError):
    """Raised by submit/act on a stopped or dead server, and set on
    futures still queued when ``close()`` tears the server down."""


class Overloaded(queue.Full):
    """Typed load-shedding rejection: the admission queue is at
    ``max_queue``. A ``queue.Full`` subclass — callers that predate the
    taxonomy and catch ``queue.Full`` still see every rejection."""


class DeadlineExceeded(RuntimeError):
    """The request sat in the admission queue past its
    ``ServeConfig.deadline_ms`` deadline; shed instead of served stale."""


class DispatcherError(RuntimeError):
    """The request was in flight when the dispatcher failed; the server
    restarted in place, and this request (only) was the casualty —
    resubmission is safe (serving is stateless and deterministic)."""


@dataclass(frozen=True)
class ActionResult:
    """One answered request."""
    action: int
    logprob: float          # behavior logprob of the sampled action
    batch_size: int         # occupancy of the dispatch that served it


@dataclass
class _Model:
    """One served policy: its program, seed master, padding width, and
    reporting counters (counters guarded by the server lock)."""
    name: str
    policy_apply: Callable
    params: object
    obs_shape: Tuple[int, ...]
    obs_dtype: object
    master: object            # per-model seed master (determinism root)
    max_batch: int            # per-model padding width
    program: Optional[Callable] = None
    n_requests: int = 0
    n_dispatches: int = 0
    n_rows: int = 0


@dataclass
class _Request:
    obs: np.ndarray
    seed: int
    future: Future
    model: Optional[_Model] = None
    admitted: float = 0.0      # monotonic admission time (deadline clock)


class PolicyServer:
    """Serve ``policy_apply(params, obs) -> (logits, value)`` through a
    continuous-batching loop.

    * ``obs_like``  — a single-observation template (shape + dtype);
      submitted observations must match it.
    * ``seed``      — the server-level seed (HTSConfig.seed of the spec
      that built it): ``request_key(master_key(seed), request_seed)``
      is the complete source of sampling randomness.

    Use as a context manager, or ``start()``/``stop()`` explicitly.
    ``start=False`` construction (and ``stop(drain=False)``) leaves the
    admission queue accumulating without a dispatcher — how the tests
    force specific batch compositions.
    """

    def __init__(self, policy_apply: Callable, params, obs_like,
                 serve: Optional[ServeConfig] = None, seed: int = 0,
                 faults: "Optional[FaultInjector | FaultPlan]" = None,
                 model: str = "default"):
        self.serve = serve if serve is not None else ServeConfig()
        self._seed = int(seed)
        self._models: dict = {}
        self._queue: "queue.Queue" = queue.Queue(self.serve.max_queue)
        self._thread: Optional[threading.Thread] = None
        self._stopping = threading.Event()
        self._closing = threading.Event()
        self._failure: Optional[BaseException] = None
        self._failure_tb: Optional[str] = None
        self._lock = threading.Lock()
        # "dispatcher"-site chaos fires at dispatch index d (the same
        # shared injector a Session threads through training)
        if faults is not None and not isinstance(faults, FaultInjector):
            faults = FaultInjector(FaultPlan.of(faults))
        self._faults = faults
        self._dispatch_seq = 0    # dispatch attempts incl. failed ones
        # reporting-only counters (under _lock)
        self.n_requests = 0
        self.n_dispatches = 0
        self.n_rows = 0           # sum of dispatch occupancies
        self.n_rejected = 0
        self.n_deadline = 0       # shed past deadline_ms
        self.n_restarts = 0       # in-place dispatcher restarts
        self._t0 = time.monotonic()   # QPS clock (reset at start())
        self._default = self._register(
            model, policy_apply, params, obs_like,
            self.serve.max_batch, seed)

    # ------------------------------------------------------------ build
    def _register(self, name: str, policy_apply: Callable, params,
                  obs_like, max_batch: int, seed: int) -> _Model:
        if name in self._models:
            raise ValueError(
                f"model {name!r} already served; model ids must be "
                f"unique (served: {sorted(self._models)})")
        obs_like = np.asarray(obs_like)
        m = _Model(name=name, policy_apply=policy_apply, params=params,
                   obs_shape=tuple(obs_like.shape),
                   obs_dtype=obs_like.dtype,
                   master=determinism.master_key(seed),
                   max_batch=int(max_batch))
        m.program = self._compile(m)
        self._models[name] = m
        return m

    def _compile(self, m: _Model) -> Callable:
        papply, master, B = m.policy_apply, m.master, m.max_batch

        def prog(params, obs, seeds):
            keys = jax.vmap(
                lambda s: determinism.request_key(master, s))(seeds)
            return actor_forward(papply, params, obs, keys)

        # the seed slab is donated (it is rebuilt per dispatch and its
        # buffer is reusable for the action row); the obs slab is not —
        # policies reshape it before producing any like-shaped output,
        # so XLA would ignore the donation and warn on every dispatch
        jprog = jax.jit(prog, donate_argnums=(2,))
        # warm the one compiled shape up front so the first request does
        # not pay compilation inside its latency
        obs0 = jnp.zeros((B,) + m.obs_shape, m.obs_dtype)
        seeds0 = jnp.zeros((B,), jnp.int32)
        jax.block_until_ready(jprog(m.params, obs0, seeds0))
        return jprog

    def add_model(self, name: str, policy_apply: Callable, params,
                  obs_like, max_batch: Optional[int] = None,
                  seed: Optional[int] = None) -> "PolicyServer":
        """Register another policy under model id ``name``: compiles and
        warms its own fixed-shape program (padding width ``max_batch``,
        default the server's) with its own seed master (default the
        server's seed) — so this model's answers are bit-identical to a
        single-model server built from the same (policy, params, seed),
        whatever else shares the admission queue. Safe to call while
        the dispatcher is running (compilation happens here, in the
        caller's thread; the model becomes routable when this
        returns)."""
        m = self._register(
            name, policy_apply, params, obs_like,
            self.serve.max_batch if max_batch is None else max_batch,
            self._seed if seed is None else seed)
        assert m is not None
        return self

    def models(self) -> list:
        """Served model ids, default model first."""
        return [self._default.name] + sorted(
            n for n in self._models if n != self._default.name)

    # back-compat surface: the default model's params/program, as the
    # single-model server exposed them (tests swap _program to inject
    # dispatcher failures; callers read .params to check hot-swaps)
    @property
    def params(self):
        return self._default.params

    @params.setter
    def params(self, value) -> None:
        self._default.params = value

    @property
    def policy_apply(self) -> Callable:
        return self._default.policy_apply

    @property
    def _program(self) -> Callable:
        return self._default.program

    @_program.setter
    def _program(self, value) -> None:
        self._default.program = value

    # -------------------------------------------------------- lifecycle
    def start(self) -> "PolicyServer":
        if self._thread is not None:
            raise ServerClosed("server already started")
        self._t0 = time.monotonic()    # QPS accounting starts at serve
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-dispatcher",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain: requests admitted before stop() are still answered."""
        if self._thread is None:
            return
        self._stopping.set()
        try:
            self._queue.put_nowait(_SHUTDOWN)
        except queue.Full:
            pass      # the loop notices _stopping at its next timeout tick
        self._thread.join()
        self._thread = None
        # fail anything that raced its way in behind the sentinel
        self._fail_pending(ServerClosed("server stopped"))

    def close(self) -> None:
        """Graceful teardown, biased toward shedding: stop admission
        NOW, let the in-flight dispatch flush (its futures resolve
        normally), then fail everything still queued with a typed
        ``ServerClosed`` — never a hung future. ``stop()`` is the
        drain-everything variant; ``close()`` is what a deadline-bound
        shutdown wants. Idempotent, and safe on a never-started or
        already-dead server."""
        self._closing.set()
        if self._thread is not None:
            try:
                self._queue.put_nowait(_SHUTDOWN)
            except queue.Full:
                pass  # the loop notices _closing at its next tick
            self._thread.join()
            self._thread = None
        self._fail_pending(ServerClosed("server closed"))

    def __enter__(self) -> "PolicyServer":
        return self.start() if self._thread is None else self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def dead(self) -> bool:
        return self._failure is not None

    @property
    def ready(self) -> bool:
        """Readiness probe: is a submit() right now going to be
        admitted? (dispatcher alive, not stopping/closing, not dead)"""
        return (self._thread is not None and self._thread.is_alive()
                and not self.dead and not self._stopping.is_set()
                and not self._closing.is_set())

    def health(self) -> dict:
        """Liveness probe. ``ok`` stays True through in-place
        dispatcher restarts (the thread survives; only the in-flight
        batch is failed) — it goes False only when the server is dead
        (restarts exhausted) or torn down."""
        alive = self._thread is not None and self._thread.is_alive()
        with self._lock:
            restarts = self.n_restarts
        return {
            "ok": alive and not self.dead,
            "ready": self.ready,
            "dispatcher_alive": alive,
            "dead": self.dead,
            "queue_depth": self._queue.qsize(),
            "restarts": restarts,
        }

    # -------------------------------------------------------- admission
    def submit(self, obs, seed: int = 0, block: bool = True,
               model: Optional[str] = None) -> Future:
        """Admit one request; the Future resolves to an ActionResult.
        ``model`` routes to a served model id (default: the model the
        server was constructed with). ``block=False`` raises
        ``Overloaded`` (a ``queue.Full``) instead of backpressuring
        when the admission queue is at ``max_queue``."""
        if self._failure is not None:
            raise ServerClosed(
                f"serve dispatcher died: {self._failure!r}") \
                from self._failure
        if self._stopping.is_set() or self._closing.is_set():
            # note an UNSTARTED server does accept submits — the queue
            # just accumulates until start() (how tests stage specific
            # batch compositions); only a stopping server admits nothing
            raise ServerClosed("server is stopping")
        if model is None:
            m = self._default
        else:
            m = self._models.get(model)
            if m is None:
                raise KeyError(
                    f"unknown model {model!r}; served models: "
                    f"{self.models()}")
        obs = np.asarray(obs, m.obs_dtype)
        if tuple(obs.shape) != m.obs_shape:
            raise ValueError(
                f"request obs shape {tuple(obs.shape)} != model "
                f"{m.name!r}'s obs shape {m.obs_shape}")
        req = _Request(obs=obs, seed=int(seed), future=Future(),
                       model=m, admitted=time.monotonic())
        try:
            self._queue.put(req, block=block)
        except queue.Full:
            with self._lock:
                self.n_rejected += 1
            raise Overloaded(
                f"admission queue is at max_queue="
                f"{self.serve.max_queue}; request shed") from None
        with self._lock:
            self.n_requests += 1
            m.n_requests += 1
        return req.future

    def act(self, obs, seed: int = 0, timeout: Optional[float] = None,
            model: Optional[str] = None) -> ActionResult:
        """Synchronous submit + wait."""
        return self.submit(obs, seed=seed,
                           model=model).result(timeout=timeout)

    # ------------------------------------------------------- dispatcher
    def _gather(self) -> Optional[list]:
        """Block up to timeout_ms for the first ready request, then
        drain whatever else is already queued, up to max_batch — no
        waiting for the batch to fill."""
        try:
            first = self._queue.get(timeout=self.serve.timeout_ms / 1e3)
        except queue.Empty:
            return None
        if first is _SHUTDOWN:
            return []
        batch = [first]
        while len(batch) < self.serve.max_batch:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            if req is _SHUTDOWN:
                self._stopping.set()
                break
            batch.append(req)
        if self.serve.deadline_ms:
            # shed at PICKUP, not admission: the deadline measures how
            # stale the answer would be, which only the dispatcher's
            # clock knows
            now = time.monotonic()
            live = []
            for req in batch:
                waited_ms = (now - req.admitted) * 1e3
                if waited_ms > self.serve.deadline_ms:
                    with self._lock:
                        self.n_deadline += 1
                    req.future.set_exception(DeadlineExceeded(
                        f"request waited {waited_ms:.1f}ms in queue, "
                        f"deadline is {self.serve.deadline_ms}ms"))
                else:
                    live.append(req)
            batch = live
        return batch

    def _dispatch(self, batch: list) -> None:
        """Group one gathered admission batch by model (first-appearance
        order), then run each group through ITS model's program padded
        to ITS width — several models ride one gather cycle. Groups
        wider than a model's ``max_batch`` are chunked."""
        groups: dict = {}
        for req in batch:
            groups.setdefault(req.model.name, []).append(req)
        for name, reqs in groups.items():
            m = self._models[name]
            for lo in range(0, len(reqs), m.max_batch):
                self._dispatch_model(m, reqs[lo:lo + m.max_batch])

    def _dispatch_model(self, m: _Model, batch: list) -> None:
        B = m.max_batch
        obs = np.zeros((B,) + m.obs_shape, m.obs_dtype)
        seeds = np.zeros((B,), np.int32)
        for i, req in enumerate(batch):
            obs[i] = req.obs
            seeds[i] = req.seed
        actions, logprobs = m.program(
            m.params, jnp.asarray(obs), jnp.asarray(seeds))
        actions = np.asarray(actions)
        logprobs = np.asarray(logprobs)
        with self._lock:
            self.n_dispatches += 1
            self.n_rows += len(batch)
            m.n_dispatches += 1
            m.n_rows += len(batch)
        for i, req in enumerate(batch):
            req.future.set_result(ActionResult(
                action=int(actions[i]), logprob=float(logprobs[i]),
                batch_size=len(batch)))

    def _loop(self) -> None:
        batch = None
        consec = 0          # consecutive failures (reset per dispatch)
        while True:
            try:
                while True:
                    batch = self._gather()
                    if batch is None:          # timeout tick
                        if self._stopping.is_set() or \
                                self._closing.is_set():
                            return
                        continue
                    if batch:
                        seq = self._dispatch_seq
                        self._dispatch_seq += 1   # counts failed attempts
                        if self._faults is not None:
                            self._faults.fire("dispatcher", seq)
                        self._dispatch(batch)
                        consec = 0
                    batch = None
                    if self._closing.is_set():
                        return      # close(): in-flight flushed, done
                    if self._stopping.is_set() and self._queue.empty():
                        return
            except BaseException as e:      # noqa: BLE001 — fail loudly
                if consec < self.serve.max_restarts:
                    # degrade, don't die: only the in-flight batch is
                    # lost (typed DispatcherError — resubmission is
                    # safe); queued requests stay admitted, the thread
                    # survives, health stays green
                    consec += 1
                    with self._lock:
                        self.n_restarts += 1
                    err = DispatcherError(
                        f"dispatcher failed (in-place restart "
                        f"{consec}/{self.serve.max_restarts}): {e!r}")
                    err.__cause__ = e
                    for req in batch or ():
                        if not req.future.done():
                            req.future.set_exception(err)
                    batch = None
                    time.sleep(min(self.serve.restart_backoff_ms
                                   * 2 ** (consec - 1), 1000.0) / 1e3)
                    continue
                self._failure = e
                self._failure_tb = traceback.format_exc()
                # the in-flight batch is already off the queue: its
                # futures must be failed here or clients hang forever
                for req in batch or ():
                    if not req.future.done():
                        req.future.set_exception(e)
                self._fail_pending(e)
                return

    def _fail_pending(self, exc: BaseException) -> None:
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            if req is not _SHUTDOWN and not req.future.done():
                req.future.set_exception(exc)

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        elapsed = max(time.monotonic() - self._t0, 1e-9)
        with self._lock:
            return {
                "n_requests": self.n_requests,
                "n_dispatches": self.n_dispatches,
                "n_rejected": self.n_rejected,
                "n_deadline": self.n_deadline,
                "n_restarts": self.n_restarts,
                "mean_batch": (self.n_rows / self.n_dispatches
                               if self.n_dispatches else 0.0),
                # per-tenant accounting: admitted-request rate and
                # dispatch occupancy for each served model id
                "models": {
                    name: {
                        "n_requests": m.n_requests,
                        "n_dispatches": m.n_dispatches,
                        "mean_batch": (m.n_rows / m.n_dispatches
                                       if m.n_dispatches else 0.0),
                        "qps": m.n_requests / elapsed,
                    }
                    for name, m in sorted(self._models.items())
                },
            }
