"""Llama-4 Scout 17B-active 16-expert MoE. [hf:meta-llama/Llama-4-Scout-17B-16E]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (expert) vocab=202048, MoE 16e
top-1 with a shared expert, interleaved chunked-local attention (iRoPE):
3 local (8192-token chunk) layers then 1 global NoPE layer.
long_500k is skipped: the global layers are full-attention.
"""
from repro.configs.base import (ModelConfig, register, ATTN_FULL, ATTN_LOCAL,
                                FFN_MOE)

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mixer_cycle=(ATTN_LOCAL, ATTN_LOCAL, ATTN_LOCAL, ATTN_FULL),
    ffn_cycle=(FFN_MOE,),
    window=8192,
    rope_on_global=False,          # iRoPE: NoPE on global layers
    n_experts=16,
    top_k=1,
    shared_expert=True,
    sub_quadratic=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
