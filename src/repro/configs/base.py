"""Model / architecture configuration.

Every assigned architecture from the public pool gets one file in this
package defining a ``ModelConfig`` with the exact numbers from the
assignment (source cited in the file). ``reduced()`` produces the
CPU-smoke-test variant of the same family (<=2 layers, d_model<=512,
<=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Layer mixer kinds.
ATTN_FULL = "attn_full"      # full causal (or bidirectional for encoders)
ATTN_LOCAL = "attn_local"    # sliding-window causal
RGLRU = "rglru"              # RecurrentGemma RG-LRU recurrent block
RWKV = "rwkv"                # RWKV-6 time-mix

# FFN kinds.
FFN_DENSE = "dense"
FFN_MOE = "moe"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # Layer pattern: cycle of (mixer, ffn) kinds, tiled over n_layers.
    mixer_cycle: Tuple[str, ...] = (ATTN_FULL,)
    ffn_cycle: Tuple[str, ...] = (FFN_DENSE,)

    # Attention options.
    window: int = 4096                # sliding window for ATTN_LOCAL
    attn_softcap: float = 0.0         # gemma2-style attention logit softcap
    final_softcap: float = 0.0        # gemma2-style final logit softcap
    rope_theta: float = 10_000.0
    mrope: bool = False               # Qwen2-VL multimodal RoPE (3 position streams)
    rope_on_global: bool = True       # llama4 iRoPE: NoPE on global layers

    # MoE options.
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False       # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    moe_group_size: int = 128         # tokens per dispatch group (GShard-style)
    router_aux_weight: float = 0.01
    # "capacity" = GShard einsum dispatch (baseline); "dropless" =
    # sort + ragged_dot under shard_map (beyond-paper, §Perf)
    moe_impl: str = "capacity"
    # cast dense-MLP weight gradients to bf16 before the data-axis
    # all-reduce (halves gradient comm; beyond-paper, §Perf)
    grad_comm_bf16: bool = False

    # Recurrent options (RG-LRU / RWKV).
    conv_width: int = 4               # temporal conv in Griffin recurrent block
    rglru_c: float = 8.0

    # Encoder-decoder (audio).
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0                  # precomputed frame embeddings length

    # VLM frontend stub.
    vision_prefix: int = 0            # patch embeddings merged at sequence start

    # Serving: local-attention layers keep a ring cache of ``window``
    # entries instead of the full sequence (beyond-paper optimization;
    # see EXPERIMENTS.md §Perf).
    ring_cache: bool = True
    # Tensor-parallel attention layout: materialize the GQA repeat so
    # q/k/v all carry the full head count (divisible by the model axis)
    # and attention runs head-parallel with zero collectives. Costs a
    # R-fold larger (sharded) k/v activation; wins when kv_heads doesn't
    # divide the model axis (beyond-paper optimization, §Perf).
    attn_tp_repeat: bool = False
    # Attention compute replicated over the model axis (batch-sharded
    # only). For head counts indivisible by the axis (llama4's 40),
    # head_dim-sharding all-reduces every score tile; replicating trades
    # bounded redundant FLOPs for zero attention collectives (§Perf).
    attn_replicate_tp: bool = False
    # Use the Pallas flash-attention kernel for full-sequence forward
    # passes where no gradient is needed (prefill/serve). interpret=True
    # on CPU; compiled on TPU. The jnp path remains the training default
    # (it carries the custom flash backward).
    use_pallas_attention: bool = False

    # Misc.
    mlp_kind: str = "swiglu"          # swiglu | gelu
    norm_kind: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Whether the arch supports the long_500k decode shape (sub-quadratic or
    # sliding-window attention on all/most layers). Full-attention archs skip.
    sub_quadratic: bool = False
    source: str = ""                  # citation for the config numbers

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """(mixer, ffn) for every layer, tiling the cycles."""
        out = []
        for i in range(self.n_layers):
            out.append((self.mixer_cycle[i % len(self.mixer_cycle)],
                        self.ffn_cycle[i % len(self.ffn_cycle)]))
        return tuple(out)

    @property
    def cycle_len(self) -> int:
        import math
        return math.lcm(len(self.mixer_cycle), len(self.ffn_cycle))

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/mixers, tiny dims."""
        n_layers = min(self.n_layers, max(2, len(self.mixer_cycle)))
        # keep at least one full cycle so every mixer kind is exercised,
        # capped at 4 layers.
        n_layers = min(max(n_layers, len(self.mixer_cycle)), 4)
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        head_dim = min(self.resolved_head_dim, 64)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            window=min(self.window, 64),
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=min(self.enc_seq, 32),
            vision_prefix=min(self.vision_prefix, 8),
            moe_group_size=16,
            # no capacity drops at toy scale so prefill+decode is exactly
            # consistent with the full forward (capacity-based MoE drops
            # depend on group boundaries, which differ between the two paths)
            capacity_factor=4.0,
        )


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    if not _REGISTRY:
        _load_all()
    return tuple(sorted(_REGISTRY))


def _load_all() -> None:
    # import side-effect registers every config module in this package
    from repro.configs import (  # noqa: F401
        llama4_scout_17b_a16e,
        recurrentgemma_9b,
        h2o_danube_3_4b,
        granite_moe_1b_a400m,
        rwkv6_7b,
        whisper_medium,
        qwen2_vl_72b,
        starcoder2_3b,
        stablelm_12b,
        gemma2_27b,
        paper_cnn,
    )
