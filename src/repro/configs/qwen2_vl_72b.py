"""Qwen2-VL 72B (language backbone). [arXiv:2409.12191]

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064, M-RoPE
(3 position streams: temporal/height/width). Vision tower (ViT) is a STUB
per the carve-out: input_specs() provides patch embeddings merged at the
sequence prefix. long_500k skipped (full attention).
"""
from repro.configs.base import ModelConfig, register, ATTN_FULL, FFN_DENSE

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mixer_cycle=(ATTN_FULL,),
    mrope=True,
    vision_prefix=256,            # merged patch-embedding prefix length
    sub_quadratic=False,
    source="arXiv:2409.12191",
))
