"""StableLM-2 12B. [hf:stabilityai/stablelm-2-1_6b (family card)]

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register, ATTN_FULL, FFN_DENSE

CONFIG = register(ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    mixer_cycle=(ATTN_FULL,),
    norm_kind="layernorm",
    sub_quadratic=False,
    source="hf:stabilityai/stablelm-2-1_6b",
))
