"""StarCoder2-3B. [arXiv:2402.19173]

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152, GQA + RoPE.
Assignment specifies plain GQA/RoPE -> full attention, long_500k skipped.
"""
from repro.configs.base import ModelConfig, register, ATTN_FULL, FFN_DENSE

CONFIG = register(ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    mixer_cycle=(ATTN_FULL,),
    mlp_kind="gelu",
    norm_kind="layernorm",
    sub_quadratic=False,
    source="arXiv:2402.19173",
))
