"""The paper's own policy networks (Atari / GFootball CNN).

Four hidden layers: conv 32x8x8/4, conv 64x4x4/2, conv 64x3x3/1, fc 512,
then policy + value heads (Espeholt et al. 2018 / Kuettler et al. 2019 /
Kurach et al. 2019 -- identical trunk for all three systems compared in
the paper). Used by the RL examples and benchmarks, not by the dry-run.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class CNNPolicyConfig:
    name: str = "paper-cnn"
    obs_shape: Tuple[int, int, int] = (84, 84, 4)
    conv_filters: Tuple[int, ...] = (32, 64, 64)
    conv_sizes: Tuple[int, ...] = (8, 4, 3)
    conv_strides: Tuple[int, ...] = (4, 2, 1)
    hidden: int = 512
    n_actions: int = 18


CONFIG = CNNPolicyConfig()
