"""Gemma-2 27B. [arXiv:2408.00118]

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000,
local(4096)+global alternating, attention logit softcap 50, final softcap 30.
Sliding-window variant implemented -> runs long_500k (global layers keep the
full cache; local layers use the window).
"""
from repro.configs.base import (ModelConfig, register, ATTN_FULL, ATTN_LOCAL,
                                FFN_DENSE)

CONFIG = register(ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    mixer_cycle=(ATTN_LOCAL, ATTN_FULL),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_kind="gelu",
    sub_quadratic=True,
    source="arXiv:2408.00118",
))
