"""RecurrentGemma-9B (Griffin). [arXiv:2402.19427]

38L d_model=4096 16H (GQA kv=1, MQA) d_ff=12288 vocab=256000.
Pattern: (RG-LRU, RG-LRU, local attention) 1:2, window 2048.
Sub-quadratic -> runs long_500k.
"""
from repro.configs.base import (ModelConfig, register, ATTN_LOCAL, RGLRU,
                                FFN_DENSE)

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mixer_cycle=(RGLRU, RGLRU, ATTN_LOCAL),
    ffn_cycle=(FFN_DENSE,),
    window=2048,
    mlp_kind="gelu",               # GeGLU in the paper; gated gelu here
    sub_quadratic=True,
    source="arXiv:2402.19427",
))
