"""IBM Granite-3.0 1B-a400m MoE. [hf:ibm-granite/granite-3.0-1b-a400m-base]

24L d_model=1024 16H (GQA kv=8) d_ff=512 (expert) vocab=49155,
MoE 32 experts top-8. Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register, ATTN_FULL, FFN_MOE

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    mixer_cycle=(ATTN_FULL,),
    ffn_cycle=(FFN_MOE,),
    n_experts=32,
    top_k=8,
    sub_quadratic=False,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
