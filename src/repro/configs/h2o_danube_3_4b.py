"""H2O Danube3 4B. [arXiv:2401.16818]

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, llama+mistral mix
with sliding-window attention -> sub-quadratic, runs long_500k.
"""
from repro.configs.base import ModelConfig, register, ATTN_LOCAL, FFN_DENSE

CONFIG = register(ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    mixer_cycle=(ATTN_LOCAL,),
    window=4096,
    sub_quadratic=True,
    source="arXiv:2401.16818",
))
