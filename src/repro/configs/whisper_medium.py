"""Whisper medium (decoder backbone + encoder). [arXiv:2212.04356]

24L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865, encoder-decoder.
Conv/mel frontend is a STUB per the assignment carve-out: input_specs()
provides precomputed frame embeddings (B, 1500, d_model).
long_500k skipped (full attention decoder).
"""
from repro.configs.base import ModelConfig, register, ATTN_FULL, FFN_DENSE

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    mixer_cycle=(ATTN_FULL,),
    mlp_kind="gelu",
    norm_kind="layernorm",
    is_encoder_decoder=True,
    n_enc_layers=24,
    enc_seq=1500,
    sub_quadratic=False,
    source="arXiv:2212.04356",
))
