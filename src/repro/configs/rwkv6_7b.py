"""RWKV-6 (Finch) 7B. [arXiv:2404.05892]

32L d_model=4096 attention-free (WKV6 time-mix, 64-dim heads) d_ff=14336
vocab=65536. Data-dependent decay. O(1) decode state -> runs long_500k.
"""
from repro.configs.base import ModelConfig, register, RWKV, FFN_DENSE

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                   # 4096 / 64-dim heads
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    mixer_cycle=(RWKV,),
    mlp_kind="gelu",              # RWKV channel-mix is its own thing; see models/rwkv6.py
    sub_quadratic=True,
    source="arXiv:2404.05892",
))
