"""Minimal optax-style optimizers (optax is not available offline).

Each optimizer is a pair of pure functions packed in an ``Optimizer``:
    init(params) -> state
    update(grads, state, params) -> (updates, state)
``apply_updates(params, updates)`` adds (gradient-ascent convention is the
caller's business; losses here are minimized, so updates are negative).

RMSProp matches the paper's hyperparameter tables (Tab. A3/A6): momentum 0,
configurable eps. Optimizer state is f32 and shards like the params.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return _tmap(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def rmsprop(lr: float, decay: float = 0.99, eps: float = 1e-5,
            momentum: float = 0.0) -> Optimizer:
    """RMSProp as used by the paper (Kostrikov A2C / TorchBeast IMPALA)."""

    def init(params):
        sq = _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if momentum:
            mom = _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            return {"sq": sq, "mom": mom}
        return {"sq": sq}

    def update(grads, state, params=None):
        gf = _tmap(lambda g: g.astype(jnp.float32), grads)
        sq = _tmap(lambda s, g: decay * s + (1 - decay) * g * g,
                   state["sq"], gf)
        upd = _tmap(lambda g, s: -lr * g / (jnp.sqrt(s) + eps), gf, sq)
        new = {"sq": sq}
        if momentum:
            mom = _tmap(lambda m, u: momentum * m + u, state["mom"], upd)
            upd = mom
            new["mom"] = mom
        return upd, new

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        t = state["t"] + 1
        gf = _tmap(lambda g: g.astype(jnp.float32), grads)
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], gf)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], gf)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        upd = _tmap(lambda m_, v_: -lr * (m_ / bc1) /
                    (jnp.sqrt(v_ / bc2) + eps), m, v)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Callable:
    """Gradient transform applied before an optimizer."""

    def clip(grads):
        leaves = jax.tree.leaves(grads)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in leaves))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
        return _tmap(lambda g: g * scale.astype(g.dtype), grads), gn

    return clip


def chain(clip_fn: Callable, opt: Optimizer) -> Optimizer:
    def update(grads, state, params=None):
        grads, _ = clip_fn(grads)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)
