from repro.optim.optimizers import (  # noqa: F401
    adam, rmsprop, sgd, clip_by_global_norm, chain, apply_updates,
    Optimizer)
from repro.optim import schedules  # noqa: F401
