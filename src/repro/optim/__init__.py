"""Optimizers plus the optimizer registry: ``get_optimizer(name,
**kwargs)`` resolves by name so experiment specs
(repro.api.ExperimentSpec) can declare their optimizer instead of
importing a constructor.

    from repro import optim
    opt = optim.get_optimizer("rmsprop", lr=7e-4, eps=1e-5)
    opt = optim.get_optimizer("adam", lr=3e-4, clip_norm=1.0)

``clip_norm`` is accepted by every entry: it chains a global-norm clip
in front of the optimizer (optim.clip_by_global_norm).
"""
from typing import Callable, Dict

from repro.optim.optimizers import (  # noqa: F401
    adam, rmsprop, sgd, clip_by_global_norm, chain, apply_updates,
    Optimizer)
from repro.optim import schedules  # noqa: F401

_REGISTRY: Dict[str, Callable[..., Optimizer]] = {}


def register_optimizer(name: str):
    """Factory decorator over a ``(**kwargs) -> Optimizer`` callable."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_optimizer(name: str, clip_norm: float = 0.0, **kwargs) -> Optimizer:
    """Build a registered optimizer: ``get_optimizer("rmsprop",
    lr=7e-4, eps=1e-5)``."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown optimizer {name!r}; "
                       f"registered: {optimizer_names()}") from None
    opt = factory(**kwargs)
    if clip_norm:
        opt = chain(clip_by_global_norm(clip_norm), opt)
    return opt


def optimizer_names():
    return sorted(_REGISTRY)


register_optimizer("sgd")(sgd)
register_optimizer("rmsprop")(rmsprop)
register_optimizer("adam")(adam)
