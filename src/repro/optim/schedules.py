"""Learning-rate schedules (pure functions step -> lr multiplier)."""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp


def constant(lr: float) -> Callable:
    return lambda step: jnp.full((), lr, jnp.float32)


def linear_decay(lr: float, total_steps: int, floor: float = 0.0) -> Callable:
    def f(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return lr * (1.0 - frac) + floor * frac
    return f


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  floor_ratio: float = 0.1) -> Callable:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = lr * (floor_ratio + (1 - floor_ratio) *
                    0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return f


def scheduled(opt_factory: Callable, schedule: Callable):
    """Wrap an optimizer factory (lr -> Optimizer) with a schedule: the
    state carries a step counter and the lr is re-derived each update."""
    from repro.optim.optimizers import Optimizer
    import jax

    base = opt_factory(1.0)     # unit-lr optimizer; scale updates

    def init(params):
        return {"inner": base.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        upd, inner = base.update(grads, state["inner"], params)
        lr = schedule(state["step"])
        upd = jax.tree.map(lambda u: u * lr, upd)
        return upd, {"inner": inner, "step": state["step"] + 1}

    return Optimizer(init, update)
