"""Checkpointing: flat .npz with pytree structure manifest (orbax is not
available offline; this is self-contained and deterministic).

Saves the full DelayedGradState — params, params_prev (the behavior
snapshot matters: restoring only params would silently reset the
one-step delay), optimizer state, and step.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, str(treedef)


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    # numpy's savez has no bf16 cast path: store bf16 leaves as f32
    # (lossless upcast) and restore back to the reference dtype.
    arrays = {}
    for i, a in enumerate(leaves):
        arr = np.asarray(a)
        if arr.dtype.name == "bfloat16":
            arr = arr.astype(np.float32)
        arrays[f"leaf_{i}"] = arr
    np.savez(path.with_suffix(".npz"), **arrays)
    manifest = {
        "n_leaves": len(leaves),
        "dtypes": [str(np.asarray(a).dtype) for a in leaves],
        "metadata": metadata or {},
    }
    path.with_suffix(".json").write_text(json.dumps(manifest, indent=1))


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != {ref.shape}")
        out.append(jnp.asarray(arr).astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest(dirpath: str) -> str | None:
    d = Path(dirpath)
    if not d.exists():
        return None
    cands = sorted(d.glob("step_*.npz"))
    return str(cands[-1].with_suffix("")) if cands else None
