"""Checkpointing: flat .npz with a versioned pytree manifest (orbax is
not available offline; this is self-contained and deterministic).

A checkpoint is two files: ``<path>.npz`` with the leaves and
``<path>.json`` with the manifest — format version, the flattened
treedef, per-leaf dtypes/shapes, and caller metadata. ``restore``
validates leaf count, tree structure, shapes, and dtypes against the
``like`` template and fails with a precise error instead of silently
unflattening mismatched leaves in flatten order.

Works on any pure-array pytree: a full ``DelayedGradState`` (params,
params_prev — the behavior snapshot matters: restoring only params would
silently reset the one-step delay), or an engine ``TrainState`` capsule
(core/engine.py). Sharded ``jax.Array`` leaves (e.g. from the sharded
runtime's shard_map programs) are gathered with ``jax.device_get`` before
writing, so a checkpoint taken on an N-device mesh restores on any
device count.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

FORMAT_VERSION = 1


def _to_numpy(leaf) -> np.ndarray:
    # device_get gathers sharded jax.Arrays to one host buffer; plain
    # numpy/python leaves pass through
    if isinstance(leaf, jax.Array):
        leaf = jax.device_get(leaf)
    return np.asarray(leaf)


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    # numpy's savez has no bf16 cast path: store bf16 leaves as f32
    # (lossless upcast) and restore back to the reference dtype.
    arrays = {}
    dtypes, shapes = [], []
    for i, a in enumerate(leaves):
        arr = _to_numpy(a)
        dtypes.append(str(arr.dtype))
        shapes.append(list(arr.shape))
        if arr.dtype.name == "bfloat16":
            arr = arr.astype(np.float32)
        arrays[f"leaf_{i}"] = arr
    manifest = {
        "version": FORMAT_VERSION,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": dtypes,
        "shapes": shapes,
        "metadata": metadata or {},
    }
    # both files go through write-tmp + atomic rename, npz before
    # manifest: a kill mid-save leaves either no .json (fresh path — so
    # latest(), which globs manifests, never selects it) or, when
    # overwriting an existing checkpoint, the intact OLD npz/json pair —
    # never a torn npz behind a valid manifest
    npz_tmp = path.with_suffix(".npz.tmp")
    with open(npz_tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(npz_tmp, path.with_suffix(".npz"))
    json_tmp = path.with_suffix(".json.tmp")
    json_tmp.write_text(json.dumps(manifest, indent=1))
    os.replace(json_tmp, path.with_suffix(".json"))


def load_manifest(path: str) -> dict | None:
    p = Path(path).with_suffix(".json")
    return json.loads(p.read_text()) if p.exists() else None


def load_metadata(path: str) -> dict:
    """The caller-supplied metadata dict saved alongside the arrays."""
    m = load_manifest(path)
    return (m or {}).get("metadata", {})


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (an equal-structure pytree
    of arrays or ShapeDtypeStructs). Tree structure, leaf count, shapes,
    and dtypes are all validated against both the template and the
    manifest before a single leaf is unflattened."""
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    leaves, treedef = jax.tree_util.tree_flatten(like)
    manifest = load_manifest(path)
    if manifest is not None:
        n = manifest.get("n_leaves")
        if n is not None and n != len(leaves):
            raise ValueError(
                f"checkpoint {path.name} has {n} leaves but the restore "
                f"template has {len(leaves)} — the pytree structure "
                f"changed (different model/optimizer/runtime config?)")
        want = manifest.get("treedef")
        if want is not None and want != str(treedef):
            raise ValueError(
                f"checkpoint {path.name} tree structure mismatch:\n"
                f"  saved:    {want}\n  template: {treedef}")
    if len(data.files) != len(leaves):
        raise ValueError(
            f"checkpoint {path.name} holds {len(data.files)} arrays but "
            f"the restore template has {len(leaves)} leaves")
    out = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(ref.shape):
            # a staleness-K capsule differs from a staleness-K' one only
            # in ring depth: same pytree, leading axes off by the ring
            # length. Diagnose that case specifically — it is the config
            # mismatch users actually hit.
            hint = ""
            if (tuple(arr.shape[1:]) == tuple(ref.shape)
                    or tuple(arr.shape) == tuple(ref.shape[1:])
                    or (arr.ndim == ref.ndim and arr.ndim > 0
                        and tuple(arr.shape[1:]) == tuple(ref.shape[1:]))):
                hint = (" — only the leading (ring) axis differs; was "
                        "this checkpoint written with a different "
                        "staleness than the restoring runtime's?")
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != {ref.shape}"
                f"{hint}")
        if manifest is not None:
            saved_dt = manifest.get("dtypes", [None] * len(leaves))[i]
            if saved_dt is not None and saved_dt != str(ref.dtype):
                raise ValueError(
                    f"leaf {i}: checkpoint dtype {saved_dt} != template "
                    f"dtype {ref.dtype}")
        out.append(jnp.asarray(arr).astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest(dirpath: str) -> str | None:
    """Newest COMPLETE checkpoint in ``dirpath`` (newest ``step_*.json``
    whose ``.npz`` half exists). A manifest without its array file is a
    torn capsule — a kill between the two halves of a save/prune, or a
    copy that dropped the npz — and selecting it would make resume
    crash on np.load instead of falling back to the previous complete
    checkpoint. Torn manifests are skipped, newest first."""
    d = Path(dirpath)
    if not d.exists():
        return None
    for p in sorted(d.glob("step_*.json"), reverse=True):
        if p.with_suffix(".npz").exists():
            return str(p.with_suffix(""))
    return None


def restore_prefix(path: str, like: Any) -> Any:
    """Restore the FIRST ``len(leaves(like))`` leaves of a checkpoint
    into the structure of ``like`` — the params-only read serving uses
    (repro.serve) on a full ``TrainState`` capsule.

    This leans on a structural invariant of the capsule formats, pinned
    by tests/test_serve.py: params are the first field of every
    update-rule state (``DelayedGradState.params`` for the HTS family,
    element 0 of the baselines' tuples) and ``algo`` is the first field
    of ``TrainState``, so in flatten order the policy parameters are
    exactly the leading leaves — for every runtime and every staleness
    (the K-ring lives in ``params_prev``, after them). Shapes and
    dtypes are validated leaf-by-leaf against the template, so a capsule
    whose layout does NOT start with ``like`` fails loudly here."""
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(data.files) < len(leaves):
        raise ValueError(
            f"checkpoint {path.name} holds {len(data.files)} arrays but "
            f"the prefix template needs {len(leaves)} leaves")
    out = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"prefix leaf {i}: checkpoint shape {arr.shape} != "
                f"template {tuple(ref.shape)} — the capsule's leading "
                f"leaves are not this policy's parameters (different "
                f"model config?)")
        out.append(jnp.asarray(arr).astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
