"""Checkpointing: flat .npz with a versioned pytree manifest (orbax is
not available offline; this is self-contained and deterministic).

A checkpoint is two files: ``<path>.npz`` with the leaves and
``<path>.json`` with the manifest — format version, the flattened
treedef, per-leaf dtypes/shapes, and caller metadata. ``restore``
validates leaf count, tree structure, shapes, and dtypes against the
``like`` template and fails with a precise error instead of silently
unflattening mismatched leaves in flatten order.

Works on any pure-array pytree: a full ``DelayedGradState`` (params,
params_prev — the behavior snapshot matters: restoring only params would
silently reset the one-step delay), or an engine ``TrainState`` capsule
(core/engine.py). Sharded ``jax.Array`` leaves (e.g. from the sharded
runtime's shard_map programs) are gathered with ``jax.device_get`` before
writing, so a checkpoint taken on an N-device mesh restores on any
device count.
"""
from __future__ import annotations

import json
import os
import zlib
from pathlib import Path
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

FORMAT_VERSION = 1


class CheckpointCorrupt(RuntimeError):
    """The checkpoint's BYTES cannot be trusted: the npz half is
    unreadable (truncated write, disk corruption, a copy that dropped
    bytes) or a leaf's content fails its manifest checksum. Distinct
    from ValueError (structural mismatch against the restore template —
    wrong config, wrong model), because the two demand different
    responses: corruption is survivable by falling back to an older
    complete checkpoint (core/trainer.Trainer does exactly that), a
    structural mismatch is a caller error no amount of retrying fixes."""


def _to_numpy(leaf) -> np.ndarray:
    # device_get gathers sharded jax.Arrays to one host buffer; plain
    # numpy/python leaves pass through
    if isinstance(leaf, jax.Array):
        leaf = jax.device_get(leaf)
    return np.asarray(leaf)


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    # numpy's savez has no bf16 cast path: store bf16 leaves as f32
    # (lossless upcast) and restore back to the reference dtype.
    arrays = {}
    dtypes, shapes, crcs = [], [], []
    for i, a in enumerate(leaves):
        arr = _to_numpy(a)
        dtypes.append(str(arr.dtype))
        shapes.append(list(arr.shape))
        if arr.dtype.name == "bfloat16":
            arr = arr.astype(np.float32)
        # per-leaf checksum of the STORED bytes (post-upcast), so a
        # flipped bit or truncated page inside the zip is detected at
        # restore as CheckpointCorrupt naming the leaf, not as silently
        # wrong parameters
        crcs.append(zlib.crc32(np.ascontiguousarray(arr).tobytes()))
        arrays[f"leaf_{i}"] = arr
    manifest = {
        "version": FORMAT_VERSION,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": dtypes,
        "shapes": shapes,
        "crc32": crcs,
        "metadata": metadata or {},
    }
    # both files go through write-tmp + atomic rename, npz before
    # manifest: a kill mid-save leaves either no .json (fresh path — so
    # latest(), which globs manifests, never selects it) or, when
    # overwriting an existing checkpoint, the intact OLD npz/json pair —
    # never a torn npz behind a valid manifest
    npz_tmp = path.with_suffix(".npz.tmp")
    with open(npz_tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(npz_tmp, path.with_suffix(".npz"))
    json_tmp = path.with_suffix(".json.tmp")
    json_tmp.write_text(json.dumps(manifest, indent=1))
    os.replace(json_tmp, path.with_suffix(".json"))


def _open_npz(path: Path):
    """np.load with byte-level failures surfaced as CheckpointCorrupt
    (a truncated/corrupt zip raises half a dozen different exception
    types depending on WHERE the damage sits; callers need one)."""
    try:
        return np.load(path.with_suffix(".npz"))
    except FileNotFoundError:
        raise CheckpointCorrupt(
            f"checkpoint {path.name} is torn: manifest present but "
            f"{path.with_suffix('.npz').name} is missing") from None
    except Exception as e:      # zipfile.BadZipFile, OSError, EOFError...
        raise CheckpointCorrupt(
            f"checkpoint {path.with_suffix('.npz').name} is unreadable "
            f"(truncated or corrupt): {e!r}") from e


def _read_leaf(data, path: Path, i: int, crcs) -> np.ndarray:
    """Extract leaf i, decompressing its bytes now (np.load is lazy —
    corruption inside the zip only surfaces on member access) and
    verifying its manifest checksum when one was recorded."""
    try:
        arr = data[f"leaf_{i}"]
    except KeyError:
        raise CheckpointCorrupt(
            f"checkpoint {path.name} has no leaf_{i} array — the npz "
            f"member list is damaged or the file was truncated") from None
    except Exception as e:      # zlib.error mid-member, struct errors...
        raise CheckpointCorrupt(
            f"checkpoint {path.name} leaf_{i} is unreadable (corrupt "
            f"bytes inside the archive): {e!r}") from e
    if crcs is not None and i < len(crcs):
        got = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if got != crcs[i]:
            raise CheckpointCorrupt(
                f"checkpoint {path.name} leaf_{i} fails its checksum "
                f"(manifest crc32={crcs[i]}, stored bytes={got}) — the "
                f"array content was corrupted after the write")
    return arr


def load_manifest(path: str) -> dict | None:
    p = Path(path).with_suffix(".json")
    return json.loads(p.read_text()) if p.exists() else None


def load_metadata(path: str) -> dict:
    """The caller-supplied metadata dict saved alongside the arrays."""
    m = load_manifest(path)
    return (m or {}).get("metadata", {})


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (an equal-structure pytree
    of arrays or ShapeDtypeStructs). Tree structure, leaf count, shapes,
    and dtypes are all validated against both the template and the
    manifest before a single leaf is unflattened."""
    path = Path(path)
    data = _open_npz(path)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    manifest = load_manifest(path)
    if manifest is not None:
        n = manifest.get("n_leaves")
        if n is not None and n != len(leaves):
            raise ValueError(
                f"checkpoint {path.name} has {n} leaves but the restore "
                f"template has {len(leaves)} — the pytree structure "
                f"changed (different model/optimizer/runtime config?)")
        want = manifest.get("treedef")
        if want is not None and want != str(treedef):
            raise ValueError(
                f"checkpoint {path.name} tree structure mismatch:\n"
                f"  saved:    {want}\n  template: {treedef}")
    if len(data.files) != len(leaves):
        raise ValueError(
            f"checkpoint {path.name} holds {len(data.files)} arrays but "
            f"the restore template has {len(leaves)} leaves")
    crcs = (manifest or {}).get("crc32")
    out = []
    for i, ref in enumerate(leaves):
        arr = _read_leaf(data, path, i, crcs)
        if tuple(arr.shape) != tuple(ref.shape):
            # a staleness-K capsule differs from a staleness-K' one only
            # in ring depth: same pytree, leading axes off by the ring
            # length. Diagnose that case specifically — it is the config
            # mismatch users actually hit.
            hint = ""
            if (tuple(arr.shape[1:]) == tuple(ref.shape)
                    or tuple(arr.shape) == tuple(ref.shape[1:])
                    or (arr.ndim == ref.ndim and arr.ndim > 0
                        and tuple(arr.shape[1:]) == tuple(ref.shape[1:]))):
                hint = (" — only the leading (ring) axis differs; was "
                        "this checkpoint written with a different "
                        "staleness than the restoring runtime's?")
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != {ref.shape}"
                f"{hint}")
        if manifest is not None:
            saved_dt = manifest.get("dtypes", [None] * len(leaves))[i]
            if saved_dt is not None and saved_dt != str(ref.dtype):
                raise ValueError(
                    f"leaf {i}: checkpoint dtype {saved_dt} != template "
                    f"dtype {ref.dtype}")
        out.append(jnp.asarray(arr).astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def complete_checkpoints(dirpath: str) -> list[str]:
    """All COMPLETE checkpoints in ``dirpath`` (``step_*.json`` whose
    ``.npz`` half exists), newest first. A manifest without its array
    file is a torn capsule — a kill between the two halves of a
    save/prune, or a copy that dropped the npz — and selecting it would
    make resume crash instead of falling back to the previous complete
    checkpoint. "Complete" here means both files exist; content
    corruption (failed checksum, damaged zip) surfaces at ``restore`` as
    CheckpointCorrupt, and supervisors walk this list newest-first to
    fall back past it (core/trainer.Trainer)."""
    d = Path(dirpath)
    if not d.exists():
        return []
    return [str(p.with_suffix(""))
            for p in sorted(d.glob("step_*.json"), reverse=True)
            if p.with_suffix(".npz").exists()]


def latest(dirpath: str) -> str | None:
    """Newest complete checkpoint in ``dirpath``, or None."""
    found = complete_checkpoints(dirpath)
    return found[0] if found else None


def restore_prefix(path: str, like: Any) -> Any:
    """Restore the FIRST ``len(leaves(like))`` leaves of a checkpoint
    into the structure of ``like`` — the params-only read serving uses
    (repro.serve) on a full ``TrainState`` capsule.

    This leans on a structural invariant of the capsule formats, pinned
    by tests/test_serve.py: params are the first field of every
    update-rule state (``DelayedGradState.params`` for the HTS family,
    element 0 of the baselines' tuples) and ``algo`` is the first field
    of ``TrainState``, so in flatten order the policy parameters are
    exactly the leading leaves — for every runtime and every staleness
    (the K-ring lives in ``params_prev``, after them). Shapes and
    dtypes are validated leaf-by-leaf against the template, so a capsule
    whose layout does NOT start with ``like`` fails loudly here.

    Error taxonomy (pinned by tests/test_checkpoint.py): a missing or
    unreadable npz / failed leaf checksum raises ``CheckpointCorrupt``;
    a missing manifest, a manifest without ``n_leaves``, too few leaves
    for the template, or a shape/dtype mismatch raises ``ValueError``
    naming what disagreed."""
    path = Path(path)
    manifest = load_manifest(path)
    if manifest is None:
        raise ValueError(
            f"checkpoint {path.name} has no manifest "
            f"({path.with_suffix('.json').name} is missing) — cannot "
            f"validate a prefix restore against an unmanifested capsule")
    n = manifest.get("n_leaves")
    if n is None:
        raise ValueError(
            f"checkpoint {path.name} manifest is missing the "
            f"'n_leaves' field (present: {sorted(manifest)})")
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if n < len(leaves):
        raise ValueError(
            f"checkpoint {path.name} holds {n} arrays but the prefix "
            f"template needs {len(leaves)} leaves")
    data = _open_npz(path)
    crcs = manifest.get("crc32")
    dtypes = manifest.get("dtypes", [None] * n)
    out = []
    for i, ref in enumerate(leaves):
        arr = _read_leaf(data, path, i, crcs)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"prefix leaf {i}: checkpoint shape {arr.shape} != "
                f"template {tuple(ref.shape)} — the capsule's leading "
                f"leaves are not this policy's parameters (different "
                f"model config?)")
        if dtypes[i] is not None and dtypes[i] != str(ref.dtype):
            raise ValueError(
                f"prefix leaf {i}: checkpoint dtype {dtypes[i]} != "
                f"template dtype {ref.dtype}")
        out.append(jnp.asarray(arr).astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
