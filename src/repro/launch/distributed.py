"""Multi-process sharded training: one invocation per process.

    # process 0 (also the coordinator) and process 1, same spec:
    PYTHONPATH=src python -m repro.launch.distributed \
        --spec examples/specs/quickstart.json \
        --coordinator localhost:12355 --num-processes 2 --process-id 0 &
    PYTHONPATH=src python -m repro.launch.distributed \
        --spec examples/specs/quickstart.json \
        --coordinator localhost:12355 --num-processes 2 --process-id 1

Every process joins the ``jax.distributed`` cluster
(core/distributed.py — gloo collectives on CPU, ordered before backend
init), builds the SAME session from the SAME spec, and runs the sharded
runtime over one global mesh spanning all processes. The scale-out
determinism contract (DESIGN.md §12) makes the result bit-exact to the
1-process run: the final-parameter digest printed by every process is
the digest the mesh runtime prints on one device — which is exactly
what the CI subprocess test asserts.

The spec's runtime must be ``sharded`` (or is forced to it here —
multi-process training has exactly one runtime), and
``batch.n_replicas``, when set, must equal the global device count.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import sys

import numpy as np


def params_digest(params) -> str:
    """sha256 over the parameter pytree (dtype/shape + bytes per leaf,
    in tree order) — the cross-process/cross-runtime comparison key."""
    import jax
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(params):
        arr = np.asarray(leaf)
        h.update(repr((str(arr.dtype), arr.shape)).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="multi-process sharded HTS-RL (one run per process)")
    ap.add_argument("--spec", required=True, help="experiment spec JSON")
    ap.add_argument("--coordinator", required=True,
                    help="host:port of process 0")
    ap.add_argument("--num-processes", type=int, required=True)
    ap.add_argument("--process-id", type=int, required=True)
    ap.add_argument("--intervals", type=int, default=None,
                    help="override spec.intervals")
    args = ap.parse_args(argv)

    # join the cluster BEFORE importing anything that touches devices
    from repro.core import distributed
    distributed.initialize(args.coordinator, args.num_processes,
                           args.process_id)

    import jax
    from repro import api

    spec = api.load(args.spec)
    if spec.runtime.name != "sharded":
        spec = spec.replace(runtime="sharded")
    mesh = distributed.global_data_mesh(
        n_replicas=spec.batch.n_replicas)
    session = api.build(spec, mesh=mesh)
    n = args.intervals if args.intervals is not None else spec.intervals
    out = session.run(n)

    digest = params_digest(out.params)
    print(json.dumps({
        "process": args.process_id,
        "num_processes": args.num_processes,
        "devices": len(jax.devices()),
        "intervals": n,
        "geometry": session.runtime.geometry.canonical(),
        "params_sha256": digest,
        "sps": round(out.sps, 1),
    }))
    sys.stdout.flush()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
