import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, prove memory fits, and extract roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
(memory_analysis, cost_analysis, per-op collective bytes, roofline terms).

The XLA_FLAGS line above MUST run before any other import: jax locks the
device count at first backend init, and the 512 placeholder host devices
exist only for this dry-run (smoke tests and benches see 1 device).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.base import get_config, list_configs  # noqa: E402
from repro.core import delayed_grad, learner  # noqa: E402
from repro.launch import specs as specs_mod  # noqa: E402
from repro.launch.mesh import (as_shardings, make_production_mesh,  # noqa: E402
                               use_mesh)
from repro.models import backbone  # noqa: E402
from repro.optim import rmsprop, adam  # noqa: E402
from repro.roofline import analysis, hlo_cost  # noqa: E402
from repro.sharding import rules  # noqa: E402

ARCH_SKIP_LIST = ()


def _mem_dict(mem) -> dict:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    return {k: getattr(mem, k) for k in keys}


def _peak_bytes(mem) -> float:
    return (mem.argument_size_in_bytes + mem.temp_size_in_bytes +
            mem.output_size_in_bytes - mem.alias_size_in_bytes)


def lower_one(arch: str, shape_name: str, mesh_name: str,
              opt_name: str = "rmsprop", extra_tag: str = "",
              overrides: dict | None = None, micro: int = 1):
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        typed = {}
        for k, v in overrides.items():
            cur = getattr(cfg, k)
            typed[k] = type(cur)(int(v) if not isinstance(cur, str) else v)
        cfg = dataclasses.replace(cfg, **typed)
    shape = specs_mod.SHAPES[shape_name]
    reason = specs_mod.skip_reason(cfg, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": reason}

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = mesh.devices.size
    abstract_params = backbone.abstract_params(cfg)
    pspecs = rules.param_pspecs(abstract_params, mesh)
    opt = rmsprop(7e-4, eps=1e-5) if opt_name == "rmsprop" else adam(1e-4)

    t0 = time.time()
    with use_mesh(mesh):
        if shape.kind == "train":
            batch = specs_mod.train_batch_specs(cfg, shape)
            dg_abs = jax.eval_shape(
                lambda p: delayed_grad.init(p, opt), abstract_params)
            dg_specs = rules.dg_state_pspecs(dg_abs, pspecs, mesh)
            b_specs = rules.batch_specs(batch, mesh)
            step = learner.make_train_step(cfg, opt,
                                           n_microbatches=micro)
            out_abs = jax.eval_shape(step, dg_abs, batch)
            out_specs = (dg_specs, jax.tree.map(lambda _: P(), out_abs[1]))
            fn = jax.jit(step,
                         in_shardings=as_shardings(mesh,
                                                   (dg_specs, b_specs)),
                         out_shardings=as_shardings(mesh, out_specs),
                         donate_argnums=(0,))
            lowered = fn.lower(dg_abs, batch)
        elif shape.kind == "prefill":
            batch = specs_mod.prefill_batch_specs(cfg, shape)
            b_specs = rules.batch_specs(batch, mesh)
            step = learner.make_prefill_step(cfg, shape.seq_len)
            out_abs = jax.eval_shape(step, abstract_params, batch)
            logits_s = rules.resolve(("batch", "vocab"), out_abs[0].shape,
                                     mesh)
            value_s = rules.resolve(("batch",), out_abs[1].shape, mesh)
            cache_s = rules.cache_pspecs(out_abs[2], cfg, mesh)
            fn = jax.jit(step,
                         in_shardings=as_shardings(mesh, (pspecs, b_specs)),
                         out_shardings=as_shardings(
                             mesh, (logits_s, value_s, cache_s)))
            lowered = fn.lower(abstract_params, batch)
        else:   # decode
            token, cache_abs, pos, extras = specs_mod.decode_specs(cfg, shape)
            cache_s = rules.cache_pspecs(cache_abs, cfg, mesh)
            tok_s = rules.batch_specs({"tokens": token}, mesh)["tokens"]
            ex_s = rules.batch_specs(extras, mesh)
            step = learner.make_serve_step(cfg)
            out_abs = jax.eval_shape(step, abstract_params, token,
                                     cache_abs, pos, extras)
            logits_s = rules.resolve(("batch", "vocab"), out_abs[0].shape,
                                     mesh)
            value_s = rules.resolve(("batch",), out_abs[1].shape, mesh)
            fn = jax.jit(step,
                         in_shardings=as_shardings(
                             mesh, (pspecs, tok_s, cache_s, P(), ex_s)),
                         out_shardings=as_shardings(
                             mesh, (logits_s, value_s, cache_s)),
                         donate_argnums=(2,))
            lowered = fn.lower(abstract_params, token, cache_abs, pos,
                               extras)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # loop-aware HLO walk: XLA's cost_analysis counts while bodies once,
    # which understates scan-over-layers models by the layer count.
    hc = hlo_cost.analyze(hlo)
    coll = analysis.parse_collectives(hlo)
    mf = analysis.model_flops_for(cfg, shape.kind, shape.seq_len,
                                  shape.global_batch)
    la_cost = {"flops": hc.flops, "bytes accessed": hc.bytes,
               "transcendentals": hc.transcendentals}
    la_coll = analysis.CollectiveStats(bytes_by_op=dict(hc.collective_bytes))
    roof = analysis.build_roofline(
        arch, shape_name, mesh_name, chips, la_cost, la_coll, mf,
        _peak_bytes(mem))
    roof.note = ("loop-aware HLO cost model; bytes are an upper-bound "
                 "traffic proxy (per-op operand+output, fusion-aware)")
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "tag": extra_tag,
        "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": _mem_dict(mem),
        "peak_bytes_per_chip": _peak_bytes(mem),
        # XLA:CPU float-normalization stashes f32 copies of bf16 buffers
        # (CPU cannot execute bf16 math); the TPU pipeline keeps bf16.
        "upcast_f32_artifact_bytes": hc.upcast_f32_bytes,
        "peak_bytes_per_chip_tpu_est": _peak_bytes(mem) - hc.upcast_f32_bytes,
        "fits_16g": (_peak_bytes(mem) - hc.upcast_f32_bytes) < 16e9,
        "cost_xla_raw": {k: cost.get(k) for k in
                         ("flops", "bytes accessed", "transcendentals")
                         if k in cost},
        "cost_loop_aware": la_cost,
        "collectives": {"bytes_by_op": coll.bytes_by_op,
                        "count_by_op": coll.count_by_op,
                        "total": coll.total_bytes},
        "roofline": json.loads(roof.to_json()),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opt", default="rmsprop", choices=["rmsprop", "adam"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override, e.g. attn_tp_repeat=1")
    ap.add_argument("--micro", type=int, default=1,
                    help="gradient-accumulation microbatches (train)")
    ap.add_argument("--resume", action="store_true",
                    help="skip combos whose artifact already exists")
    args = ap.parse_args()
    overrides = dict(o.split("=", 1) for o in args.override)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    shapes = list(specs_mod.SHAPES) if (args.all or not args.shape) \
        else [args.shape]

    failures = 0
    for arch in archs:
        for shape in shapes:
            tagpart = f"__{args.tag}" if args.tag else ""
            fname = outdir / f"{arch}__{shape}__{args.mesh}{tagpart}.json"
            if args.resume and fname.exists() and \
                    "error" not in fname.read_text()[:200]:
                print(f"[RESUME-SKIP] {arch} {shape} {args.mesh}",
                      flush=True)
                continue
            t0 = time.time()
            try:
                res = lower_one(arch, shape, args.mesh, args.opt,
                                args.tag, overrides, args.micro)
            except Exception as e:
                failures += 1
                res = {"arch": arch, "shape": shape, "mesh": args.mesh,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
            res["wall_s"] = round(time.time() - t0, 2)
            fname.write_text(json.dumps(res, indent=1, default=float))
            status = ("SKIP" if res.get("skipped")
                      else "FAIL" if res.get("error") else "OK")
            extra = ""
            if status == "OK":
                extra = (f" peak/chip={res['peak_bytes_per_chip_tpu_est']/1e9:.2f}GB(tpu-est)"
                         f" bottleneck={res['roofline']['bottleneck']}")
            print(f"[{status}] {arch} {shape} {args.mesh}"
                  f" ({res['wall_s']}s){extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
