"""The unified spec-driven launcher: one CLI for every runtime,
workload, and algorithm, consuming the declarative surface (repro.api).

    PYTHONPATH=src python -m repro.launch.run --spec examples/specs/quickstart.json
    PYTHONPATH=src python -m repro.launch.run --env catch --runtime mesh \
        --intervals 50
    PYTHONPATH=src python -m repro.launch.run --spec spec.json \
        --set hts.staleness=2 --set optimizer.kwargs.lr=3e-4
    PYTHONPATH=src python -m repro.launch.run --spec spec.json --print-spec

Flags compose left-to-right onto the spec: ``--spec`` (or the component
flags) produces the base, ``--intervals``/``--runtime``/``--set`` edit
its canonical form, and the result is re-validated before anything is
built — so an edit that names an unknown field fails exactly like a bad
spec file would. ``--print-spec`` emits the final canonical JSON and
exits (the way to author new spec files). With a checkpoint directory
(spec ``checkpoint.dir`` or ``--ckpt-dir``), training runs through the
checkpointed trainer and ``--resume`` continues a killed run
bit-exactly.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro import api


def _apply_set(canon: dict, assignment: str) -> None:
    """Apply one ``dotted.path=json_value`` edit to the canonical dict.

    A path segment naming a KNOWN optional block that the dict does not
    carry (a hand-written partial spec without a ``tenancy`` block, say)
    constructs that block's default canonical form in place and keeps
    walking — ``--set tenancy.weight=2`` must mean "default tenancy
    block, weight 2", not KeyError. Only truly unknown names — absent
    from the edited dict AND from a default spec's canonical form —
    fail, loudly, with the path named."""
    if "=" not in assignment:
        raise SystemExit(f"--set takes dotted.path=JSON, got "
                         f"{assignment!r}")
    path, _, raw = assignment.partition("=")
    keys = path.split(".")
    node = canon
    # walk a default spec's canonical form in parallel: it is the
    # authority on which absent names are real optional blocks/fields
    default = api.ExperimentSpec().canonical()
    for key in keys[:-1]:
        if not isinstance(node, dict):
            raise SystemExit(f"--set {path}: {key!r}'s parent is not "
                             f"an object")
        fallback = default.get(key) if isinstance(default, dict) else None
        if key not in node:
            if fallback is None:
                raise SystemExit(
                    f"--set {path}: no such spec field {key!r} "
                    f"(canonical fields: {sorted(node)})")
            node[key] = json.loads(json.dumps(fallback))  # deep copy
        node = node[key]
        default = fallback
    leaf = keys[-1]
    if not isinstance(node, dict):
        raise SystemExit(f"--set {path}: {keys[-2]!r} is not an object")
    # hts knobs and component kwargs may be introduced by an edit;
    # everything else must exist in the canonical form — either in the
    # edited dict or in a default spec's (a partial dict's missing
    # optional field is constructible, a typo is not)
    allow_new = keys[0] == "hts" or "kwargs" in keys[:-1]
    known = isinstance(default, dict) and leaf in default
    if leaf not in node and not (allow_new or known):
        raise SystemExit(f"--set {path}: no such spec field {leaf!r}")
    try:
        node[leaf] = json.loads(raw)
    except ValueError:
        node[leaf] = raw          # bare strings need no quotes


def _override_component(canon: dict, key: str, name: str) -> None:
    """Swap a component's registry name. The spec's kwargs survive when
    the name is unchanged; a genuine swap drops them (they are
    component-specific) — loudly, never silently."""
    cur = canon[key]
    if name == cur["name"]:
        return                    # same component: keep its kwargs
    if cur["kwargs"]:
        print(f"note: --{key} {name} replaces spec {key} "
              f"{cur['name']!r} and drops its kwargs "
              f"{sorted(cur['kwargs'])}", file=sys.stderr)
    canon[key] = {"name": name, "kwargs": {}}


def _resolve_spec(args) -> api.ExperimentSpec:
    if args.spec:
        spec = api.load(args.spec)
    else:
        spec = api.ExperimentSpec(env=args.env)
    canon = spec.canonical()
    if args.env and args.spec:
        _override_component(canon, "env", args.env)
    if args.runtime:
        _override_component(canon, "runtime", args.runtime)
    if args.algorithm:
        canon["algorithm"] = args.algorithm
    if args.intervals is not None:
        canon["intervals"] = args.intervals
    if args.ckpt_dir:
        canon["checkpoint"]["dir"] = args.ckpt_dir
    if args.ckpt_every is not None:
        canon["checkpoint"]["every"] = args.ckpt_every
    for assignment in args.set or ():
        _apply_set(canon, assignment)
    return api.from_dict(canon)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="spec-driven launcher over repro.api")
    ap.add_argument("--spec", default=None, metavar="FILE",
                    help="ExperimentSpec JSON (see examples/specs/)")
    ap.add_argument("--env", default=None,
                    help="env registry name (default spec, or 'catch' "
                         "without --spec)")
    ap.add_argument("--runtime", default=None,
                    help="override the spec's runtime registry name")
    ap.add_argument("--algorithm", default=None,
                    help="override the spec's algorithm")
    ap.add_argument("--intervals", type=int, default=None,
                    help="override the spec's run length")
    ap.add_argument("--set", action="append", metavar="PATH=JSON",
                    help="edit any canonical spec field, e.g. "
                         "--set hts.staleness=2")
    ap.add_argument("--ckpt-dir", default=None,
                    help="override checkpoint.dir (enables fit/resume)")
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="override checkpoint.every")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint")
    ap.add_argument("--log-every", type=int, default=0, metavar="N",
                    help="print per-interval metrics every N intervals "
                         "(0: summary only)")
    ap.add_argument("--print-spec", action="store_true",
                    help="print the final canonical spec JSON and exit")
    args = ap.parse_args()
    if args.env is None and args.spec is None:
        args.env = "catch"

    spec = _resolve_spec(args)
    if args.print_spec:
        print(api.dumps(spec, indent=2))
        return
    if args.resume and not spec.checkpoint.dir:
        ap.error("--resume needs a checkpoint dir (spec checkpoint.dir "
                 "or --ckpt-dir)")

    session = api.build(spec)
    if args.log_every:
        @session.on_interval
        def _log(m):
            if m["interval"] % args.log_every:
                return
            if "rewards" in m and np.asarray(m["rewards"]).size:
                print(f"interval {m['interval']:5d} "
                      f"reward/step {np.mean(m['rewards']):+.4f}",
                      flush=True)
            elif "loss" in m:
                print(f"interval {m['interval']:5d} "
                      f"loss {m['loss']:.4f}", flush=True)

    if spec.checkpoint.dir:
        report = session.fit(resume=args.resume)
        print(f"[{spec.runtime.name}] {report.intervals} intervals "
              f"({report.resumed_from} resumed) | {report.steps} steps "
              f"in {report.wall_time:.1f}s ({report.sps:.0f} SPS)")
        if len(report.episode_returns):
            print(f"final metric (mean return, last 100 episodes): "
                  f"{report.final_metric():.3f}")
        return

    out = session.run()
    print(f"[{spec.runtime.name}] {out.steps} steps in "
          f"{out.wall_time:.1f}s ({out.sps:.0f} SPS incl. compile)")
    if out.rewards.size:
        r = out.rewards
        q = max(1, r.shape[0] // 4)
        print(f"reward/step: first {q} intervals "
              f"{r[:q].mean():+.4f} -> last {q} {r[-q:].mean():+.4f}")
    if out.metrics:
        tail = {k: float(np.mean(v[-max(1, len(v) // 4):]))
                for k, v in out.metrics.items()}
        print("tail metrics: " + ", ".join(
            f"{k}={v:.4f}" for k, v in sorted(tail.items())))


if __name__ == "__main__":
    main()
