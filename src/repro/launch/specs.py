"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape_name)`` returns the abstract inputs for the step
function the shape exercises:

  train_4k     -> train_step(dg_state, batch)
  prefill_32k  -> prefill_step(params, batch)
  decode_32k   -> serve_step(params, token, cache, pos, extras)
  long_500k    -> serve_step, B=1, 512k cache (sub-quadratic archs only)

Modality frontends are stubbed per the assignment carve-out: whisper gets
precomputed frame embeddings (train/prefill) or encoder output (decode);
qwen2-vl gets patch embeddings + M-RoPE position ids.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import backbone


class ShapeSpec(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def supports(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False
    return True


def skip_reason(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention architecture: 512k decode requires a "
                "sub-quadratic or sliding-window variant (DESIGN.md "
                "§Arch-applicability)")
    return None


def _extras(cfg: ModelConfig, B: int, S: int, decode: bool):
    ex = {}
    bf16 = jnp.bfloat16
    if cfg.mrope:
        shp = (3, B, 1) if decode else (3, B, S)
        ex["mrope_positions"] = _sds(shp, jnp.int32)
    if cfg.vision_prefix and not decode:
        ex["patch_embeds"] = _sds((B, cfg.vision_prefix, cfg.d_model), bf16)
    if cfg.is_encoder_decoder:
        if decode:
            ex["enc_out"] = _sds((B, cfg.enc_seq, cfg.d_model), bf16)
        else:
            ex["audio_embeds"] = _sds((B, cfg.enc_seq, cfg.d_model), bf16)
    return ex


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((B, S), jnp.int32),
        "actions": _sds((B, S), jnp.int32),
        "advantages": _sds((B, S), jnp.float32),
        "returns": _sds((B, S), jnp.float32),
        "behavior_logprob": _sds((B, S), jnp.float32),
        "loss_mask": _sds((B, S), jnp.float32),
    }
    batch.update(_extras(cfg, B, S, decode=False))
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32)}
    batch.update(_extras(cfg, B, S, decode=False))
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: backbone.init_decode_cache(cfg, B, S))
    token = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    extras = _extras(cfg, B, S, decode=True)
    return token, cache, pos, extras
