"""Serving launcher — two modes, one command.

**Policy-as-a-service** (``--spec``): serve an RL policy from an
ExperimentSpec through the continuous-batching PolicyServer
(repro.serve, DESIGN.md §10), loading the newest TrainState checkpoint
capsule when the spec (or ``--checkpoint``) names one, then drive the
open-loop Poisson load generator against it and report p50/p99 + QPS:

    PYTHONPATH=src python -m repro.launch.serve \
        --spec examples/specs/quickstart.json \
        --checkpoint ckpts/step_00000040 --requests 500 --rate 2000

**LLM decode** (``--arch``, the historical mode): batched prefill +
per-token serve_step for any assigned arch:

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
        --batch 4 --prompt-len 16 --gen 16

Both are the actor-side hot path of HTS-RL at scale, with the same
determinism contract as the RL actors: executor-style keys that are
pure functions of the request identity, so batch composition can never
change an answer.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import determinism, learner
from repro.models import backbone


def serve_policy(args) -> None:
    """--spec mode: build the session, serve it, drive the load gen."""
    from repro import api
    from repro.serve import loadgen

    spec = api.load(args.spec)
    if args.max_batch is not None:
        spec = spec.replace(serve={"max_batch": args.max_batch,
                                   "max_queue": spec.serve.max_queue,
                                   "timeout_ms": spec.serve.timeout_ms})
    print(f"# serving {spec.env.name} x {spec.policy.name} "
          f"(max_batch={spec.serve.max_batch}, "
          f"checkpoint={args.checkpoint or spec.checkpoint.dir or 'none'})",
          flush=True)
    metrics = loadgen.run(spec, requests=args.requests, rate=args.rate,
                          seed=args.seed, checkpoint=args.checkpoint)
    for name, value in metrics.items():
        print(f"{name}={value:.6g}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default=None, metavar="FILE",
                    help="serve an RL policy from this ExperimentSpec "
                         "JSON (policy-as-a-service mode)")
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="with --spec: TrainState capsule base path "
                         "(default: latest under the spec's checkpoint "
                         "dir, else initial params)")
    ap.add_argument("--requests", type=int, default=500,
                    help="with --spec: load-generator request count")
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="with --spec: offered load, req/s")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="with --spec: override the spec's "
                         "serve.max_batch")
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.spec:
        serve_policy(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    B, S, G = args.batch, args.prompt_len, args.gen
    max_len = S + G

    params = backbone.init_params(cfg, jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (B, S), 0,
                                 cfg.vocab_size)
    master = determinism.master_key(args.seed)

    kw = {}
    if cfg.is_encoder_decoder:
        kw["audio_embeds"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                       jnp.bfloat16)
    if cfg.vision_prefix:
        kw["patch_embeds"] = jnp.zeros((B, cfg.vision_prefix, cfg.d_model),
                                       jnp.bfloat16)
    if cfg.mrope:
        kw["mrope_positions"] = jnp.broadcast_to(jnp.arange(S), (3, B, S))

    t0 = time.time()
    logits, _, cache = jax.jit(
        lambda p, t: backbone.prefill(p, cfg, t, max_len, **kw)
    )(params, prompts)
    print(f"prefill {B}x{S}: {time.time() - t0:.2f}s")

    serve = learner.make_serve_step(cfg)
    jserve = jax.jit(serve, donate_argnums=(2,))

    def pick(logits, step):
        if args.temperature <= 0:
            return jnp.argmax(logits, -1)
        keys = determinism.obs_keys(master, jnp.arange(B), step)
        return jax.vmap(determinism.sample_action)(
            keys, logits / args.temperature)

    tok = pick(logits, 0).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(G - 1):
        extras = {}
        if cfg.mrope:
            extras["mrope_positions"] = jnp.full((3, B, 1), S + i)
        if cfg.is_encoder_decoder:
            extras["enc_out"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                          jnp.bfloat16)
        logits, _, cache = jserve(params, tok[:, None], cache,
                                  jnp.int32(S + i), extras)
        tok = pick(logits, i + 1).astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    gen = jnp.stack(out_tokens, axis=1)
    print(f"decode {G - 1} steps: {dt:.2f}s "
          f"({B * (G - 1) / max(dt, 1e-9):.1f} tok/s)")
    print("generated:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
