"""Multi-tenant launcher: admit several spec files into one TenantPool
(repro.tenancy) and time-slice the device between them.

    PYTHONPATH=src python -m repro.launch.pool \
        --spec examples/specs/pool_a.json --spec examples/specs/pool_b.json
    PYTHONPATH=src python -m repro.launch.pool \
        --spec a.json --spec b.json --weight 2 --weight 1 --sequential
    PYTHONPATH=src python -m repro.launch.pool \
        --spec a.json --spec b.json --digest --check-solo

``--weight``/``--name`` repeat and align positionally with ``--spec``,
overriding each spec's ``tenancy`` block. ``--digest`` prints one
per-tenant result digest line (sha256 over final params + reward
stream + episode returns). ``--check-solo`` then re-runs every tenant
SOLO in the same process and exits nonzero unless each pooled digest
equals its solo digest — the CI smoke for the multiplexing-determinism
contract (DESIGN.md §13) in one command.
"""
from __future__ import annotations

import argparse
import hashlib
import sys
import time

import numpy as np

from repro import api


def result_digest(params, rewards, episode_returns) -> str:
    """sha256 over the result's arrays, order-stable: params leaves in
    tree-flatten order, then the reward stream, then episode returns."""
    import jax
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(rewards)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(episode_returns)).tobytes())
    return h.hexdigest()


def jain_index(values) -> float:
    """Jain's fairness index over per-tenant (weight-normalized)
    shares: 1.0 = perfectly proportional, 1/n = one tenant got all."""
    x = np.asarray(list(values), dtype=np.float64)
    if x.size == 0 or not x.sum():
        return float("nan")
    return float(x.sum() ** 2 / (x.size * (x ** 2).sum()))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="multi-tenant pool launcher over repro.tenancy")
    ap.add_argument("--spec", action="append", required=True,
                    metavar="FILE", help="ExperimentSpec JSON; repeat "
                    "once per tenant")
    ap.add_argument("--weight", action="append", type=int, default=None,
                    help="fair-share weight, positionally aligned with "
                    "--spec (default: each spec's tenancy.weight)")
    ap.add_argument("--name", action="append", default=None,
                    help="tenant name, positionally aligned with --spec "
                    "(default: tenancy.name or t<index>)")
    ap.add_argument("--intervals", type=int, default=None,
                    help="override every tenant's interval budget")
    ap.add_argument("--max-concurrency", type=int, default=2,
                    help="slices in flight across distinct tenants "
                    "(results are identical for every value)")
    ap.add_argument("--sequential", action="store_true",
                    help="shorthand for --max-concurrency 1")
    ap.add_argument("--digest", action="store_true",
                    help="print per-tenant result digests")
    ap.add_argument("--check-solo", action="store_true",
                    help="re-run each tenant solo and fail unless the "
                    "pooled digests match (determinism smoke)")
    args = ap.parse_args()

    specs = [api.load(p) for p in args.spec]
    if args.intervals is not None:
        specs = [s.replace(intervals=args.intervals) for s in specs]
    for flag, vals in (("--weight", args.weight), ("--name", args.name)):
        if vals is not None and len(vals) != len(specs):
            ap.error(f"{flag} repeats must align with --spec: got "
                     f"{len(specs)} spec(s), {len(vals)} value(s)")

    pool = api.Session.pool(
        specs, weights=args.weight, names=args.name,
        max_concurrency=1 if args.sequential else args.max_concurrency)
    t0 = time.perf_counter()
    results = pool.run()
    wall = time.perf_counter() - t0

    total_steps = sum(r.steps for r in results.values())
    counts = pool.schedule_counts()
    weights = {name: pool._get(name).weight for name in results}
    shares = [counts[n] / weights[n] for n in results]
    print(f"[pool] {len(results)} tenants | {total_steps} steps in "
          f"{wall:.1f}s ({total_steps / max(wall, 1e-9):.0f} aggregate "
          f"SPS) | Jain fairness {jain_index(shares):.3f}")
    for name, r in results.items():
        print(f"  {name}: {r.intervals}/{r.target} intervals, "
              f"{r.steps} steps, weight {weights[name]}, "
              f"status {r.status}")

    digests = {name: result_digest(r.params, r.rewards,
                                   r.episode_returns)
               for name, r in results.items()}
    if args.digest or args.check_solo:
        for name, d in digests.items():
            print(f"  digest {name} {d}")

    if args.check_solo:
        failed = []
        for name, spec in zip(results, specs):
            r = results[name]
            solo = api.build(spec).run(r.target)
            from repro.core import evaluate
            s = evaluate.ReturnStream(spec.hts_config().n_envs)
            if solo.rewards.size:
                s.extend(solo.rewards, solo.dones)
            d = result_digest(solo.params, solo.rewards, s.returns)
            ok = d == digests[name]
            print(f"  solo   {name} {d} "
                  f"{'== pooled OK' if ok else '!= pooled MISMATCH'}")
            if not ok:
                failed.append(name)
        if failed:
            print(f"[pool] determinism check FAILED for {failed}",
                  file=sys.stderr)
            raise SystemExit(1)
        print("[pool] every tenant bit-exact to its solo run")


if __name__ == "__main__":
    main()
