"""Production mesh definitions (TPU v5e numbers).

A FUNCTION, not a module constant — importing this module must never touch
jax device state (smoke tests run on 1 CPU device; only dryrun.py forces
512 host devices).

Also hosts the version-compat shims: ``jax.sharding.AxisType`` and
``jax.set_mesh`` only exist on newer jax; on the pinned 0.4.x the plain
mesh plus the ``Mesh`` context manager provide identical semantics for
our (fully ``Auto``) usage.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    if hasattr(jax.sharding, "AxisType"):   # jax >= 0.5
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1D 'data' mesh (examples/tests
    and the sharded data-parallel runtime)."""
    n = len(jax.devices())
    return _make_mesh((n,), ("data",))


def use_mesh(mesh):
    """Context manager installing ``mesh`` for PartitionSpec resolution:
    ``jax.set_mesh`` where available, the Mesh context manager otherwise."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh   # jax.sharding.Mesh is itself a context manager


def as_shardings(mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree for jit in/out_shardings
    (jax 0.4.x rejects raw PartitionSpecs there; NamedSharding works on
    every version). PartitionSpec subclasses tuple, so mark it as a leaf."""
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, PartitionSpec)
        else s,
        spec_tree, is_leaf=lambda x: isinstance(x, PartitionSpec))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-chip sustained)
CHIPS_PER_POD = 256
