"""Production mesh definitions (TPU v5e numbers).

A FUNCTION, not a module constant — importing this module must never touch
jax device state (smoke tests run on 1 CPU device; only dryrun.py forces
512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1D 'data' mesh (examples/tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-chip sustained)
CHIPS_PER_POD = 256
