"""Training launcher: HTS-RL learner over any assigned architecture.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --reduced --steps 50 --batch 8 --seq 64

On this container it runs the reduced config on 1 CPU device; on a real
cluster the same code path pjit's over make_production_mesh() (pass
--mesh pod, requires the devices to exist). The data source is the
deterministic TokenStream; swap in traj_to_batch-fed rollouts for a live
environment (see examples/llm_policy_hts.py for the full HTS-RL loop).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import algorithms
from repro.checkpoint import io as ckpt_io
from repro.configs.base import get_config
from repro.core import delayed_grad, learner
from repro.data.pipeline import TokenStream
from repro.launch.mesh import (as_shardings, make_host_mesh,
                               make_production_mesh, use_mesh)
from repro.models import backbone
from repro.optim import adam, rmsprop
from repro.sharding import rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--opt", default="adam", choices=["adam", "rmsprop"])
    # the token-trajectory learner implements only these two registry
    # algorithms (stale-correction algorithms need behavior-lagged
    # rollouts, which TokenStream does not produce)
    ap.add_argument("--algorithm", default="a2c", choices=["a2c", "ppo"])
    ap.add_argument("--mesh", default="host", choices=["host", "pod",
                                                       "multipod"])
    ap.add_argument("--checkpoint-dir", "--ckpt-dir", dest="ckpt_dir",
                    default=None)
    ap.add_argument("--ckpt-every", type=int, default=0, metavar="N",
                    help="save a checkpoint every N steps (0: only at "
                         "the end, when --checkpoint-dir is set)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in "
                         "--checkpoint-dir; bit-exact (the TokenStream "
                         "is fast-forwarded to the resumed step)")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()
    if args.ckpt_every and not args.ckpt_dir:
        ap.error("--ckpt-every requires --checkpoint-dir")
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --checkpoint-dir")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt = adam(args.lr) if args.opt == "adam" else rmsprop(args.lr)

    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))

    params = backbone.init_params(cfg, jax.random.key(0))
    dg = delayed_grad.init(params, opt)
    # resolve through the registry so launcher strings and runtime
    # algorithms stay one namespace
    alg = algorithms.get_algorithm(args.algorithm)
    step_fn = learner.make_train_step(cfg, opt, alg.name)

    start_step = 0
    if args.resume:
        path = ckpt_io.latest(args.ckpt_dir)
        if path is not None:
            meta = ckpt_io.load_metadata(path)
            # anything that changes the update math or the data stream
            # must match, or "resume" would silently train a different
            # run (validate only keys the checkpoint recorded, for
            # compatibility with older checkpoints)
            for key, have in (("arch", args.arch),
                              ("algorithm", args.algorithm),
                              ("opt", args.opt), ("batch", args.batch),
                              ("seq", args.seq)):
                if key in meta and meta[key] != have:
                    raise SystemExit(
                        f"checkpoint {path} has {key}={meta[key]!r}, "
                        f"but this run was launched with {have!r}")
            dg = ckpt_io.restore(path, jax.eval_shape(lambda: dg))
            start_step = int(meta.get("step", meta.get("steps", 0)))
            print(f"resuming from {path} at step {start_step}", flush=True)

    pspecs = rules.param_pspecs(jax.eval_shape(lambda: params), mesh)
    dg_specs = rules.dg_state_pspecs(
        jax.eval_shape(lambda: dg), pspecs, mesh)
    stream = TokenStream(cfg.vocab_size, args.batch, args.seq)
    sample = stream.next_batch()
    # loop iteration i consumes stream batch i+1 (the probe above took
    # batch 0): fast-forward so a resumed run continues the exact stream
    stream.skip(start_step)
    b_specs = rules.batch_specs(jax.eval_shape(lambda: sample), mesh)
    out_specs = (dg_specs,
                 jax.tree.map(lambda _: P(),
                              jax.eval_shape(step_fn, dg, sample)[1]))

    with use_mesh(mesh):
        jstep = jax.jit(
            step_fn,
            in_shardings=as_shardings(mesh, (dg_specs, b_specs)),
            out_shardings=as_shardings(mesh, out_specs),
            donate_argnums=(0,))
        def save_ckpt(step: int) -> None:
            ckpt_io.save(f"{args.ckpt_dir}/step_{step:08d}", dg,
                         {"arch": args.arch, "step": step,
                          "algorithm": args.algorithm, "opt": args.opt,
                          "batch": args.batch, "seq": args.seq})
            print(f"checkpoint -> {args.ckpt_dir}/step_{step:08d}",
                  flush=True)

        t0 = time.time()
        for i in range(start_step, args.steps):
            batch = stream.next_batch()
            dg, stats = jstep(dg, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                done = i - start_step + 1
                print(f"step {i:4d} loss={float(stats['loss']):.4f} "
                      f"pg={float(stats['pg']):.4f} "
                      f"ent={float(stats['entropy']):.4f} "
                      f"({(time.time() - t0) / done:.3f}s/step)",
                      flush=True)
            if (args.ckpt_dir and args.ckpt_every
                    and (i + 1) % args.ckpt_every == 0
                    and i + 1 < args.steps):
                save_ckpt(i + 1)
        if args.ckpt_dir and args.steps > start_step:
            save_ckpt(args.steps)


if __name__ == "__main__":
    main()
