"""Training launcher: HTS-RL learner over any assigned architecture.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --reduced --steps 50 --batch 8 --seq 64

On this container it runs the reduced config on 1 CPU device; on a real
cluster the same code path pjit's over make_production_mesh() (pass
--mesh pod, requires the devices to exist).

Since the api redesign this launcher is a thin shell over the
declarative surface: the flags become an ``ExperimentSpec`` (env
``token_stream`` x policy ``backbone`` x the chosen optimizer/algorithm
x runtime ``stream``) and the loop is the engine-contract stream
runtime (core/stream_runtime.py) — the same ``learner.make_train_step``
pjit over the same stream batches, so losses are step-for-step
identical with the pre-api launcher, and checkpoints written by either
resume bit-exactly under the other (the checkpoint format — the
DelayedGradState plus arch/step metadata — is unchanged).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import api
from repro.checkpoint import io as ckpt_io
from repro.core import delayed_grad
from repro.core.engine import TrainState


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--opt", default="adam", choices=["adam", "rmsprop"])
    # the token-trajectory learner implements only these two registry
    # algorithms (stale-correction algorithms need behavior-lagged
    # rollouts, which TokenStream does not produce)
    ap.add_argument("--algorithm", default="a2c", choices=["a2c", "ppo"])
    ap.add_argument("--mesh", default="host", choices=["host", "pod",
                                                       "multipod"])
    ap.add_argument("--checkpoint-dir", "--ckpt-dir", dest="ckpt_dir",
                    default=None)
    ap.add_argument("--ckpt-every", type=int, default=0, metavar="N",
                    help="save a checkpoint every N steps (0: only at "
                         "the end, when --checkpoint-dir is set)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in "
                         "--checkpoint-dir; bit-exact (the TokenStream "
                         "is fast-forwarded to the resumed step)")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()
    if args.ckpt_every and not args.ckpt_dir:
        ap.error("--ckpt-every requires --checkpoint-dir")
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --checkpoint-dir")

    # the flags, as a declarative spec (api.save-able; the same
    # experiment runs under `python -m repro.launch.run --spec ...`).
    # The stream's vocab must match the (possibly reduced) model config.
    spec = api.ExperimentSpec(
        env={"name": "token_stream",
             "kwargs": {"vocab": _vocab_of(args), "batch": args.batch,
                        "seq": args.seq}},
        policy={"name": "backbone",
                "kwargs": {"arch": args.arch, "reduced": args.reduced}},
        optimizer={"name": args.opt, "kwargs": {"lr": args.lr}},
        algorithm=args.algorithm,
        runtime={"name": "stream", "kwargs": {"mesh": args.mesh}},
        intervals=args.steps)
    session = api.build(spec)

    start_step = 0
    state = None
    if args.resume:
        path = ckpt_io.latest(args.ckpt_dir)
        if path is not None:
            meta = ckpt_io.load_metadata(path)
            # anything that changes the update math or the data stream
            # must match, or "resume" would silently train a different
            # run (validate only keys the checkpoint recorded, for
            # compatibility with older checkpoints)
            for key, have in (("arch", args.arch),
                              ("algorithm", args.algorithm),
                              ("opt", args.opt), ("batch", args.batch),
                              ("seq", args.seq)):
                if key in meta and meta[key] != have:
                    raise SystemExit(
                        f"checkpoint {path} has {key}={meta[key]!r}, "
                        f"but this run was launched with {have!r}")
            dg = ckpt_io.restore(path, jax.eval_shape(
                lambda: delayed_grad.init(session.params, session.opt)))
            start_step = int(meta.get("step", meta.get("steps", 0)))
            state = TrainState(algo=dg, env_state={}, obs={}, buffer={},
                               interval=jnp.asarray(start_step, jnp.int32))
            print(f"resuming from {path} at step {start_step}", flush=True)
    if state is None:
        state = session.state()

    t0 = time.time()

    @session.on_interval
    def _log(m):
        i = m["interval"]
        if i % args.log_every == 0 or i == args.steps - 1:
            done = i - start_step + 1
            print(f"step {i:4d} loss={m['loss']:.4f} "
                  f"pg={m['pg']:.4f} "
                  f"ent={m['entropy']:.4f} "
                  f"({(time.time() - t0) / done:.3f}s/step)",
                  flush=True)

    def save_ckpt(state: TrainState, step: int) -> None:
        # the pre-api checkpoint format, unchanged: the DelayedGradState
        # alone (launch-specific metadata carries the step), so old and
        # new launchers resume each other's checkpoints
        ckpt_io.save(f"{args.ckpt_dir}/step_{step:08d}", state.algo,
                     {"arch": args.arch, "step": step,
                      "algorithm": args.algorithm, "opt": args.opt,
                      "batch": args.batch, "seq": args.seq})
        print(f"checkpoint -> {args.ckpt_dir}/step_{step:08d}",
              flush=True)

    done = start_step
    while done < args.steps:
        # segment to the next global ckpt-every multiple (matching the
        # pre-api launcher's checkpoint boundaries exactly)
        if args.ckpt_dir and args.ckpt_every:
            stop = min(((done // args.ckpt_every) + 1) * args.ckpt_every,
                       args.steps)
        else:
            stop = args.steps
        session.run_from(state, stop - done)
        state = session.state()
        done = stop
        if args.ckpt_dir and args.ckpt_every and done < args.steps:
            save_ckpt(state, done)
    if args.ckpt_dir and args.steps > start_step:
        save_ckpt(state, args.steps)


def _vocab_of(args) -> int:
    """The (possibly reduced) model config's vocab size — what the
    token stream must emit."""
    from repro.configs.base import get_config
    cfg = get_config(args.arch)
    return (cfg.reduced() if args.reduced else cfg).vocab_size


if __name__ == "__main__":
    main()
