"""Pallas kernels (flash attention, LRU scan, WKV6) with CPU fallbacks.

Each kernel package exposes three layers:

  kernel.py  — the Pallas implementation (TPU-shaped grids/blocks);
  ref.py     — a pure-jnp oracle, used for testing and as a fallback;
  ops.py     — the jit'd public wrapper that auto-routes per backend.

Routing: on TPU the Pallas kernel runs compiled; anywhere else it runs in
``interpret=True`` mode (bit-faithful to the kernel semantics, slow), or
callers can force the jnp oracle with ``use_pallas=False``.
``resolve_backend`` centralizes that decision so the three wrappers stay
in sync.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(use_pallas, interpret):
    """Fill in auto (None) routing flags: (use_pallas, interpret)."""
    if use_pallas is None:
        use_pallas = True
    if interpret is None:
        interpret = not on_tpu()
    return use_pallas, interpret
