"""Pure-jnp oracle for the WKV6 (RWKV-6 "Finch") recurrence kernel.

Re-exports the model's reference implementation — the kernel and the model
share one source of truth for the math.
"""
from repro.models.rwkv6 import wkv6_ref  # noqa: F401
