"""Pallas TPU kernel for the RWKV-6 WKV recurrence.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

The recurrence is inherently sequential in t (this is also true of the
official CUDA kernel); parallelism comes from (B, H). Grid
(B, H, T//chunk) with chunks as the fastest (sequential) axis; the
(N, N) state lives in VMEM scratch across chunk steps.

BlockSpecs: r/k/v/w tiles (1, chunk, 1, N); u tile (1, N); o tile like r.
VMEM = 4 * chunk * N * 4B + N^2 * 4B   (chunk=128, N=64 -> 148 KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, s_out_ref,
            s_ref, *, chunk, n_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)      # (chunk, N)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    w = w_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)            # (N,)

    def body(t, s):
        kv = k[t][:, None] * v[t][None, :]      # (N, N)
        o = (r[t][:, None] * (s + u[:, None] * kv)).sum(axis=0)
        o_ref[0, t, 0] = o.astype(o_ref.dtype)
        return w[t][:, None] * s + kv

    s = jax.lax.fori_loop(0, chunk, body, s_ref[...])
    s_ref[...] = s

    @pl.when(ci == n_chunks - 1)
    def _final():
        s_out_ref[0, 0] = s_ref[...]


def wkv6(r, k, v, w, u, s0=None, *, chunk: int = 128,
         interpret: bool = True):
    """r,k,v,w: (B, T, H, N); u: (H, N); s0: (B, H, N, N) f32 or None.

    Returns (o (B,T,H,N), s_T (B,H,N,N) f32)."""
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0
    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), jnp.float32)
    grid = (B, H, T // chunk)

    o, sT = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=T // chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, N), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, N), r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return o, sT
