"""jit'd wrapper for the WKV6 kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.wkv6.kernel import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "chunk",
                                             "interpret"))
def mix(r, k, v, w, u, s0=None, *, use_pallas: bool | None = None,
        chunk: int = 128, interpret: bool | None = None):
    """use_pallas/interpret default to auto-routing per backend: compiled
    Pallas on TPU, interpreted Pallas elsewhere (repro.kernels)."""
    from repro.kernels import resolve_backend
    use_pallas, interpret = resolve_backend(use_pallas, interpret)
    if use_pallas:
        return wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=interpret)
    return wkv6_ref(r, k, v, w, u, s0)
