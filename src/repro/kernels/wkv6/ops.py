"""jit'd, differentiable wrapper for the WKV6 kernel.

The Pallas forward carries a ``jax.custom_vjp`` whose backward
differentiates the chunk-checkpointed jnp oracle on the saved inputs
(same fused-forward/XLA-backward split as flash_attention/ops.py; the
oracle's ``jax.checkpoint`` chunking keeps the backward's state storage
at chunk boundaries only). tests/test_kernels.py pins Pallas-path
gradients to the oracle-path gradients.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _mix_pallas(r, k, v, w, u, s0, chunk, interpret):
    return wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=interpret)


def _mix_fwd(r, k, v, w, u, s0, chunk, interpret):
    out = _mix_pallas(r, k, v, w, u, s0, chunk, interpret)
    return out, (r, k, v, w, u, s0)


def _mix_bwd(chunk, interpret, res, cts):
    r, k, v, w, u, s0 = res
    _, vjp = jax.vjp(
        lambda *args: wkv6_ref(*args, chunk=chunk), r, k, v, w, u, s0)
    return vjp(cts)


_mix_pallas.defvjp(_mix_fwd, _mix_bwd)


@functools.partial(jax.jit, static_argnames=("use_pallas", "chunk",
                                             "interpret"))
def mix(r, k, v, w, u, s0=None, *, use_pallas: bool | None = None,
        chunk: int = 128, interpret: bool | None = None):
    """use_pallas/interpret default to auto-routing per backend: compiled
    Pallas on TPU, interpreted Pallas elsewhere (repro.kernels). Both
    paths are differentiable (see module docstring)."""
    from repro.kernels import resolve_backend
    use_pallas, interpret = resolve_backend(use_pallas, interpret)
    if use_pallas:
        if s0 is None:
            B, _, H, N = r.shape
            s0 = jnp.zeros((B, H, N, N), jnp.float32)
        return _mix_pallas(r, k, v, w, u, s0, chunk, interpret)
    return wkv6_ref(r, k, v, w, u, s0)
