"""Pallas TPU flash attention (causal / sliding-window / softcap, GQA).

Grid: (B, H, nq, nk) — the TPU grid is executed sequentially with the last
dimension fastest, so the online-softmax state for one (b, h, qi) lives in
VMEM scratch across the nk steps and is finalized on the last one.

BlockSpecs (VMEM tiles):
  q:   (1, 1, Bq, Dh)   index (b, h, qi)          — Bq x Dh tile
  k,v: (1, 1, Bk, Dh)   index (b, h // R, ki)     — GQA: kv head shared
  out: (1, 1, Bq, Dh)

Default Bq=Bk=128 and Dh in {64,128,256}: the qk^T tile is 128x128 (MXU
native), VMEM footprint ~ (Bq*Dh + 2*Bk*Dh + Bq*Bk) * 4B  < 1 MB.

Targets TPU; validated on CPU via interpret=True against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal, window, cap, scale, kv_len, nk, bq, bk):
    b, h, qi, ki = (pl.program_id(i) for i in range(4))

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # skip fully-masked tiles (grid still iterates; compute is gated)
    run = jnp.bool_(True)
    if causal:
        run = run & (k_start <= q_start + bq - 1)
    if window:
        run = run & (k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (Bq, Dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (Bk, Dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (Bq, Bk)
        if cap:
            s = cap * jnp.tanh(s / cap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        if kv_len is not None:
            mask &= kpos < kv_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    cap: float = 0.0, kv_len=None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: (B, H, Sq, Dh); k, v: (B, KV, Sk, Dh). Returns (B, H, Sq, Dh)."""
    B, H, Sq, Dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    R = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "pad sequence to block multiple"
    nq, nk = Sq // bq, Sk // bk
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, cap=cap,
        scale=Dh ** -0.5, kv_len=kv_len, nk=nk, bq=bq, bk=bk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, Dh),
                         lambda b, h, qi, ki, R=R: (b, h // R, ki, 0)),
            pl.BlockSpec((1, 1, bk, Dh),
                         lambda b, h, qi, ki, R=R: (b, h // R, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),   # l (running denom)
            pltpu.VMEM((bq, Dh), jnp.float32),  # acc (weighted values)
        ],
        interpret=interpret,
    )(q, k, v)
