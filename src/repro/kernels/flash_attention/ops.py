"""jit'd, differentiable public wrapper for the flash attention kernel.

Accepts the model's (B, S, H, Dh) layout, transposes to the kernel's
(B, H, S, Dh), pads the sequence to a block multiple, and dispatches to
the Pallas kernel (interpret=True on CPU) or the jnp oracle.

Gradients: the Pallas forward carries a ``jax.custom_vjp`` whose
backward differentiates the jnp oracle on the saved (q, k, v) — the
standard fused-forward/XLA-backward split (the O(S^2) recompute happens
only under ``grad``; inference never pays it). A dedicated backward
kernel is a future optimization; the contract that matters — identical
gradients on the Pallas and oracle paths — is what
tests/test_kernels.py pins down.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _attend_pallas(qt, kt, vt, causal, window, cap, kv_len, bq, bk,
                   interpret):
    return flash_attention(qt, kt, vt, causal=causal, window=window,
                           cap=cap, kv_len=kv_len, bq=bq, bk=bk,
                           interpret=interpret)


def _attend_fwd(qt, kt, vt, causal, window, cap, kv_len, bq, bk,
                interpret):
    out = _attend_pallas(qt, kt, vt, causal, window, cap, kv_len, bq, bk,
                         interpret)
    return out, (qt, kt, vt)


def _attend_bwd(causal, window, cap, kv_len, bq, bk, interpret, res, g):
    qt, kt, vt = res
    _, vjp = jax.vjp(
        lambda q, k, v: flash_attention_ref(q, k, v, causal=causal,
                                            window=window, cap=cap,
                                            kv_len=kv_len),
        qt, kt, vt)
    return vjp(g)


_attend_pallas.defvjp(_attend_fwd, _attend_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "bq", "bk", "use_pallas",
                                             "interpret"))
def attend(q, k, v, *, causal: bool = True, window: int = 0,
           cap: float = 0.0, bq: int = 128, bk: int = 128,
           use_pallas: bool | None = None, interpret: bool | None = None):
    """q: (B, S, H, Dh); k, v: (B, S, KV, Dh) -> (B, S, H, Dh).

    use_pallas/interpret default to auto-routing per backend: compiled
    Pallas on TPU, interpreted Pallas elsewhere (repro.kernels). Both
    paths are differentiable (see module docstring)."""
    from repro.kernels import resolve_backend
    use_pallas, interpret = resolve_backend(use_pallas, interpret)
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    bq_ = min(bq, Sq)
    bk_ = min(bk, Sk)
    pq = (-Sq) % bq_
    pk = (-Sk) % bk_
    kv_len = Sk if pk else None
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    if use_pallas:
        ot = _attend_pallas(qt, kt, vt, causal, window, cap, kv_len,
                            bq_, bk_, interpret)
    else:
        ot = flash_attention_ref(qt, kt, vt, causal=causal, window=window,
                                 cap=cap, kv_len=kv_len)
    return jnp.transpose(ot[:, :, :Sq], (0, 2, 1, 3))
