"""Pure-jnp oracle for the flash attention kernel.

Naive materialized attention — O(S^2) memory, fine at test shapes.
Layout matches the kernel: q (B, H, Sq, Dh); k, v (B, KV, Sk, Dh),
GQA query-head h uses kv head h // (H // KV).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        cap: float = 0.0, kv_len=None):
    B, H, Sq, Dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    R = H // KV
    kr = jnp.repeat(k, R, axis=1)
    vr = jnp.repeat(v, R, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * Dh ** -0.5
    if cap:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_len is not None:
        mask &= (kpos < kv_len)[None, :]
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)
                      ).astype(q.dtype)
