"""Pallas TPU kernel for the RG-LRU linear recurrence.

TPU-native re-think of a GPU scan: instead of a two-pass Blelchoch scan
with inter-block carries in global memory, we exploit the TPU grid's
SEQUENTIAL execution order — grid (B, D//bd, S//chunk) with the sequence
chunks as the fastest axis. The running state h for one (b, d-block) lives
in VMEM scratch across chunk steps; within a chunk the recurrence is an
unrolled-by-8 fori loop over rows already resident in VMEM.

BlockSpecs: a, b, y tiles (1, chunk, bd); h0 tile (1, bd).
VMEM footprint = 3 * chunk * bd * 4B + bd * 4B  (chunk=256, bd=512 -> 1.5 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, y_ref, h_ref, *, chunk):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)          # (chunk, bd)
    b = b_ref[0].astype(jnp.float32)

    def body(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_ref[...], unroll=8)
    h_ref[...] = h


def lru_scan(a, b, h0=None, *, chunk: int = 256, bd: int = 512,
             interpret: bool = True):
    """a, b: (B, S, D); h0: (B, D) or None -> (h (B,S,D), h_last (B,D))."""
    B, S, D = a.shape
    chunk = min(chunk, S)
    bd = min(bd, D)
    assert S % chunk == 0 and D % bd == 0
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)
    grid = (B, D // bd, S // chunk)

    y = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, chunk, bd), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, bd), lambda bi, di, si: (bi, di)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd),
                               lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y, y[:, -1].astype(jnp.float32)
