"""Pure-jnp oracle for the RG-LRU linear-recurrence scan kernel.

    h_t = a_t * h_{t-1} + b_t      (elementwise over channels)

a, b: (B, S, D) f32; h0: (B, D) f32 or None. Returns (h (B,S,D), h_last).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lru_scan_ref(a, b, h0=None):
    B, S, D = a.shape
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if h0 is not None:
        bf = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return h.astype(a.dtype), h[:, -1]
