"""jit'd, differentiable wrapper for the LRU scan kernel.

The Pallas path carries a ``jax.custom_vjp`` with an ANALYTIC backward
that reuses the forward kernel: for h_t = a_t * h_{t-1} + b_t, the
cotangent recurrence lam_t = g_t + a_{t+1} * lam_{t+1} is itself a linear
recurrence run in reversed time, so the backward is one more
``lru_scan`` call (on flipped/shifted coefficients) plus elementwise
products — no O(S^2) materialization, same VMEM behavior as the forward.
Verified against ``jax.grad`` of the jnp oracle and against numerical
differences in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lru_scan.kernel import lru_scan
from repro.kernels.lru_scan.ref import lru_scan_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _scan_pallas(a, b, h0, chunk, bd, interpret):
    return lru_scan(a, b, h0, chunk=chunk, bd=bd, interpret=interpret)


def _scan_fwd(a, b, h0, chunk, bd, interpret):
    y, h_last = lru_scan(a, b, h0, chunk=chunk, bd=bd, interpret=interpret)
    # the output IS the state trajectory: h_{t-1} = y_{t-1}, so the
    # backward needs no residuals beyond (a, h0, y) — plus a zero-size
    # dtype witness so db matches b even when a and b dtypes differ
    return (y, h_last), (a, h0, y, jnp.zeros((), b.dtype))


def _scan_bwd(chunk, bd, interpret, res, cts):
    a, h0, y, b_proto = res
    gy, gh_last = cts
    af = a.astype(jnp.float32)
    c = gy.astype(jnp.float32)
    c = c.at[:, -1].add(gh_last.astype(jnp.float32))  # h_last aliases y_-1
    # lam_t = c_t + a_{t+1} lam_{t+1}  <=>  a forward LRU scan over
    # flipped time with coefficients [0, a_{S-1}, ..., a_1]
    a_rev = jnp.concatenate(
        [jnp.zeros_like(af[:, :1]), jnp.flip(af, 1)[:, :-1]], axis=1)
    mu, _ = lru_scan(a_rev, jnp.flip(c, 1), None, chunk=chunk, bd=bd,
                     interpret=interpret)
    lam = jnp.flip(mu.astype(jnp.float32), 1)
    prev_h = jnp.concatenate(
        [h0.astype(jnp.float32)[:, None], y.astype(jnp.float32)[:, :-1]],
        axis=1)
    da = (lam * prev_h).astype(a.dtype)
    db = lam.astype(b_proto.dtype)
    dh0 = (af[:, 0] * lam[:, 0]).astype(h0.dtype)
    return da, db, dh0


_scan_pallas.defvjp(_scan_fwd, _scan_bwd)


@functools.partial(jax.jit, static_argnames=("use_pallas", "chunk", "bd",
                                             "interpret"))
def scan(a, b, h0=None, *, use_pallas: bool | None = None, chunk: int = 256,
         bd: int = 512, interpret: bool | None = None):
    """use_pallas/interpret default to auto-routing per backend: compiled
    Pallas on TPU, interpreted Pallas elsewhere (repro.kernels). Both
    paths are differentiable; the Pallas backward is the kernel itself
    run in reversed time (see module docstring)."""
    from repro.kernels import resolve_backend
    use_pallas, interpret = resolve_backend(use_pallas, interpret)
    if use_pallas:
        if h0 is None:
            h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
        return _scan_pallas(a, b, h0, chunk, bd, interpret)
    return lru_scan_ref(a, b, h0)
