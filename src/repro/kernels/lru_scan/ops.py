"""jit'd wrapper for the LRU scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.lru_scan.kernel import lru_scan
from repro.kernels.lru_scan.ref import lru_scan_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "chunk", "bd",
                                             "interpret"))
def scan(a, b, h0=None, *, use_pallas: bool | None = None, chunk: int = 256,
         bd: int = 512, interpret: bool | None = None):
    """use_pallas/interpret default to auto-routing per backend: compiled
    Pallas on TPU, interpreted Pallas elsewhere (repro.kernels)."""
    from repro.kernels import resolve_backend
    use_pallas, interpret = resolve_backend(use_pallas, interpret)
    if use_pallas:
        return lru_scan(a, b, h0, chunk=chunk, bd=bd, interpret=interpret)
    return lru_scan_ref(a, b, h0)
