"""Claim 1: expected runtime of batch-synchronized rollout (paper Sec. 4.2).

    E[T_total^{n,K}] ~= K/(n a) * ( g/b * (1 + (a-1)/(b F^{-1}(1-1/n)))
                                    + F^{-1}(1-1/n) ) + K c / n

where F^{-1} is the Gamma(a, b) inverse CDF and g the Euler–Mascheroni
constant. Also provides the discrete-event simulator used to verify the
approximation (Fig. 3(a,b)) and the empirical-vs-Gamma goodness-of-fit
check from appendix A.
"""
from __future__ import annotations

import numpy as np
from scipy import stats

EULER_GAMMA = 0.5772156649015329


def expected_runtime(K: int, n: int, alpha: int, beta: float,
                     c: float = 0.0, step_shape: float = 1.0) -> float:
    """Eq. (7). K states, n envs, sync every alpha steps; each step time
    ~ Gamma(step_shape, rate=beta) so the alpha-step sum is
    Gamma(alpha*step_shape, beta) (the paper's claim uses step_shape=1,
    i.e. exponential steps; step_shape controls per-step variance at a
    fixed mean when beta = step_shape / mean). Actor compute time c/step.
    """
    a = alpha * step_shape
    Finv = stats.gamma.ppf(1.0 - 1.0 / n, a=a, scale=1.0 / beta)
    em = (EULER_GAMMA / beta) * (1.0 + (a - 1.0) / (beta * Finv)) + Finv
    return (K / (n * alpha)) * em + K * c / n


def simulate_runtime(K: int, n: int, alpha: int, beta: float,
                     c: float = 0.0, seed: int = 0,
                     dist: str = "exp", step_shape: float = 1.0) -> float:
    """Discrete-event simulation of the synchronized rollout.

    Each of the n envs performs alpha steps per interval; the interval ends
    when the slowest env finishes (max over n of a sum of alpha step times);
    total = sum over K/(n*alpha) intervals. dist: 'exp' -> step ~ Exp(beta)
    (so the alpha-sum is Gamma(alpha, beta), matching the claim's
    assumption).
    """
    rng = np.random.default_rng(seed)
    n_intervals = max(1, K // (n * alpha))
    if dist == "exp":
        sums = rng.gamma(shape=alpha * step_shape, scale=1.0 / beta,
                         size=(n_intervals, n))
    elif dist == "uniform":
        steps = rng.uniform(0, 2.0 / beta, size=(n_intervals, n, alpha))
        sums = steps.sum(-1)
    else:
        raise ValueError(dist)
    return float(sums.max(axis=1).sum() + n_intervals * alpha * c)


def async_runtime(K: int, n: int, beta: float, c: float = 0.0,
                  seed: int = 0) -> float:
    """Fully asynchronous lower bound: no synchronization, each env streams
    independently; makespan = max over envs of its own K/n step times."""
    rng = np.random.default_rng(seed)
    per_env = K // n
    times = rng.gamma(shape=per_env, scale=1.0 / beta, size=n)
    return float(times.max() + per_env * c)


def staleness_pipeline_runtime(rollout_times, learner_times,
                               staleness: int) -> float:
    """Deterministic recursion for the staleness-K slab-ring pipeline
    (DESIGN.md §4): given per-interval rollout durations R_j and serial
    per-update learner durations L_j, the coordinator's schedule is

        t_end[j]  = max(t_end[j-1] + R_j, ready[j-K])     (the interval
                     ends when its rollout finishes AND the apply has
                     consumed the learner pass over interval j-K's data
                     — the two overlap; unconstrained for j < K)
        ready[i]  = max(ready[i-1], t_end[i]) + L_i       (serial learner
                     FIFO: pass i starts when its data exists and the
                     previous pass finished)

    and the segment completes when both the last rollout and the learner
    backlog drain: max(t_end[-1], ready[-1]). At K=1 this reproduces the
    paper's per-interval max(R, L) synchronization loss; as K grows the
    bound relaxes toward max(sum R, sum L) — the same frontier
    benchmarks/staleness_sweep.py measures with real threads. Larger K
    never predicts a slower schedule on the same traces (the constraint
    set only shrinks)."""
    R = np.asarray(rollout_times, np.float64)
    L = np.asarray(learner_times, np.float64)
    if R.shape != L.shape or R.ndim != 1:
        raise ValueError(f"per-interval traces must match: {R.shape} vs "
                         f"{L.shape}")
    K = int(staleness)
    if K < 1:
        raise ValueError(f"staleness must be >= 1, got {K}")
    t_end, ready = [], []
    for j in range(len(R)):
        t = (t_end[-1] if t_end else 0.0) + R[j]
        if j - K >= 0:
            t = max(t, ready[j - K])
        t_end.append(t)
        ready.append(max(ready[-1] if ready else 0.0, t) + L[j])
    return float(max(t_end[-1], ready[-1])) if len(R) else 0.0


def gamma_fit_pvalue(samples: np.ndarray) -> float:
    """Appendix A: Kolmogorov–Smirnov goodness-of-fit of interval times to
    a Gamma distribution (fitted shape/scale)."""
    a, loc, scale = stats.gamma.fit(samples, floc=0.0)
    return float(stats.kstest(samples, "gamma", args=(a, loc, scale)).pvalue)
