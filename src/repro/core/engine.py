"""The unified runtime engine: one protocol, many schedulers.

HTS-RL's thesis is that *scheduling* (when rollouts and updates run, and
on which params) is orthogonal to the *update math* (repro.algorithms).
This module pins down the scheduling side:

  * ``HTSConfig``  — the shared hyperparameter bundle (interval length
    alpha, env count, algorithm name, seed, ...). Historically defined in
    ``mesh_runtime``; it lives here now and is re-exported from there.
  * ``Runtime``    — protocol: ``init()`` builds/rebuilds runtime state,
    ``run(n_intervals) -> RunResult`` executes that many synchronization
    intervals. Every runtime consumes ALL data it produces: after
    ``run(n)`` exactly ``n`` delayed-gradient (or plain) updates have been
    applied, so different runtimes are directly comparable (and, for the
    HTS family, bit-identical — tests/test_equivalence.py).
  * ``TrainState`` — the continuation capsule: ``state()`` captures it,
    ``run_from(state, n)`` continues from it. The contract
    (tests/test_continuation.py): ``run(a + b)`` is bit-identical to
    ``run(a)`` + ``state()`` + ``run_from(state, b)``, with a checkpoint
    save/restore round-trip (repro.checkpoint.io) allowed at every
    boundary.
  * the registry  — ``get_runtime(name)`` / ``make_runtime(name, ...)``
    resolve the built-ins lazily (so importing the engine never drags in
    threading or shard_map machinery):

      host      threaded executors/actors/learner (paper Fig. 1(e))
      mesh      single fused XLA program per interval
      sharded   data-parallel fused program via shard_map (n_envs sharded
                over the mesh 'data' axis, delayed grads all-reduced)
      sync      conventional alternating rollout/update baseline
      async     stale-policy baseline (behavior lags k updates)
      serve     policy-as-a-service inference (repro.serve): same
                construction contract, but answers action requests —
                run/run_from raise; drive it via Session.serve()

All runtime factories share one signature:

    factory(env, policy_apply, params, opt, cfg, **runtime_kwargs)

with ``env`` the *single* (unvectorized) environment; each runtime
replicates it to ``cfg.n_envs`` however its execution model requires.
"""
from __future__ import annotations

import importlib
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Protocol, runtime_checkable

import numpy as np
import jax
import jax.numpy as jnp


class HTSConfig(NamedTuple):
    alpha: int = 16
    n_envs: int = 16
    gamma: float = 0.99
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    algorithm: str = "a2c"          # any repro.algorithms registry name
    use_gae: bool = False
    gae_lambda: float = 0.95
    ppo_clip: float = 0.2
    seed: int = 0
    # staleness bound K for the HTS family: how many intervals of rollout
    # may run ahead of the learner (slab-ring depth K+1, delay-K update
    # rule — DESIGN.md §4/§5). 1 = the paper's double buffer. The sync
    # baseline has no delay and the async baseline has its own
    # AsyncConfig.staleness; both reject staleness != 1 rather than
    # silently ignore it.
    staleness: int = 1
    # which batched env implementation steps the n_envs replicas:
    # "host" vmaps the scalar env (today's semantics — the bit-exactness
    # oracle), "device" selects the env's natively-batched device-
    # resident port (repro.envs.device), stepped inside the fused scan
    # with no per-step host dispatch. Trajectories are bit-identical
    # across backends (DESIGN.md §2.2); envs without a port reject
    # "device" loudly at construction time.
    env_backend: str = "host"


class TrainState(NamedTuple):
    """Everything a runtime needs to continue training bit-exactly — the
    checkpoint capsule (a pure-array pytree, so repro.checkpoint.io can
    round-trip it with no custom serialization).

    * ``algo``      — the update-rule state: a ``DelayedGradState`` for the
      HTS family (params + behavior snapshot + opt state + step), a
      ``(params, opt_state)`` tuple for the sync baseline, and
      ``(params, opt_state, history)`` for the async baseline (the stale
      snapshot FIFO is part of the schedule, so it must survive a resume —
      otherwise the resumed policy lag would differ from the straight run).
    * ``env_state`` — stacked per-replica environment state (n_envs, ...).
    * ``obs``       — current observations (n_envs, ...).
    * ``buffer``    — slab-ring occupancy: the read storage's UNCONSUMED
      trajectories, i.e. the data the next K intervals' learner passes
      will differentiate on. At staleness=1 this is the single pending
      trajectory pytree (the paper's double buffer); at staleness=K>1
      each leaf gains a leading K axis (ring slots, oldest first —
      slots for not-yet-run intervals hold the zero trajectory). {} for
      baselines, which consume immediately.
    * ``interval``  — the global interval counter j (int32 scalar). It
      seeds the rollout step offset (j * alpha), so resuming at j draws
      exactly the (run_seed, env_id, step) PRNG keys the straight run
      would — the PRNG itself needs NO state in the capsule, because keys
      are pure functions of (seed, env_id, step) (DESIGN.md §3).
    """
    algo: Any
    env_state: Any
    obs: Any
    buffer: Any
    interval: Any


@dataclass
class RunResult:
    """What every runtime returns from ``run``.

    ``rewards``/``dones`` are (n_intervals, alpha, n_envs) numpy arrays;
    ``state`` is the runtime's full carry (a DelayedGradState for the HTS
    family). ``metrics`` (optional) carries extra per-interval streams —
    leading axis n_intervals — for runtimes whose workload has no
    reward/done semantics (the stream runtime's loss stats); the
    Session observer hook (repro.api) forwards them per interval.

    Mapping-style access (``out["params"]``, ``out["dg"]``) was
    deprecated in PR 5 and is now REMOVED — use the attributes
    (``out.params``; the old ``out["dg"]`` is ``out.state``).
    """
    params: Any
    state: Any
    steps: int
    wall_time: float
    sps: float
    rewards: np.ndarray
    dones: np.ndarray
    metrics: Any = None

    def __getitem__(self, key):
        attr = "state" if key == "dg" else key
        raise TypeError(
            f"RunResult is not a mapping (RunResult[{key!r}] was "
            f"removed after its PR-5 deprecation); use the "
            f"RunResult.{attr} attribute")

    def interval_metrics(self):
        """Yield ``(i, metrics)`` per interval: the reward/done slices
        plus any extra ``metrics`` streams, sliced on their leading
        interval axis — the one payload shape every observer consumer
        (repro.api.Session, core/trainer.Trainer) dispatches."""
        extras = self.metrics or {}
        for i in range(self.rewards.shape[0]):
            m = {"rewards": self.rewards[i], "dones": self.dones[i]}
            for key, arr in extras.items():
                m[key] = arr[i]
            yield i, m


@runtime_checkable
class Runtime(Protocol):
    name: str

    def init(self) -> None:
        """(Re)build runtime state: params/optimizer carry, env replicas,
        buffers. Calling it resets the runtime to its initial state."""
        ...

    def run(self, n_intervals: int) -> RunResult:
        """Execute ``n_intervals`` synchronization intervals FROM THE
        INITIAL STATE (every implementation calls ``init()`` first, so
        repeated ``run`` calls are independent, deterministic replays —
        which is what lets benchmarks use run-twice warmup). Compiled
        programs are cached across calls; only training state resets."""
        ...

    def state(self) -> TrainState:
        """Capture the continuation capsule. After ``run``/``run_from``
        this is the MID-STREAM state (the final interval's trajectory
        still unconsumed in ``buffer``); the RunResult's ``params`` are
        one reporting-only update ahead of ``state().algo`` because the
        trailing learner pass is never folded into the stream — that is
        what makes ``run(a+b) == run(a); run_from(state, b)`` exact."""
        ...

    def run_from(self, state: TrainState, n_intervals: int,
                 finalize: bool = True) -> RunResult:
        """Continue for ``n_intervals`` more intervals from ``state``
        (typically ``state()`` of a previous segment, possibly after a
        checkpoint round-trip). ``run(n)`` ≡ ``run_from(initial state, n)``
        ≡ any partition of n into ``run_from`` segments, bit-exactly.
        ``finalize=False`` skips the reporting-only trailing pass (the
        returned params are then mid-stream) — callers that only stream
        metrics per segment, like the trainer, avoid paying an extra
        learner update per checkpoint."""
        ...


class ScanRuntimeBase:
    """Shared plumbing for every scan-based runtime (mesh, sharded, sync,
    async): compiled programs built once and cached per ``n_intervals``,
    carry reset per ``run``, timing, and RunResult assembly. Subclasses
    fill in four hooks:

      _build()          compile-once closures (step fns, learner, ...)
      _initial_carry()  fresh training state
      _program(n)       callable (carry) -> (carry', metrics); the default
                        jits a scan of ``self._step``
      _result_state(c)  (params, state) out of the final carry

    plus four continuation hooks with defaults for the HTS carry shape
    ``(algo, env_state, obs, buffer, j)``:

      _carry_to_state(c) / _state_to_carry(s)   TrainState <-> carry
      _finalize(c)      consume the unconsumed read buffer for REPORTING
                        only (the HTS trailing learner pass); identity for
                        baselines. ``self.carry`` is never finalized — it
                        stays mid-stream so ``run_from`` cannot
                        double-consume an interval.
    """

    name: str = "?"

    def __init__(self, env, policy_apply: Callable, params, opt,
                 cfg: HTSConfig):
        self.env1 = env
        self.policy_apply = policy_apply
        self.params0 = params
        self.opt = opt
        self.cfg = cfg
        self.carry = None
        self._built = False
        self._programs: Dict[int, Callable] = {}

    # ------------------------------------------------------------ hooks
    def _build(self) -> None:
        raise NotImplementedError

    def _initial_carry(self):
        raise NotImplementedError

    def _program(self, n_intervals: int) -> Callable:
        # the carry is donated: params/opt-state/trajectory buffers are
        # updated in place instead of being copied at the program
        # boundary. Safe because every carry this is called with is
        # runtime-private: _initial_carry builds fresh arrays (and copies
        # params0), run_from copies the caller's capsule, and state()
        # copies on capture.
        return jax.jit(lambda carry: jax.lax.scan(
            self._step, carry, None, length=n_intervals),
            donate_argnums=0)

    def _result_state(self, carry):
        raise NotImplementedError

    # ------------------------------------------------- continuation hooks
    def _carry_to_state(self, carry) -> TrainState:
        algo, env_state, obs, buf, j = carry
        return TrainState(algo, env_state, obs, buf, j)

    def _state_to_carry(self, state: TrainState):
        return (state.algo, state.env_state, state.obs, state.buffer,
                state.interval)

    def _finalize(self, carry):
        """Reporting-only: drain the unconsumed read ring (the HTS
        family's K trailing learner passes). Baselines consume data
        immediately, so the default is the identity."""
        return carry

    def _host_metrics(self, metrics):
        """Bring the program's metric streams to THIS host. Identity by
        default; the sharded runtime overrides it to all-gather streams
        that live sharded across a multi-process mesh."""
        return metrics

    # --------------------------------------------------------- plumbing
    def init(self) -> None:
        if not self._built:
            self._build()
            self._built = True
        self.carry = self._initial_carry()

    def state(self) -> TrainState:
        if self.carry is None:
            self.init()
        # copy on capture: the live carry is donated to the next program
        # call, which would otherwise invalidate the capsule's buffers
        return jax.tree.map(jnp.copy, self._carry_to_state(self.carry))

    def run(self, n_intervals: int) -> RunResult:
        self.init()
        return self._segment(n_intervals)

    def run_from(self, state: TrainState, n_intervals: int,
                 finalize: bool = True) -> RunResult:
        if not self._built:
            self._build()
            self._built = True
        # copy on restore: the program donates its carry, and the caller
        # keeps (and may reuse) the capsule
        self.carry = self._state_to_carry(jax.tree.map(jnp.copy, state))
        return self._segment(n_intervals, finalize)

    def _segment(self, n_intervals: int, finalize: bool = True) -> RunResult:
        cfg = self.cfg
        if n_intervals not in self._programs:
            self._programs[n_intervals] = self._program(n_intervals)
        t0 = time.perf_counter()
        self.carry, metrics = self._programs[n_intervals](self.carry)
        # self.carry stays mid-stream (continuable); the trailing pass
        # below exists only to satisfy the run(n)-applies-n-updates
        # reporting contract of RunResult (so run_from(state_of(a), 0)
        # reports exactly run(a)'s params — the skip=(j==0) guard inside
        # _finalize keeps a fresh state at params0). finalize=False
        # callers (trainer mid-run segments) skip that reporting cost.
        final = self._finalize(self.carry) if finalize else self.carry
        params, state = self._result_state(final)
        # wall_time blocks on EVERYTHING the run produced (params AND
        # metric streams), not just the first output — async dispatch
        # must not flatter the SPS numbers
        jax.block_until_ready((params, metrics))
        wall = time.perf_counter() - t0
        metrics = self._host_metrics(metrics)
        steps = n_intervals * cfg.alpha * cfg.n_envs
        return RunResult(
            params=params, state=state, steps=steps, wall_time=wall,
            sps=steps / max(wall, 1e-9),
            rewards=np.asarray(metrics["rewards"]),
            dones=np.asarray(metrics["dones"]))


# ---------------------------------------------------------------- registry
_REGISTRY: Dict[str, Callable[..., Runtime]] = {}

# name -> module that registers it (imported on first lookup)
_LAZY: Dict[str, str] = {
    "host": "repro.core.host_runtime",
    "mesh": "repro.core.mesh_runtime",
    "sharded": "repro.core.sharded_runtime",
    "sync": "repro.core.baselines",
    "async": "repro.core.baselines",
    "serve": "repro.serve.runtime",
}

# registry entries that share the construction contract but answer
# requests instead of running training intervals (their run/state/
# run_from raise) — training-only surfaces (the SPS sweep, the
# equivalence/continuation matrices) iterate training_runtime_names()
SERVING_RUNTIMES = frozenset({"serve"})


def register_runtime(name: str):
    """Class/factory decorator: ``@register_runtime("mesh")``."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_runtime(name: str) -> Callable[..., Runtime]:
    """Resolve a runtime factory by registry name."""
    if name not in _REGISTRY and name in _LAZY:
        importlib.import_module(_LAZY[name])
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown runtime {name!r}; "
                       f"registered: {runtime_names()}") from None


def runtime_names():
    return sorted(set(_REGISTRY) | set(_LAZY))


def training_runtime_names():
    """Registry names whose run/run_from execute training intervals —
    everything but the serving entries (repro.serve.runtime)."""
    return [n for n in runtime_names() if n not in SERVING_RUNTIMES]


def make_runtime(name: str, env, policy_apply, params, opt, cfg: HTSConfig,
                 **kwargs) -> Runtime:
    """Construct a runtime: ``make_runtime("sharded", env1, papply, params,
    opt, cfg)``. ``kwargs`` are runtime-specific (e.g. ``host=HostConfig``
    for host, ``acfg=AsyncConfig`` for async, ``mesh=`` for sharded)."""
    return get_runtime(name)(env, policy_apply, params, opt, cfg, **kwargs)
