"""The unified runtime engine: one protocol, many schedulers.

HTS-RL's thesis is that *scheduling* (when rollouts and updates run, and
on which params) is orthogonal to the *update math* (repro.algorithms).
This module pins down the scheduling side:

  * ``HTSConfig``  — the shared hyperparameter bundle (interval length
    alpha, env count, algorithm name, seed, ...). Historically defined in
    ``mesh_runtime``; it lives here now and is re-exported from there.
  * ``Runtime``    — protocol: ``init()`` builds/rebuilds runtime state,
    ``run(n_intervals) -> RunResult`` executes that many synchronization
    intervals. Every runtime consumes ALL data it produces: after
    ``run(n)`` exactly ``n`` delayed-gradient (or plain) updates have been
    applied, so different runtimes are directly comparable (and, for the
    HTS family, bit-identical — tests/test_equivalence.py).
  * the registry  — ``get_runtime(name)`` / ``make_runtime(name, ...)``
    resolve the built-ins lazily (so importing the engine never drags in
    threading or shard_map machinery):

      host      threaded executors/actors/learner (paper Fig. 1(e))
      mesh      single fused XLA program per interval
      sharded   data-parallel fused program via shard_map (n_envs sharded
                over the mesh 'data' axis, delayed grads all-reduced)
      sync      conventional alternating rollout/update baseline
      async     stale-policy baseline (behavior lags k updates)

All runtime factories share one signature:

    factory(env, policy_apply, params, opt, cfg, **runtime_kwargs)

with ``env`` the *single* (unvectorized) environment; each runtime
replicates it to ``cfg.n_envs`` however its execution model requires.
"""
from __future__ import annotations

import importlib
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Protocol, runtime_checkable

import numpy as np
import jax


class HTSConfig(NamedTuple):
    alpha: int = 16
    n_envs: int = 16
    gamma: float = 0.99
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    algorithm: str = "a2c"          # any repro.algorithms registry name
    use_gae: bool = False
    gae_lambda: float = 0.95
    ppo_clip: float = 0.2
    seed: int = 0


@dataclass
class RunResult:
    """What every runtime returns from ``run``.

    ``rewards``/``dones`` are (n_intervals, alpha, n_envs) numpy arrays;
    ``state`` is the runtime's full carry (a DelayedGradState for the HTS
    family). Mapping-style access (``out["params"]``, ``out["dg"]``) is
    kept for existing benchmarks/tests.
    """
    params: Any
    state: Any
    steps: int
    wall_time: float
    sps: float
    rewards: np.ndarray
    dones: np.ndarray

    def __getitem__(self, key):
        if key == "dg":
            return self.state
        return getattr(self, key)


@runtime_checkable
class Runtime(Protocol):
    name: str

    def init(self) -> None:
        """(Re)build runtime state: params/optimizer carry, env replicas,
        buffers. Calling it resets the runtime to its initial state."""
        ...

    def run(self, n_intervals: int) -> RunResult:
        """Execute ``n_intervals`` synchronization intervals FROM THE
        INITIAL STATE (every implementation calls ``init()`` first, so
        repeated ``run`` calls are independent, deterministic replays —
        which is what lets benchmarks use run-twice warmup). Compiled
        programs are cached across calls; only training state resets."""
        ...


class ScanRuntimeBase:
    """Shared plumbing for every scan-based runtime (mesh, sharded, sync,
    async): compiled programs built once and cached per ``n_intervals``,
    carry reset per ``run``, timing, and RunResult assembly. Subclasses
    fill in four hooks:

      _build()          compile-once closures (step fns, learner, ...)
      _initial_carry()  fresh training state
      _program(n)       callable (carry) -> (carry', metrics); the default
                        jits a scan of ``self._step``
      _result_state(c)  (params, state) out of the final carry
    """

    name: str = "?"

    def __init__(self, env, policy_apply: Callable, params, opt,
                 cfg: HTSConfig):
        self.env1 = env
        self.policy_apply = policy_apply
        self.params0 = params
        self.opt = opt
        self.cfg = cfg
        self.carry = None
        self._built = False
        self._programs: Dict[int, Callable] = {}

    # ------------------------------------------------------------ hooks
    def _build(self) -> None:
        raise NotImplementedError

    def _initial_carry(self):
        raise NotImplementedError

    def _program(self, n_intervals: int) -> Callable:
        return jax.jit(lambda carry: jax.lax.scan(
            self._step, carry, None, length=n_intervals))

    def _result_state(self, carry):
        raise NotImplementedError

    # --------------------------------------------------------- plumbing
    def init(self) -> None:
        if not self._built:
            self._build()
            self._built = True
        self.carry = self._initial_carry()

    def run(self, n_intervals: int) -> RunResult:
        self.init()
        cfg = self.cfg
        if n_intervals not in self._programs:
            self._programs[n_intervals] = self._program(n_intervals)
        t0 = time.perf_counter()
        self.carry, metrics = self._programs[n_intervals](self.carry)
        params, state = self._result_state(self.carry)
        jax.block_until_ready(params)
        wall = time.perf_counter() - t0
        steps = n_intervals * cfg.alpha * cfg.n_envs
        return RunResult(
            params=params, state=state, steps=steps, wall_time=wall,
            sps=steps / max(wall, 1e-9),
            rewards=np.asarray(metrics["rewards"]),
            dones=np.asarray(metrics["dones"]))


# ---------------------------------------------------------------- registry
_REGISTRY: Dict[str, Callable[..., Runtime]] = {}

# name -> module that registers it (imported on first lookup)
_LAZY: Dict[str, str] = {
    "host": "repro.core.host_runtime",
    "mesh": "repro.core.mesh_runtime",
    "sharded": "repro.core.sharded_runtime",
    "sync": "repro.core.baselines",
    "async": "repro.core.baselines",
}


def register_runtime(name: str):
    """Class/factory decorator: ``@register_runtime("mesh")``."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_runtime(name: str) -> Callable[..., Runtime]:
    """Resolve a runtime factory by registry name."""
    if name not in _REGISTRY and name in _LAZY:
        importlib.import_module(_LAZY[name])
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown runtime {name!r}; "
                       f"registered: {runtime_names()}") from None


def runtime_names():
    return sorted(set(_REGISTRY) | set(_LAZY))


def make_runtime(name: str, env, policy_apply, params, opt, cfg: HTSConfig,
                 **kwargs) -> Runtime:
    """Construct a runtime: ``make_runtime("sharded", env1, papply, params,
    opt, cfg)``. ``kwargs`` are runtime-specific (e.g. ``host=HostConfig``
    for host, ``acfg=AsyncConfig`` for async, ``mesh=`` for sharded)."""
    return get_runtime(name)(env, policy_apply, params, opt, cfg, **kwargs)
