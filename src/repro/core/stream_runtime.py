"""The LLM-scale learner as an engine runtime: the TokenStream workload
(repro.data.pipeline) driven through the Runtime contract
(core/engine.py) instead of a bespoke launcher loop.

One "interval" = one delayed-gradient update over one (B, S) token
batch — the exact computation ``repro.launch.train`` has always run
(same ``learner.make_train_step``, same pjit shardings from
repro.sharding.rules, same stream batch order), so porting the launcher
onto this runtime changes its losses by ZERO bits. What the contract
adds on top of the loop:

  * ``run(n)`` is a reset-and-replay; ``state()``/``run_from`` give the
    continuation capsule, so ``run(a + b)`` equals ``run(a)`` +
    ``run_from(state, b)`` bit-exactly (the TokenStream is a pure
    function of (seed, step) — fast-forward IS resume);
  * ``RunResult.metrics`` streams per-interval loss stats, which the
    Session observer hook (repro.api) forwards — the launcher's
    progress printing is an observer now, not loop plumbing.

Stream-batch numbering, pinned for compatibility: batch 0 has always
been consumed by the launcher's shape probe, so interval j trains on
batch j + 1. This runtime reproduces that (the probe batch seeds the
pjit shapes), keeping new runs step-for-step loss-identical with every
run the old launcher loop ever logged or checkpointed.

This runtime is NOT in the engine name registry: every registered
factory takes a single unvectorized Env, while this one consumes a
TokenStream factory. ``repro.api.build`` constructs it for
``runtime="stream"`` specs; the workload/model pair comes from the env
("token_stream") and policy ("backbone") registries.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import delayed_grad, learner
from repro.core.engine import HTSConfig, RunResult, TrainState
from repro.optim import Optimizer
from repro.sharding import rules

# algorithms whose loss the token-trajectory learner implements
# (stale-correction algorithms need behavior-lagged rollouts, which a
# TokenStream does not produce)
_ALGORITHMS = ("a2c", "ppo")


class StreamRuntime:
    name = "stream"

    def __init__(self, stream_factory: Callable, params, opt: Optimizer,
                 cfg: HTSConfig, model_config,
                 mesh: Union[str, object, None] = "host",
                 n_microbatches: int = 1, batch=None):
        if cfg.algorithm not in _ALGORITHMS:
            raise ValueError(
                f"the stream runtime implements {list(_ALGORITHMS)}, got "
                f"algorithm {cfg.algorithm!r} (stale-correction "
                f"algorithms need behavior-lagged rollouts)")
        if cfg.staleness != 1:
            raise ValueError(
                f"the stream runtime is the delay-1 LLM learner; got "
                f"staleness={cfg.staleness}")
        self.stream_factory = stream_factory
        self.params0 = params
        self.opt = opt
        self.cfg = cfg
        self.model_config = model_config
        self.mesh = self._resolve_mesh(mesh)
        # typed geometry (repro.core.batch): grad_accumulation maps to
        # the learner's microbatch count; replica scale-out belongs to
        # the sharded runtimes, so n_replicas must be unset/1 here —
        # make_train_step validates both
        self.batch = batch
        self.n_microbatches = n_microbatches
        self._built = False
        self.dg = None
        self.stream = None
        self.j = 0
        # reporting-only live observer (repro.api.Session installs it):
        # called as ``on_interval(j, {"loss": ..., ...})`` per update
        self.on_interval: Optional[Callable[[int, dict], None]] = None

    @staticmethod
    def _resolve_mesh(mesh):
        from repro.launch.mesh import make_host_mesh, make_production_mesh
        if mesh is None or mesh == "host":
            return make_host_mesh()
        if mesh in ("pod", "multipod"):
            return make_production_mesh(multi_pod=(mesh == "multipod"))
        if isinstance(mesh, str):
            raise ValueError(f"unknown mesh name {mesh!r}; known: "
                             f"['host', 'pod', 'multipod'] (or pass a "
                             f"live Mesh via build overrides)")
        return mesh

    # ------------------------------------------------------------ build
    def _build(self) -> None:
        if self._built:
            return
        from repro.launch.mesh import as_shardings, use_mesh
        mesh, opt = self.mesh, self.opt
        step_fn = learner.make_train_step(self.model_config, opt,
                                          self.cfg.algorithm,
                                          self.n_microbatches,
                                          batch_geometry=self.batch)
        dg0 = jax.eval_shape(
            lambda: delayed_grad.init(self.params0, opt))
        # the probe batch: REAL batch 0 off a fresh stream, exactly the
        # shape probe the launcher loop took (and why training starts at
        # batch 1 — see module docstring)
        probe = self.stream_factory().next_batch()
        self._batch_shape = jax.eval_shape(lambda: probe)
        pspecs = rules.param_pspecs(
            jax.eval_shape(lambda: self.params0), mesh)
        dg_specs = rules.dg_state_pspecs(dg0, pspecs, mesh)
        b_specs = rules.batch_specs(self._batch_shape, mesh)
        out_specs = (dg_specs,
                     jax.tree.map(lambda _: P(),
                                  jax.eval_shape(step_fn, dg0, probe)[1]))
        with use_mesh(mesh):
            self._jstep = jax.jit(
                step_fn,
                in_shardings=as_shardings(mesh, (dg_specs, b_specs)),
                out_shardings=as_shardings(mesh, out_specs),
                donate_argnums=(0,))
        self._built = True

    def init(self) -> None:
        self._build()
        # params0 copied: the step donates its dg argument, and replays
        # must not chew through the caller's parameter tree
        self.dg = delayed_grad.init(
            jax.tree.map(jnp.copy, self.params0), self.opt)
        self.stream = self.stream_factory().skip(1)   # past the probe
        self.j = 0

    # ---------------------------------------------------- continuation
    def state(self) -> TrainState:
        if self.dg is None:
            self.init()
        return TrainState(
            algo=jax.tree.map(jnp.copy, self.dg),
            env_state={}, obs={}, buffer={},
            interval=jnp.asarray(self.j, jnp.int32))

    def run(self, n_intervals: int) -> RunResult:
        self.init()
        return self._segment(n_intervals)

    def run_from(self, state: TrainState, n_intervals: int,
                 finalize: bool = True) -> RunResult:
        del finalize   # updates are consumed inline; nothing trails
        self._build()
        self.dg = delayed_grad.DelayedGradState(
            *jax.tree.map(jnp.copy, tuple(state.algo)))
        self.j = int(state.interval)
        self.stream = self.stream_factory().skip(1 + self.j)
        return self._segment(n_intervals)

    # -------------------------------------------------------- the loop
    def _segment(self, n_intervals: int) -> RunResult:
        t0 = time.perf_counter()
        stats_log = []
        for j in range(self.j, self.j + n_intervals):
            batch = self.stream.next_batch()
            self.dg, stats = self._jstep(self.dg, batch)
            stats_log.append(stats)
            if self.on_interval is not None:
                self.on_interval(j, {k: float(v)
                                     for k, v in stats.items()})
        self.j += n_intervals
        metrics = {}
        if stats_log:
            metrics = {k: np.asarray([s[k] for s in stats_log],
                                     np.float32)
                       for k in stats_log[0]}
        jax.block_until_ready((self.dg.params, metrics))
        wall = time.perf_counter() - t0
        B = self.stream.batch
        S = self.stream.seq
        steps = n_intervals * B * S          # tokens = env steps
        empty = np.zeros((n_intervals, 0, 0), np.float32)
        return RunResult(
            params=self.dg.params, state=self.dg, steps=steps,
            wall_time=wall, sps=steps / max(wall, 1e-9),
            rewards=empty, dones=empty, metrics=metrics or None)
