"""Faithful threaded HTS-RL (paper Fig. 1(e) / Fig. 2(d)) on a single host.

Process layout (paper -> here): executor processes -> one persistent
thread per environment replica; actor processes -> ``n_actors``
persistent threads batching whatever observations are in the state
buffer; learner -> the coordinator thread. JAX releases the GIL inside
compiled computations, so threads give the same concurrency the paper
gets from processes (see DESIGN.md §2).

The hot path dispatches O(1) compiled programs per *batch*, not per
env-step:

  * persistent worker pools — actor/executor/stepper threads are spawned
    once per ``run`` segment and reused across all intervals (previously
    ``n_actors + n_envs`` threads were spawned and joined per interval);
  * batched env stepping — executors submit ready (env, step, action)
    requests to a stepper that groups them into ONE fixed-shape padded
    dispatch over device-resident stacked env states (previously one
    ``jit(env.step)`` dispatch + three forced host syncs per env-step);
  * per-interval seed tables — all ``(env, step)`` action and transition
    keys for an interval are derived in one device call (previously two
    ``fold_in`` dispatches per observation);
  * slab hand-off — the double buffer is a ``SlabPair`` of preallocated
    numpy slabs passed to the learner by reference (previously the whole
    interval was copied on every hand-off).

Key properties implemented exactly as in the paper:
  * state buffer / action buffer between executors and actors (queues),
    actors poll and batch asynchronously;
  * per-observation executor-attached seeds -> deterministic actions
    regardless of actor count/batching (Sec. 4.1 'full determinism');
  * two data storages with the swap barrier (core/buffers.SlabPair: the
    coordinator blocks on the previous learner before a slab is reused);
  * learner computes the gradient at theta_{j-1} on D^{theta_{j-1}} while
    executors collect D^{theta_j} — one-step delayed gradient (Eq. 6);
  * batch synchronization every alpha steps.

The actor computation and the learner update are the SAME functions the
fused/sharded runtimes use (core/rollout.actor_forward,
mesh_runtime.make_learner_update) — the thread scheduling here and the
XLA scheduling there are two executions of one program, which is why
tests/test_equivalence.py can demand bit-identical parameters. Batch
composition cannot affect values: keys are pure functions of
(seed, env_id, step) and both the actor forward and the batched env
step are vmapped row-independent programs, so ANY grouping of ready
envs — including the out-of-order groupings ``step_time`` skew produces
— writes bit-identical trajectories (tests/test_perf_guards.py).

``step_time`` (optional) injects simulated environment step durations via
``time.sleep`` for wall-clock throughput experiments.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import delayed_grad, determinism
from repro.core.buffers import SlabPair
from repro.core.engine import (HTSConfig, RunResult, TrainState,
                               register_runtime)
from repro.core.mesh_runtime import make_learner_update
from repro.core.rollout import actor_forward
from repro.envs.interfaces import Env
from repro.envs.steptime import StepTimeModel
from repro.optim import Optimizer

_SHUTDOWN = object()          # queue sentinel for pool teardown


@dataclass
class HostConfig:
    n_actors: int = 4
    step_time: Optional[StepTimeModel] = None
    time_scale: float = 1.0          # multiply simulated durations
    actor_compute: float = 0.0       # optional simulated actor latency
    profile: bool = False            # accumulate per-phase wall times


@register_runtime("host")
class HostHTSRL:
    name = "host"

    def __init__(self, env: Env, policy_apply: Callable, params,
                 opt: Optimizer, cfg: HTSConfig,
                 host: Optional[HostConfig] = None, **host_kwargs):
        self.env = env
        self.cfg = cfg
        self.host = host if host is not None else HostConfig(**host_kwargs)
        self.opt = opt
        self.policy_apply = policy_apply
        self.params0 = params
        self._built = False
        self.dg = None    # built lazily: run() always starts via init()
        self.profile: Dict[str, float] = {}
        self._prof_lock = threading.Lock()

    # ------------------------------------------------------------- build
    def _build(self) -> None:
        """Compile-once pieces (jitted fns, slab specs); reused across
        init() resets so warm reruns don't recompile or reallocate."""
        if self._built:
            return
        cfg, env, policy_apply = self.cfg, self.env, self.policy_apply
        master = jax.random.key(cfg.seed)

        self._env_reset_v = jax.jit(jax.vmap(env.reset))

        # all (env, step) action/transition keys for interval j in ONE
        # device call — the executor hot loop never touches the PRNG
        def make_tables(j):
            gsteps = j * cfg.alpha + jnp.arange(cfg.alpha, dtype=jnp.int32)
            ids = jnp.arange(cfg.n_envs, dtype=jnp.int32)

            def key_data(e, g):
                return jax.random.key_data(determinism.obs_key(master, e, g))

            def per_step(g):
                return (jax.vmap(lambda e: key_data(e, g))(ids),
                        jax.vmap(lambda e: key_data(e + 1_000_003, g))(ids))

            return jax.vmap(per_step)(gsteps)   # 2 x (alpha, n_envs, key)

        self._tables_fn = jax.jit(make_tables)

        # fixed-batch actor forward (padded to n_envs -> one compile);
        # shares core/rollout.actor_forward with the fused runtimes.
        # Keys are gathered from the interval table by (step, env) — the
        # batch composition actors happen to see cannot change them.
        def actor_fwd(p, obs, ids, ts, table):
            keys = jax.vmap(jax.random.wrap_key_data)(table[ts, ids])
            return actor_forward(policy_apply, p, obs, keys)

        self._actor_fwd = jax.jit(actor_fwd)

        # fixed-batch env stepping over device-resident stacked states:
        # gather the ready rows, vmap one step, scatter back in place
        # (donated -> XLA updates the state buffer without reallocating).
        # Padding repeats the last request; duplicate scatter indices
        # then write identical values, so the result is deterministic.
        def step_batch(env_states, actions, ids, ts, table):
            keys = jax.vmap(jax.random.wrap_key_data)(table[ts, ids])
            sel = jax.tree.map(lambda x: x[ids], env_states)
            ns, nobs, r, d = jax.vmap(env.step)(sel, actions, keys)
            env_states = jax.tree.map(
                lambda full, rows: full.at[ids].set(rows), env_states, ns)
            return env_states, nobs, r, d

        self._step_batch = jax.jit(step_batch, donate_argnums=(0,))

        learn = make_learner_update(policy_apply, self.opt, cfg)
        # trailing reporting-only pass: must NOT donate (self.dg and the
        # capsule keep using its inputs)
        self._learn_fn = jax.jit(learn)

        # in-stream learner: theta_{j-1} and the old opt state are dead
        # once the update is applied, so they are donated and updated in
        # place. params (theta_j) is NOT donated — the actor pool is
        # still sampling with it for the rest of the interval.
        def stream_learn(params_prev, opt_state, step, params, traj):
            dg = delayed_grad.DelayedGradState(params, params_prev,
                                               opt_state, step)
            return learn(dg, traj)

        self._learn_stream = jax.jit(stream_learn, donate_argnums=(0, 1))

        obs_shape = env.obs_shape
        self._spec = {
            "obs": (obs_shape, np.float32 if obs_shape else np.int32),
            "actions": ((), np.int32),
            "rewards": ((), np.float32),
            "dones": ((), np.float32),
            "behavior_logprob": ((), np.float32),
        }
        self._slabs = SlabPair(cfg.alpha, cfg.n_envs, self._spec)
        self._built = True

    def init(self) -> None:
        cfg = self.cfg
        self._build()
        # params0 is copied so in-place (donating) updates can never
        # invalidate the caller's parameter tree across run() replays
        self.dg = delayed_grad.init(jax.tree.map(jnp.copy, self.params0),
                                    self.opt)
        keys = jax.random.split(jax.random.key(cfg.seed ^ 0x5EED),
                                cfg.n_envs)
        self.env_states, obs = self._env_reset_v(keys)
        self.obs_np = np.array(obs)     # writable host copy
        self.j = 0              # global interval counter
        self.prev_traj = None   # unconsumed read-buffer trajectory
        self._reset_logs()

    def _reset_logs(self) -> None:
        self.rewards_log: list = []
        self.dones_log: list = []
        self.sps_steps = 0
        self.wall_time = 0.0
        self.profile = {}

    def _prof(self, key: str, dt: float) -> None:
        with self._prof_lock:
            self.profile[key] = self.profile.get(key, 0.0) + dt

    # ------------------------------------------------------ continuation
    def _zero_traj(self):
        """The j=0 read buffer: all-zero trajectory with dones=1 (mirrors
        mesh_runtime.init_carry so host/mesh capsules are one structure)."""
        cfg = self.cfg
        obs_shape, obs_dtype = self._spec["obs"]
        return {
            "obs": jnp.zeros((cfg.alpha, cfg.n_envs) + tuple(obs_shape),
                             obs_dtype),
            "actions": jnp.zeros((cfg.alpha, cfg.n_envs), jnp.int32),
            "rewards": jnp.zeros((cfg.alpha, cfg.n_envs), jnp.float32),
            "dones": jnp.ones((cfg.alpha, cfg.n_envs), jnp.float32),
            "behavior_logprob": jnp.zeros((cfg.alpha, cfg.n_envs),
                                          jnp.float32),
            "bootstrap_obs": jnp.zeros((cfg.n_envs,) + tuple(obs_shape),
                                       obs_dtype),
        }

    def state(self) -> TrainState:
        """The continuation capsule — structurally identical to the fused
        runtimes' (same TrainState fields, same buffer pytree), so a host
        checkpoint restores into a mesh/sharded run and vice versa. Every
        leaf is COPIED: the runtime's own buffers are donated/slab-backed
        and a later segment would otherwise mutate them under the capsule."""
        if self.dg is None:
            self.init()
        buf = (self.prev_traj if self.prev_traj is not None
               else self._zero_traj())
        capsule = TrainState(self.dg, self.env_states,
                             jnp.asarray(self.obs_np), dict(buf),
                             jnp.asarray(self.j, jnp.int32))
        return jax.tree.map(jnp.copy, capsule)

    def _restore(self, state: TrainState) -> None:
        # copies decouple the capsule from this runtime's donated buffers
        self.dg = delayed_grad.DelayedGradState(
            *jax.tree.map(jnp.copy, tuple(state.algo)))
        self.env_states = jax.tree.map(jnp.copy, state.env_state)
        self.obs_np = np.array(state.obs)
        self.j = int(state.interval)
        self.prev_traj = (jax.tree.map(jnp.asarray, dict(state.buffer))
                          if self.j > 0 else None)
        self._reset_logs()

    def run_from(self, state: TrainState, n_intervals: int,
                 finalize: bool = True) -> RunResult:
        self._build()
        self._restore(state)
        return self._segment(n_intervals, finalize)

    # ------------------------------------------------------------- pools
    def _spawn_pools(self) -> None:
        cfg = self.cfg
        # a worker that survived a previous segment's teardown (stuck in
        # a long dispatch/sleep past the join timeout) must never deliver
        # a stale result into THIS segment's fresh slot queues — that
        # would silently corrupt the trajectory. Refuse loudly instead.
        zombies = [th for th in getattr(self, "_zombies", ())
                   if th.is_alive()]
        if zombies:
            raise RuntimeError(
                f"{len(zombies)} worker thread(s) from a previous segment "
                f"are still running after teardown; refusing to start a "
                f"new segment on this runtime")
        self._state_q: "queue.Queue" = queue.Queue()
        self._step_q: "queue.Queue" = queue.Queue()
        self._action_slots = [queue.Queue() for _ in range(cfg.n_envs)]
        self._step_slots = [queue.Queue() for _ in range(cfg.n_envs)]
        self._start_barrier = threading.Barrier(cfg.n_envs + 1)
        self._end_barrier = threading.Barrier(cfg.n_envs + 1)
        self._pool_stop = False
        self._pool_exc: list = []
        self._threads = (
            [threading.Thread(target=self._guard, args=(self._actor_loop,),
                              daemon=True)
             for _ in range(self.host.n_actors)]
            + [threading.Thread(target=self._guard, args=(self._stepper_loop,),
                                daemon=True)]
            + [threading.Thread(target=self._guard,
                                args=(self._executor_loop, i), daemon=True)
               for i in range(cfg.n_envs)])
        for th in self._threads:
            th.start()

    def _release_pool_waits(self) -> None:
        """Unblock EVERY wait a pool thread can be parked on: both
        barriers, the shared request queues, and the per-env slot
        queues. Idempotent; used by normal teardown and by _guard when a
        worker dies (an executor blocked on its slot would otherwise
        never see a sentinel and leak)."""
        self._pool_stop = True
        for barrier in (self._start_barrier, self._end_barrier):
            try:
                barrier.abort()
            except Exception:
                pass
        for _ in range(self.host.n_actors):
            self._state_q.put(_SHUTDOWN)
        self._step_q.put(_SHUTDOWN)
        for slot in list(self._action_slots) + list(self._step_slots):
            slot.put(_SHUTDOWN)

    def _shutdown_pools(self) -> None:
        self._release_pool_waits()
        for th in self._threads:
            th.join(timeout=10.0)
        # keep handles to any straggler so _spawn_pools can refuse to
        # run a new segment while it is still alive
        self._zombies = [th for th in self._threads if th.is_alive()]
        self._threads = []

    def _guard(self, fn, *args) -> None:
        """Worker wrapper: record the exception and release every pool
        wait so the coordinator (and sibling workers) unblock instead of
        hanging."""
        try:
            fn(*args)
        except Exception as e:          # noqa: BLE001 — repropagated
            if self._pool_stop:
                return                  # normal teardown (aborted barrier)
            self._pool_exc.append(e)
            self._release_pool_waits()

    def _check_pool(self) -> None:
        if self._pool_exc:
            raise self._pool_exc[0]

    def _drain_batch(self, q: "queue.Queue", first) -> Optional[list]:
        """The shared actor/stepper batching protocol: take the blocking
        ``first`` item, greedily drain up to ``n_envs`` ready requests,
        and re-surface a shutdown sentinel for sibling workers. Returns
        None on shutdown."""
        if first is _SHUTDOWN:
            return None
        batch = [first]
        while len(batch) < self.cfg.n_envs:
            try:
                item = q.get_nowait()
            except queue.Empty:
                break
            if item is _SHUTDOWN:
                q.put(_SHUTDOWN)      # keep sentinel for sibling workers
                break
            batch.append(item)
        return batch

    @staticmethod
    def _pad(n: int, *cols):
        """Pad int32 request columns to the fixed dispatch width ``n`` by
        repeating the last request (identical padded rows compute —
        and, for scatters, write — identical values)."""
        out = []
        for col in cols:
            a = np.asarray(col, np.int32)
            pad = n - a.shape[0]
            out.append(np.concatenate([a, np.repeat(a[-1:], pad)])
                       if pad else a)
        return out

    # ------------------------------------------------------------ actors
    def _actor_loop(self) -> None:
        n = self.cfg.n_envs
        q = self._state_q
        prof = self.host.profile
        while True:
            batch = self._drain_batch(q, q.get())
            if batch is None:
                return
            k = len(batch)
            ids, ts = self._pad(n, [b[0] for b in batch],
                                [b[1] for b in batch])
            obs = np.stack([b[2] for b in batch])
            if k < n:
                obs = np.concatenate([obs, np.repeat(obs[-1:], n - k, 0)])
            if self.host.actor_compute:
                time.sleep(self.host.actor_compute * self.host.time_scale)
            t0 = time.perf_counter() if prof else 0.0
            actions, blp = self._actor_fwd(self._behavior, obs, ids, ts,
                                           self._actor_table)
            actions = np.asarray(actions)
            blp = np.asarray(blp)
            if prof:
                self._prof("actor_forward", time.perf_counter() - t0)
            for i in range(k):
                self._action_slots[ids[i]].put(
                    (int(actions[i]), float(blp[i])))

    # ----------------------------------------------------------- stepper
    def _stepper_loop(self) -> None:
        """Groups ready (env, step, action) requests into one padded
        fixed-shape dispatch. Which envs land in which group is racy and
        irrelevant: each row's transition depends only on its own
        (state, action, key)."""
        n = self.cfg.n_envs
        q = self._step_q
        prof = self.host.profile
        while True:
            batch = self._drain_batch(q, q.get())
            if batch is None:
                return
            k = len(batch)
            ids, ts, acts = self._pad(n, [b[0] for b in batch],
                                      [b[1] for b in batch],
                                      [b[2] for b in batch])
            t0 = time.perf_counter() if prof else 0.0
            self.env_states, nobs, r, d = self._step_batch(
                self.env_states, acts, ids, ts, self._step_table)
            nobs = np.asarray(nobs)
            r = np.asarray(r)
            d = np.asarray(d)
            if prof:
                self._prof("env_step_dispatch", time.perf_counter() - t0)
            for i in range(k):
                self._step_slots[ids[i]].put(
                    (nobs[i], float(r[i]), float(d[i])))

    # --------------------------------------------------------- executors
    def _executor_loop(self, env_id: int) -> None:
        cfg, host = self.cfg, self.host
        prof = host.profile
        while True:
            try:
                self._start_barrier.wait()
            except threading.BrokenBarrierError:
                return                  # pool teardown
            if self._pool_stop:
                return
            j = self._cur_j
            slab, boot = self._cur_slab, self._cur_boot
            obs = self.obs_np[env_id]
            for t in range(cfg.alpha):
                self._state_q.put((env_id, t, obs))
                t0 = time.perf_counter() if prof else 0.0
                got = self._action_slots[env_id].get()
                if got is _SHUTDOWN:
                    return              # a sibling worker died mid-interval
                action, blp = got
                if prof:
                    self._prof("actor_wait", time.perf_counter() - t0)
                if host.step_time is not None:
                    dt = host.step_time.sample(env_id, j * cfg.alpha + t,
                                               cfg.seed)
                    time.sleep(dt * host.time_scale)
                    if prof:
                        self._prof("sim_env_sleep", dt * host.time_scale)
                self._step_q.put((env_id, t, action))
                t0 = time.perf_counter() if prof else 0.0
                got = self._step_slots[env_id].get()
                if got is _SHUTDOWN:
                    return
                nobs, r, d = got
                if prof:
                    self._prof("env_step_wait", time.perf_counter() - t0)
                slab["obs"][t, env_id] = obs
                slab["actions"][t, env_id] = action
                slab["rewards"][t, env_id] = r
                slab["dones"][t, env_id] = d
                slab["behavior_logprob"][t, env_id] = blp
                obs = nobs
            self.obs_np[env_id] = obs
            boot[env_id] = obs
            self._end_barrier.wait()

    # --------------------------------------------------------------- run
    def run(self, n_intervals: int) -> RunResult:
        self.init()   # engine contract: every run starts from params0
        return self._segment(n_intervals)

    def _run_intervals(self, n_intervals: int) -> None:
        cfg = self.cfg
        prof = self.host.profile
        self._spawn_pools()
        try:
            prev_traj = self.prev_traj
            for j in range(self.j, self.j + n_intervals):
                self._check_pool()
                # swap barrier: the learner dispatched LAST interval read
                # the slab this interval overwrites — "write full AND
                # read exhausted" before the roles flip (DESIGN.md §4)
                t0 = time.perf_counter() if prof else 0.0
                jax.block_until_ready(self.dg)
                if prof:
                    self._prof("learner_drain", time.perf_counter() - t0)
                slab, boot = self._slabs.write_view(j)
                self._cur_j = j
                self._cur_slab, self._cur_boot = slab, boot
                self._behavior = self.dg.params     # theta_j
                self._actor_table, self._step_table = self._tables_fn(
                    jnp.asarray(j, jnp.int32))
                self._start_barrier.wait()          # release executors
                # learner runs concurrently on the previous interval's
                # data (one-step delayed gradient, Eq. 6)
                if prev_traj is not None:
                    self.dg = self._learn_stream(
                        self.dg.params_prev, self.dg.opt_state,
                        self.dg.step, self.dg.params, prev_traj)
                t0 = time.perf_counter() if prof else 0.0
                self._end_barrier.wait()            # executors finished
                if prof:
                    self._prof("interval_barrier",
                               time.perf_counter() - t0)
                # interval done: hand the slab to the learner by
                # reference; only the small reporting streams are copied
                prev_traj = self._slabs.as_traj(j)
                self.rewards_log.append(slab["rewards"].copy())
                self.dones_log.append(slab["dones"].copy())
                self.sps_steps += cfg.alpha * cfg.n_envs
            self.j += n_intervals
            self.prev_traj = prev_traj
        except threading.BrokenBarrierError:
            self._check_pool()
            raise
        finally:
            self._shutdown_pools()
        self._check_pool()

    def _segment(self, n_intervals: int, finalize: bool = True) -> RunResult:
        cfg = self.cfg
        t_start = time.perf_counter()
        if n_intervals > 0:
            self._run_intervals(n_intervals)
        # trailing learner pass on the final interval's data — REPORTING
        # ONLY: self.dg stays mid-stream (prev_traj unconsumed), so
        # state()/run_from continue bit-exactly without double-applying
        # this update (same split as ScanRuntimeBase._finalize).
        dg_final = self.dg
        if finalize and self.prev_traj is not None:
            dg_final = self._learn_fn(self.dg, self.prev_traj)
        jax.block_until_ready(dg_final)   # honest wall time / SPS
        self.wall_time = time.perf_counter() - t_start
        empty = np.zeros((0, cfg.alpha, cfg.n_envs), np.float32)
        return RunResult(
            params=dg_final.params, state=dg_final, steps=self.sps_steps,
            wall_time=self.wall_time,
            sps=self.sps_steps / max(self.wall_time, 1e-9),
            rewards=np.stack(self.rewards_log) if self.rewards_log else empty,
            dones=np.stack(self.dones_log) if self.dones_log else empty)
