"""Faithful threaded HTS-RL (paper Fig. 1(e) / Fig. 2(d)) on a single host.

Process layout (paper -> here): executor processes -> one thread per
environment replica; actor processes -> ``n_actors`` threads batching
whatever observations are in the state buffer; learner -> the coordinator
thread. JAX releases the GIL inside compiled computations, so threads give
the same concurrency the paper gets from processes (see DESIGN.md §2).

Key properties implemented exactly as in the paper:
  * state buffer / action buffer between executors and actors (queues),
    actors poll and batch asynchronously;
  * per-observation executor-attached seeds -> deterministic actions
    regardless of actor count/batching (Sec. 4.1 'full determinism');
  * two data storages with the swap barrier (core/buffers.py);
  * learner computes the gradient at theta_{j-1} on D^{theta_{j-1}} while
    executors collect D^{theta_j} — one-step delayed gradient (Eq. 6);
  * batch synchronization every alpha steps.

The actor computation and the learner update are the SAME functions the
fused/sharded runtimes use (core/rollout.actor_forward,
mesh_runtime.make_learner_update) — the thread scheduling here and the
XLA scheduling there are two executions of one program, which is why
tests/test_equivalence.py can demand bit-identical parameters.

``step_time`` (optional) injects simulated environment step durations via
``time.sleep`` for wall-clock throughput experiments.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import delayed_grad, determinism
from repro.core.buffers import DoubleBuffer
from repro.core.engine import (HTSConfig, RunResult, TrainState,
                               register_runtime)
from repro.core.mesh_runtime import make_learner_update
from repro.core.rollout import actor_forward
from repro.envs.interfaces import Env
from repro.envs.steptime import StepTimeModel
from repro.optim import Optimizer


@dataclass
class HostConfig:
    n_actors: int = 4
    step_time: Optional[StepTimeModel] = None
    time_scale: float = 1.0          # multiply simulated durations
    actor_compute: float = 0.0       # optional simulated actor latency


@register_runtime("host")
class HostHTSRL:
    name = "host"

    def __init__(self, env: Env, policy_apply: Callable, params,
                 opt: Optimizer, cfg: HTSConfig,
                 host: Optional[HostConfig] = None, **host_kwargs):
        self.env = env
        self.cfg = cfg
        self.host = host if host is not None else HostConfig(**host_kwargs)
        self.opt = opt
        self.policy_apply = policy_apply
        self.params0 = params
        self._built = False
        self.dg = None    # built lazily: run() always starts via init()

    def _build(self) -> None:
        """Compile-once pieces (jitted fns, storage specs); reused across
        init() resets so warm reruns don't recompile."""
        if self._built:
            return
        cfg, env, policy_apply = self.cfg, self.env, self.policy_apply
        self._env_step = jax.jit(env.step)
        self._env_reset = jax.jit(env.reset)

        # fixed-batch actor forward (padded to n_envs -> one compile);
        # shares core/rollout.actor_forward with the fused runtimes
        def actor_fwd(p, obs, seeds):
            keys = jax.vmap(jax.random.wrap_key_data)(seeds)
            return actor_forward(policy_apply, p, obs, keys)

        self._actor_fwd = jax.jit(actor_fwd)
        self._learn_fn = jax.jit(
            make_learner_update(policy_apply, self.opt, cfg))
        obs_shape = env.obs_shape
        self._spec = {
            "obs": (obs_shape, np.float32 if obs_shape else np.int32),
            "actions": ((), np.int32),
            "rewards": ((), np.float32),
            "dones": ((), np.float32),
            "behavior_logprob": ((), np.float32),
        }
        self._built = True

    def init(self) -> None:
        cfg = self.cfg
        self._build()
        self.master = jax.random.key(cfg.seed)
        self.dg = delayed_grad.init(self.params0, self.opt)
        spec = self._spec
        self.buffer = DoubleBuffer(cfg.alpha * cfg.n_envs, spec)
        self.bootstrap_obs = np.zeros((cfg.n_envs,) + tuple(spec["obs"][0]),
                                      spec["obs"][1])
        # per-env current state/obs
        keys = jax.random.split(jax.random.key(cfg.seed ^ 0x5EED),
                                cfg.n_envs)
        self.env_states, self.obs = [], []
        for i in range(cfg.n_envs):
            s, o = self._env_reset(keys[i])
            self.env_states.append(s)
            self.obs.append(np.asarray(o))
        self.j = 0              # global interval counter
        self.prev_traj = None   # unconsumed read-buffer trajectory
        self._reset_logs()

    def _reset_logs(self) -> None:
        self.rewards_log: list = []
        self.dones_log: list = []
        self.sps_steps = 0
        self.wall_time = 0.0

    # ------------------------------------------------------ continuation
    def _zero_traj(self):
        """The j=0 read buffer: all-zero trajectory with dones=1 (mirrors
        mesh_runtime.init_carry so host/mesh capsules are one structure)."""
        cfg = self.cfg
        obs_shape, obs_dtype = self._spec["obs"]
        return {
            "obs": jnp.zeros((cfg.alpha, cfg.n_envs) + tuple(obs_shape),
                             obs_dtype),
            "actions": jnp.zeros((cfg.alpha, cfg.n_envs), jnp.int32),
            "rewards": jnp.zeros((cfg.alpha, cfg.n_envs), jnp.float32),
            "dones": jnp.ones((cfg.alpha, cfg.n_envs), jnp.float32),
            "behavior_logprob": jnp.zeros((cfg.alpha, cfg.n_envs),
                                          jnp.float32),
            "bootstrap_obs": jnp.zeros((cfg.n_envs,) + tuple(obs_shape),
                                       obs_dtype),
        }

    def state(self) -> TrainState:
        """The continuation capsule — structurally identical to the fused
        runtimes' (same TrainState fields, same buffer pytree), so a host
        checkpoint restores into a mesh/sharded run and vice versa."""
        if self.dg is None:
            self.init()
        env_state = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *self.env_states)
        buf = (self.prev_traj if self.prev_traj is not None
               else self._zero_traj())
        return TrainState(self.dg, env_state,
                          jnp.asarray(np.stack(self.obs)), buf,
                          jnp.asarray(self.j, jnp.int32))

    def _restore(self, state: TrainState) -> None:
        cfg = self.cfg
        self.master = jax.random.key(cfg.seed)
        self.dg = delayed_grad.DelayedGradState(*state.algo)
        self.buffer = DoubleBuffer(cfg.alpha * cfg.n_envs, self._spec)
        obs = np.asarray(state.obs)
        self.obs = [obs[i].copy() for i in range(cfg.n_envs)]
        self.env_states = [jax.tree.map(lambda x: x[i], state.env_state)
                           for i in range(cfg.n_envs)]
        self.bootstrap_obs = obs.copy()
        self.j = int(state.interval)
        self.prev_traj = (jax.tree.map(jnp.asarray, dict(state.buffer))
                          if self.j > 0 else None)
        self._reset_logs()

    def run_from(self, state: TrainState, n_intervals: int,
                 finalize: bool = True) -> RunResult:
        self._build()
        self._restore(state)
        return self._segment(n_intervals, finalize)

    # ------------------------------------------------------------ actors
    def _actor_loop(self, state_q: "queue.Queue", action_slots, params):
        n = self.cfg.n_envs
        while True:
            try:
                first = state_q.get(timeout=5.0)
            except queue.Empty:
                return
            if first is None:
                return
            batch = [first]
            while len(batch) < n:
                try:
                    batch.append(state_q.get_nowait())
                except queue.Empty:
                    break
            if batch[-1] is None:
                state_q.put(None)      # keep sentinel for other actors
                batch = batch[:-1]
                if not batch:
                    return
            env_ids = [b[0] for b in batch]
            obs = np.stack([b[2] for b in batch])
            seeds = np.stack([b[3] for b in batch])
            pad = n - len(batch)
            if pad:
                obs = np.concatenate([obs, np.zeros((pad,) + obs.shape[1:],
                                                    obs.dtype)])
                seeds = np.concatenate([seeds, seeds[-1:].repeat(pad, 0)])
            if self.host.actor_compute:
                time.sleep(self.host.actor_compute * self.host.time_scale)
            actions, blp = self._actor_fwd(params, jnp.asarray(obs),
                                           jnp.asarray(seeds))
            actions = np.asarray(actions)
            blp = np.asarray(blp)
            for i, eid in enumerate(env_ids):
                action_slots[eid].put((int(actions[i]), float(blp[i])))

    # --------------------------------------------------------- executors
    def _executor_loop(self, env_id: int, interval_j: int,
                       state_q: "queue.Queue", action_slots):
        cfg, host = self.cfg, self.host
        obs = self.obs[env_id]
        state = self.env_states[env_id]
        for t in range(cfg.alpha):
            gstep = interval_j * cfg.alpha + t
            key = determinism.obs_key(self.master, env_id, gstep)
            seed = np.asarray(jax.random.key_data(key))
            state_q.put((env_id, t, obs, seed))
            action, blp = action_slots[env_id].get()
            if host.step_time is not None:
                dt = host.step_time.sample(env_id, gstep, cfg.seed)
                time.sleep(dt * host.time_scale)
            skey = determinism.obs_key(self.master, env_id + 1_000_003,
                                       gstep)
            state, nobs, r, d = self._env_step(state, jnp.asarray(action),
                                               skey)
            nobs = np.asarray(nobs)
            self.buffer.write_storage.write_slot(
                t * cfg.n_envs + env_id,
                obs=obs, actions=action, rewards=float(r), dones=float(d),
                behavior_logprob=blp)
            obs = nobs
        with self.buffer.cv:
            self.buffer.write_storage.advance(cfg.alpha)
        self.obs[env_id] = obs
        self.env_states[env_id] = state
        self.bootstrap_obs[env_id] = obs

    # ------------------------------------------------------------- learn
    def _learn(self, read_traj):
        self.dg = self._learn_fn(self.dg, read_traj)

    def _storage_to_traj(self, storage, bootstrap_obs):
        # NOTE: explicit .copy() — jnp.asarray on the CPU backend can alias
        # the numpy buffer zero-copy, and both the storages (after a swap)
        # and bootstrap_obs are mutated in place by the next interval's
        # executors while the learner is still reading this snapshot.
        cfg = self.cfg
        out = {}
        for k, arr in storage.data.items():
            out[k] = jnp.asarray(
                arr.reshape((cfg.alpha, cfg.n_envs) + arr.shape[1:]).copy())
        out["bootstrap_obs"] = jnp.asarray(bootstrap_obs.copy())
        return out

    # --------------------------------------------------------------- run
    def run(self, n_intervals: int) -> RunResult:
        self.init()   # engine contract: every run starts from params0
        return self._segment(n_intervals)

    def _segment(self, n_intervals: int, finalize: bool = True) -> RunResult:
        cfg = self.cfg
        t_start = time.perf_counter()
        prev_traj = self.prev_traj
        for j in range(self.j, self.j + n_intervals):
            state_q: "queue.Queue" = queue.Queue()
            action_slots = {i: queue.Queue() for i in range(cfg.n_envs)}
            behavior = self.dg.params     # theta_j
            actors = [threading.Thread(
                target=self._actor_loop, args=(state_q, action_slots,
                                               behavior), daemon=True)
                for _ in range(self.host.n_actors)]
            execs = [threading.Thread(
                target=self._executor_loop, args=(i, j, state_q,
                                                  action_slots), daemon=True)
                for i in range(cfg.n_envs)]
            for th in actors + execs:
                th.start()
            # learner runs concurrently on the *previous* interval's data
            if prev_traj is not None:
                self._learn(prev_traj)
            for th in execs:
                th.join()
            state_q.put(None)
            for th in actors:
                th.join()
            # interval done: record, snapshot read data, swap storages
            st = self.buffer.write_storage
            prev_traj = self._storage_to_traj(st, self.bootstrap_obs)
            r = st.data["rewards"].reshape(cfg.alpha, cfg.n_envs)
            d = st.data["dones"].reshape(cfg.alpha, cfg.n_envs)
            self.rewards_log.append(r.copy())
            self.dones_log.append(d.copy())
            self.sps_steps += cfg.alpha * cfg.n_envs
            self.buffer.swap()
        self.j += n_intervals
        self.prev_traj = prev_traj
        # trailing learner pass on the final interval's data — REPORTING
        # ONLY: self.dg stays mid-stream (prev_traj unconsumed), so
        # state()/run_from continue bit-exactly without double-applying
        # this update (same split as ScanRuntimeBase._finalize).
        dg_final = self.dg
        if finalize and prev_traj is not None:
            dg_final = self._learn_fn(self.dg, prev_traj)
        self.wall_time = time.perf_counter() - t_start
        empty = np.zeros((0, cfg.alpha, cfg.n_envs), np.float32)
        return RunResult(
            params=dg_final.params, state=dg_final, steps=self.sps_steps,
            wall_time=self.wall_time,
            sps=self.sps_steps / max(self.wall_time, 1e-9),
            rewards=np.stack(self.rewards_log) if self.rewards_log else empty,
            dones=np.stack(self.dones_log) if self.dones_log else empty)
