"""Faithful threaded HTS-RL (paper Fig. 1(e) / Fig. 2(d)) on a single host.

Process layout (paper -> here): executor processes -> one persistent
thread per environment replica; actor processes -> ``n_actors``
persistent threads batching whatever observations are in the state
buffer; learner -> the coordinator thread. JAX releases the GIL inside
compiled computations, so threads give the same concurrency the paper
gets from processes (see DESIGN.md §2).

The hot path dispatches O(1) compiled programs per *batch*, not per
env-step:

  * persistent worker pools — actor/executor/stepper threads are spawned
    once per ``run`` segment and reused across all intervals;
  * batched env stepping — executors submit ready (env, step, action)
    requests to a stepper that groups them into ONE fixed-shape padded
    dispatch over device-resident stacked env states;
  * per-interval seed tables — all ``(env, step)`` action and transition
    keys for an interval are derived in one device call;
  * slab hand-off — the trajectory storage is a ``SlabRing`` of K+1
    preallocated numpy slabs passed to the learner by reference.

The staleness-K pipeline (``HTSConfig.staleness``; DESIGN.md §4): the
learner is split into a *gradient* pass and an *apply* pass. The
gradient for interval ``j``'s data is dispatched the moment interval
``j`` finishes — at theta_j, the params that generated it — and applied
K intervals later (delay-K update, Eq. 6 generalized):

    theta_{j+1} = theta_j + eta * grad J(theta_{j-K}, D^{theta_{j-K}})

so every gradient has K intervals of rollout wall time to complete
before anything blocks on it. At K=1 this is exactly the paper's
double-buffer schedule (the coordinator effectively blocks on the
previous interval's learner); at K>1 the coordinator only blocks on the
learner pass from K+1 intervals back, which is what recovers
asynchronous-style throughput under a slow learner while keeping the
staleness bound — and the determinism contract — intact
(benchmarks/staleness_sweep.py measures the frontier).

Key properties implemented exactly as in the paper:
  * state buffer / action buffer between executors and actors (queues),
    actors poll and batch asynchronously;
  * per-observation executor-attached seeds -> deterministic actions
    regardless of actor count/batching (Sec. 4.1 'full determinism');
  * K+1 data storages with the ring barrier (core/buffers.SlabRing: the
    coordinator blocks on the gradient pass that read a slab before the
    slab is reused);
  * batch synchronization every alpha steps.

The actor computation and the learner update are the SAME functions the
fused/sharded runtimes use (core/rollout.actor_forward,
mesh_runtime.make_learner_update and its grad/apply split) — the thread
scheduling here and the XLA scheduling there are two executions of one
program, which is why tests/test_equivalence.py and tests/
test_staleness.py can demand bit-identical parameters at every K. Batch
composition cannot affect values: keys are pure functions of
(seed, env_id, step) and both the actor forward and the batched env
step are vmapped row-independent programs, so ANY grouping of ready
envs — including the out-of-order groupings ``step_time`` skew produces
— writes bit-identical trajectories (tests/test_perf_guards.py).

``step_time`` (optional) injects simulated environment step durations via
``time.sleep``; ``learner_time`` injects a simulated per-update learner
duration (a dedicated sim thread completes gradient passes FIFO, one
``learner_time`` apart — a serial learner) for wall-clock throughput
experiments. Neither changes a single computed value.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import delayed_grad, determinism
from repro.core.buffers import SlabRing
from repro.core.engine import (HTSConfig, RunResult, TrainState,
                               register_runtime)
from repro.core.mesh_runtime import (make_grad_fn, make_learner_update,
                                     make_ring_drain)
from repro.core.rollout import actor_forward
from repro.envs.interfaces import Env
from repro.envs.steptime import StepTimeModel
from repro.faults import FaultInjector, FaultPlan
from repro.optim import Optimizer

_SHUTDOWN = object()          # queue sentinel for pool teardown


@dataclass
class HostConfig:
    n_actors: int = 4
    step_time: Optional[StepTimeModel] = None
    time_scale: float = 1.0          # multiply simulated durations
    actor_compute: float = 0.0       # optional simulated actor latency
    # simulated per-update learner duration: a float (constant) or a
    # StepTimeModel sampled per update index — deterministic like
    # step_time, so throughput experiments are replayable
    learner_time: "float | StepTimeModel" = 0.0
    profile: bool = False            # accumulate per-phase wall times


@register_runtime("host")
class HostHTSRL:
    name = "host"

    def __init__(self, env: Env, policy_apply: Callable, params,
                 opt: Optimizer, cfg: HTSConfig,
                 host: Optional[HostConfig] = None,
                 faults: "Optional[FaultInjector | FaultPlan]" = None,
                 batch=None, **host_kwargs):
        if host is not None and host_kwargs:
            # both forms at once used to silently discard the kwargs —
            # e.g. HostHTSRL(..., host=HostConfig(), n_actors=8) ran
            # with 4 actors and nobody noticed
            raise TypeError(
                f"pass either host=HostConfig(...) or HostConfig field "
                f"kwargs, not both (got host and {sorted(host_kwargs)})")
        if cfg.staleness < 1:
            raise ValueError(f"staleness must be >= 1, got {cfg.staleness}")
        self.env = env
        # the batched env the stepper dispatches: vmapped scalar env
        # ("host", today's semantics) or the natively-batched device
        # port ("device" — same thread/dispatch cadence, scatter-free
        # batched programs; the fused runtimes move this whole loop
        # on-device). Bit-identical either way (DESIGN.md §2.2);
        # resolved HERE so bad backends/envs fail at construction.
        from repro.envs.device import batched_env
        self.venv = batched_env(env, cfg.n_envs, cfg.env_backend)
        self.cfg = cfg
        self.host = host if host is not None else HostConfig(**host_kwargs)
        # batch geometry (repro.core.batch): the host runtime has one
        # replica, so any configured (grad_accumulation, n_replicas)
        # factorization is reproduced as chunks = A*R sequential
        # microbatch blocks inside the gradient pass — bit-exact to the
        # physically-replicated run by the canonical-reduction contract
        # (DESIGN.md §12). micro_batch is thus the gradient block size;
        # the slab ring stays (alpha, n_envs) — actors fill the global
        # slab, the learner scans it in micro_batch-sized blocks.
        from repro.core.batch import BatchConfig
        self.batch = BatchConfig.of(batch)
        self.geometry = self.batch.resolve(cfg.n_envs, default_replicas=1)
        self.opt = opt
        self.policy_apply = policy_apply
        self.params0 = params
        # deterministic chaos (DESIGN.md §11): worker loops and the
        # coordinator poll this injector at their logical (site,
        # interval) points. An injected exc rides the SAME paths a real
        # failure does — _guard capture for workers, coordinator raise
        # for the learner — so the chaos tests exercise the production
        # failure machinery, not a parallel one. None (default): zero
        # hot-path cost beyond one attribute check per dispatch.
        if faults is not None and not isinstance(faults, FaultInjector):
            faults = FaultInjector(FaultPlan.of(faults))
        self._faults = faults
        self._built = False
        self.dg = None    # built lazily: run() always starts via init()
        self.profile: Dict[str, float] = {}
        self._prof_lock = threading.Lock()
        # reporting-only live observer: called by the coordinator as
        # ``on_interval(j, {"rewards": (alpha, n_envs), "dones": ...})``
        # the moment interval j's slab is complete (repro.api.Session
        # installs it). Never touches the training computation.
        self.on_interval: Optional[Callable[[int, dict], None]] = None

    # ------------------------------------------------------------- build
    def _build(self) -> None:
        """Compile-once pieces (jitted fns, slab specs); reused across
        init() resets so warm reruns don't recompile or reallocate."""
        if self._built:
            return
        cfg, env, policy_apply = self.cfg, self.env, self.policy_apply
        master = jax.random.key(cfg.seed)

        venv = self.venv            # resolved at construction (__init__)
        self._env_reset_v = jax.jit(venv.reset)

        # all (env, step) action/transition keys for interval j in ONE
        # device call — the executor hot loop never touches the PRNG
        def make_tables(j):
            gsteps = j * cfg.alpha + jnp.arange(cfg.alpha, dtype=jnp.int32)
            ids = jnp.arange(cfg.n_envs, dtype=jnp.int32)

            def key_data(e, g):
                return jax.random.key_data(determinism.obs_key(master, e, g))

            def per_step(g):
                return (jax.vmap(lambda e: key_data(e, g))(ids),
                        jax.vmap(lambda e: key_data(e + 1_000_003, g))(ids))

            return jax.vmap(per_step)(gsteps)   # 2 x (alpha, n_envs, key)

        self._tables_fn = jax.jit(make_tables)

        # fixed-batch actor forward (padded to n_envs -> one compile);
        # shares core/rollout.actor_forward with the fused runtimes.
        # Keys are gathered from the interval table by (step, env) — the
        # batch composition actors happen to see cannot change them.
        def actor_fwd(p, obs, ids, ts, table):
            keys = jax.vmap(jax.random.wrap_key_data)(table[ts, ids])
            return actor_forward(policy_apply, p, obs, keys)

        self._actor_fwd = jax.jit(actor_fwd)

        # fixed-batch env stepping over device-resident stacked states:
        # gather the ready rows, vmap one step, scatter back in place
        # (donated -> XLA updates the state buffer without reallocating).
        # Padding repeats the last request; duplicate scatter indices
        # then write identical values, so the result is deterministic.
        def step_batch(env_states, actions, ids, ts, table):
            keys = jax.vmap(jax.random.wrap_key_data)(table[ts, ids])
            sel = jax.tree.map(lambda x: x[ids], env_states)
            ns, nobs, r, d = venv.step(sel, actions, keys)
            env_states = jax.tree.map(
                lambda full, rows: full.at[ids].set(rows), env_states, ns)
            return env_states, nobs, r, d

        self._step_batch = jax.jit(step_batch, donate_argnums=(0,))

        # the learner, split at the staleness pipeline's joint:
        #   grad   — dispatched the moment interval j's data is complete,
        #            at theta_j (the params that generated it). Depends
        #            only on (theta_j, D_j), so it runs concurrently with
        #            the next K intervals of rollout.
        #   apply  — consumes the K-intervals-old pending gradient and
        #            advances (params, behavior history, opt state).
        # The fused runtimes compute the identical composition inside one
        # XLA program; splitting changes scheduling, not values.
        self._grad_fn = jax.jit(make_grad_fn(
            policy_apply, cfg, grad_accumulation=self.geometry.chunks))

        def stream_apply(params_prev, opt_state, step, params, grads):
            dg = delayed_grad.DelayedGradState(params, params_prev,
                                               opt_state, step)
            return delayed_grad.update(dg, grads, self.opt)

        # theta_{j-K} (the history's oldest slot) and the old opt state
        # are dead once the update is applied, so they are donated and
        # updated in place. params (theta_j) is NOT donated — the actor
        # pool is still sampling with it, and in-flight gradient passes
        # read the unstacked theta buffers it chains from.
        self._apply_fn = jax.jit(stream_apply, donate_argnums=(0, 1))

        # trailing reporting-only drain of the K pending ring slots: the
        # SAME drain the fused runtimes jit (make_ring_drain), must NOT
        # donate (self.dg and the capsule keep using its inputs)
        learn = make_learner_update(
            policy_apply, self.opt, cfg,
            grad_accumulation=self.geometry.chunks)
        self._final_fn = make_ring_drain(learn, cfg.staleness)

        obs_shape = env.obs_shape
        self._spec = {
            "obs": (obs_shape, np.float32 if obs_shape else np.int32),
            "actions": ((), np.int32),
            "rewards": ((), np.float32),
            "dones": ((), np.float32),
            "behavior_logprob": ((), np.float32),
        }
        self._slabs = SlabRing(cfg.alpha, cfg.n_envs, self._spec,
                               n_slots=cfg.staleness + 1)
        self._built = True

    def init(self) -> None:
        cfg = self.cfg
        self._build()
        # params0 is copied so in-place (donating) updates can never
        # invalidate the caller's parameter tree across run() replays
        self.dg = delayed_grad.init(jax.tree.map(jnp.copy, self.params0),
                                    self.opt, staleness=cfg.staleness)
        keys = jax.random.split(jax.random.key(cfg.seed ^ 0x5EED),
                                cfg.n_envs)
        self.env_states, obs = self._env_reset_v(keys)
        self.obs_np = np.array(obs)     # writable host copy
        self.j = 0              # global interval counter
        # gradient passes in flight: oldest-first, one entry per
        # unconsumed ring slot — {"j", "traj" (slab-aliased), "grads"
        # (dispatched), "ready" (sim-learner gate or None)}
        self._pending: deque = deque()
        self._reset_logs()

    def _reset_logs(self) -> None:
        self.rewards_log: list = []
        self.dones_log: list = []
        self.sps_steps = 0
        self.wall_time = 0.0
        self.profile = {}

    def _prof(self, key: str, dt: float) -> None:
        with self._prof_lock:
            self.profile[key] = self.profile.get(key, 0.0) + dt

    # ------------------------------------------------------ continuation
    def _zero_traj(self):
        """An empty ring slot: all-zero trajectory with dones=1 (mirrors
        mesh_runtime.init_carry so host/mesh capsules are one structure)."""
        cfg = self.cfg
        obs_shape, obs_dtype = self._spec["obs"]
        return {
            "obs": jnp.zeros((cfg.alpha, cfg.n_envs) + tuple(obs_shape),
                             obs_dtype),
            "actions": jnp.zeros((cfg.alpha, cfg.n_envs), jnp.int32),
            "rewards": jnp.zeros((cfg.alpha, cfg.n_envs), jnp.float32),
            "dones": jnp.ones((cfg.alpha, cfg.n_envs), jnp.float32),
            "behavior_logprob": jnp.zeros((cfg.alpha, cfg.n_envs),
                                          jnp.float32),
            "bootstrap_obs": jnp.zeros((cfg.n_envs,) + tuple(obs_shape),
                                       obs_dtype),
        }

    def _buffer_ring(self):
        """The unconsumed read storage as the capsule/drain pytree: slot
        p holds interval ``j - K + p``'s trajectory (zero for intervals
        that never ran). K=1 keeps the plain single-trajectory dict so
        the capsule structure is unchanged from the double-buffer days;
        K>1 stacks the K slots oldest-first (mirrors the fused carry)."""
        K = self.cfg.staleness
        have = {e["j"]: e["traj"] for e in self._pending}
        slots = [have.get(self.j - K + p) or self._zero_traj()
                 for p in range(K)]
        if K == 1:
            return dict(slots[0])
        return jax.tree.map(lambda *xs: jnp.stack(xs), *slots)

    def state(self) -> TrainState:
        """The continuation capsule — structurally identical to the fused
        runtimes' (same TrainState fields, same buffer pytree), so a host
        checkpoint restores into a mesh/sharded run and vice versa. Every
        leaf is COPIED: the runtime's own buffers are donated/slab-backed
        and a later segment would otherwise mutate them under the capsule."""
        if self.dg is None:
            self.init()
        capsule = TrainState(self.dg, self.env_states,
                             jnp.asarray(self.obs_np), self._buffer_ring(),
                             jnp.asarray(self.j, jnp.int32))
        return jax.tree.map(jnp.copy, capsule)

    def _restore(self, state: TrainState) -> None:
        # copies decouple the capsule from this runtime's donated buffers
        self.dg = delayed_grad.DelayedGradState(
            *jax.tree.map(jnp.copy, tuple(state.algo)))
        self.env_states = jax.tree.map(jnp.copy, state.env_state)
        self.obs_np = np.array(state.obs)
        self.j = int(state.interval)
        K = self.cfg.staleness
        # re-dispatch the in-flight gradient passes the capsule implies:
        # ring slot p (data of interval j-K+p) differentiated at its
        # behavior params (history slot p) — exactly the gradients the
        # uninterrupted run would have pending
        self._pending = deque()
        for p in range(K):
            i = self.j - K + p
            if i < 0:
                continue          # slot never filled (j < K)
            traj = jax.tree.map(
                jnp.copy,
                dict(state.buffer) if K == 1
                else jax.tree.map(lambda x, _p=p: x[_p], dict(state.buffer)))
            bp = (self.dg.params_prev if K == 1 else
                  jax.tree.map(lambda h, _p=p: h[_p], self.dg.params_prev))
            self._pending.append({"j": i, "traj": traj,
                                  "grads": self._grad_fn(bp, traj),
                                  "ready": None})
        self._reset_logs()

    def run_from(self, state: TrainState, n_intervals: int,
                 finalize: bool = True) -> RunResult:
        self._build()
        self._restore(state)
        return self._segment(n_intervals, finalize)

    # ------------------------------------------------------------- pools
    def _spawn_pools(self) -> None:
        cfg = self.cfg
        # a worker that survived a previous segment's teardown (stuck in
        # a long dispatch/sleep past the join timeout) must never deliver
        # a stale result into THIS segment's fresh slot queues — that
        # would silently corrupt the trajectory. Refuse loudly instead.
        zombies = [th for th in getattr(self, "_zombies", ())
                   if th.is_alive()]
        if zombies:
            raise RuntimeError(
                f"{len(zombies)} worker thread(s) from a previous segment "
                f"are still running after teardown; refusing to start a "
                f"new segment on this runtime")
        self._state_q: "queue.Queue" = queue.Queue()
        self._step_q: "queue.Queue" = queue.Queue()
        self._sim_q: "queue.Queue" = queue.Queue()
        self._action_slots = [queue.Queue() for _ in range(cfg.n_envs)]
        self._step_slots = [queue.Queue() for _ in range(cfg.n_envs)]
        self._start_barrier = threading.Barrier(cfg.n_envs + 1)
        self._end_barrier = threading.Barrier(cfg.n_envs + 1)
        self._pool_stop = False
        self._pool_exc: list = []
        self._threads = (
            [threading.Thread(target=self._guard, args=(self._actor_loop,),
                              daemon=True)
             for _ in range(self.host.n_actors)]
            + [threading.Thread(target=self._guard, args=(self._stepper_loop,),
                                daemon=True)]
            + [threading.Thread(target=self._guard,
                                args=(self._executor_loop, i), daemon=True)
               for i in range(cfg.n_envs)])
        self._sim_learner_on = (
            isinstance(self.host.learner_time, StepTimeModel)
            or bool(self.host.learner_time))
        if self._sim_learner_on:
            self._threads.append(threading.Thread(
                target=self._guard, args=(self._sim_learner_loop,),
                daemon=True))
        for th in self._threads:
            th.start()

    def _release_pool_waits(self) -> None:
        """Unblock EVERY wait a pool thread can be parked on: both
        barriers, the shared request queues, and the per-env slot
        queues. Idempotent; used by normal teardown and by _guard when a
        worker dies (an executor blocked on its slot would otherwise
        never see a sentinel and leak)."""
        self._pool_stop = True
        for barrier in (self._start_barrier, self._end_barrier):
            try:
                barrier.abort()
            except Exception:
                pass
        for _ in range(self.host.n_actors):
            self._state_q.put(_SHUTDOWN)
        self._step_q.put(_SHUTDOWN)
        self._sim_q.put(_SHUTDOWN)
        for slot in list(self._action_slots) + list(self._step_slots):
            slot.put(_SHUTDOWN)
        # the coordinator may be parked on a pending gradient's ready
        # gate (sim learner): if the sim thread is the one that died, no
        # one would ever set it — wake every pending gate so the
        # coordinator reaches a (broken) barrier and re-raises via
        # _check_pool instead of hanging
        for ent in list(getattr(self, "_pending", ())):
            if ent.get("ready") is not None:
                ent["ready"].set()

    def _shutdown_pools(self) -> None:
        self._release_pool_waits()
        for th in self._threads:
            th.join(timeout=10.0)
        # keep handles to any straggler so _spawn_pools can refuse to
        # run a new segment while it is still alive
        self._zombies = [th for th in self._threads if th.is_alive()]
        self._threads = []

    def _guard(self, fn, *args) -> None:
        """Worker wrapper: record the exception (with its traceback, for
        the coordinator to re-raise loudly) and release every pool wait
        so the coordinator (and sibling workers) unblock instead of
        hanging. Catches BaseException: a KeyboardInterrupt/SystemExit
        delivered to a worker thread must ALSO fail the run — an
        uncaught one would kill the thread silently and leave the
        coordinator blocked on a barrier forever."""
        try:
            fn(*args)
        except BaseException as e:      # noqa: BLE001 — repropagated
            if self._pool_stop:
                return                  # normal teardown (aborted barrier)
            self._pool_exc.append((e, traceback.format_exc()))
            self._release_pool_waits()

    def _check_pool(self) -> None:
        if self._pool_exc:
            exc, tb = self._pool_exc[0]
            raise RuntimeError(
                f"host runtime worker thread died: {exc!r}\n"
                f"--- worker thread traceback ---\n{tb}") from exc

    def _drain_batch(self, q: "queue.Queue", first) -> Optional[list]:
        """The shared actor/stepper batching protocol: take the blocking
        ``first`` item, greedily drain up to ``n_envs`` ready requests,
        and re-surface a shutdown sentinel for sibling workers. Returns
        None on shutdown."""
        if first is _SHUTDOWN:
            return None
        batch = [first]
        while len(batch) < self.cfg.n_envs:
            try:
                item = q.get_nowait()
            except queue.Empty:
                break
            if item is _SHUTDOWN:
                q.put(_SHUTDOWN)      # keep sentinel for sibling workers
                break
            batch.append(item)
        return batch

    @staticmethod
    def _pad(n: int, *cols):
        """Pad int32 request columns to the fixed dispatch width ``n`` by
        repeating the last request (identical padded rows compute —
        and, for scatters, write — identical values)."""
        out = []
        for col in cols:
            a = np.asarray(col, np.int32)
            pad = n - a.shape[0]
            out.append(np.concatenate([a, np.repeat(a[-1:], pad)])
                       if pad else a)
        return out

    # ------------------------------------------------------------ actors
    def _actor_loop(self) -> None:
        n = self.cfg.n_envs
        q = self._state_q
        prof = self.host.profile
        while True:
            batch = self._drain_batch(q, q.get())
            if batch is None:
                return
            if self._faults is not None:
                self._faults.fire("actor", self._cur_j)
            k = len(batch)
            ids, ts = self._pad(n, [b[0] for b in batch],
                                [b[1] for b in batch])
            obs = np.stack([b[2] for b in batch])
            if k < n:
                obs = np.concatenate([obs, np.repeat(obs[-1:], n - k, 0)])
            if self.host.actor_compute:
                time.sleep(self.host.actor_compute * self.host.time_scale)
            t0 = time.perf_counter() if prof else 0.0
            actions, blp = self._actor_fwd(self._behavior, obs, ids, ts,
                                           self._actor_table)
            actions = np.asarray(actions)
            blp = np.asarray(blp)
            if prof:
                self._prof("actor_forward", time.perf_counter() - t0)
            for i in range(k):
                self._action_slots[ids[i]].put(
                    (int(actions[i]), float(blp[i])))

    # ----------------------------------------------------------- stepper
    def _stepper_loop(self) -> None:
        """Groups ready (env, step, action) requests into one padded
        fixed-shape dispatch. Which envs land in which group is racy and
        irrelevant: each row's transition depends only on its own
        (state, action, key)."""
        n = self.cfg.n_envs
        q = self._step_q
        prof = self.host.profile
        while True:
            batch = self._drain_batch(q, q.get())
            if batch is None:
                return
            if self._faults is not None:
                self._faults.fire("stepper", self._cur_j)
            k = len(batch)
            ids, ts, acts = self._pad(n, [b[0] for b in batch],
                                      [b[1] for b in batch],
                                      [b[2] for b in batch])
            if self._faults is not None:
                # distinct from "stepper" death: this models the ENV
                # raising mid-step (the exception surfaces from the env
                # dispatch point, inside the stepper thread)
                self._faults.fire("env_step", self._cur_j)
            t0 = time.perf_counter() if prof else 0.0
            self.env_states, nobs, r, d = self._step_batch(
                self.env_states, acts, ids, ts, self._step_table)
            nobs = np.asarray(nobs)
            r = np.asarray(r)
            d = np.asarray(d)
            if prof:
                self._prof("env_step_dispatch", time.perf_counter() - t0)
            for i in range(k):
                self._step_slots[ids[i]].put(
                    (nobs[i], float(r[i]), float(d[i])))

    # ------------------------------------------------------- sim learner
    def _sim_learner_loop(self) -> None:
        """The simulated serial learner (``HostConfig.learner_time``):
        completes submitted gradient passes FIFO, each taking the real
        compute time plus the simulated duration — so gradient i's
        completion chains on gradient i-1's, like a single learner
        process. Durations come from a constant or a seeded
        StepTimeModel keyed on the data interval index (deterministic,
        replayable). Only the *timing* of the ready gate is simulated;
        the gradient values were dispatched by the coordinator
        untouched."""
        lt = self.host.learner_time
        while True:
            item = self._sim_q.get()
            if item is _SHUTDOWN:
                return
            data_j, grads, ready = item
            jax.block_until_ready(grads)
            dt = (lt.sample(0, data_j, self.cfg.seed ^ 0x1EA12)
                  if isinstance(lt, StepTimeModel) else lt)
            time.sleep(dt * self.host.time_scale)
            ready.set()

    # --------------------------------------------------------- executors
    def _executor_loop(self, env_id: int) -> None:
        cfg, host = self.cfg, self.host
        prof = host.profile
        while True:
            try:
                self._start_barrier.wait()
            except threading.BrokenBarrierError:
                return                  # pool teardown
            if self._pool_stop:
                return
            j = self._cur_j
            if self._faults is not None:
                self._faults.fire("executor", j)
            slab, boot = self._cur_slab, self._cur_boot
            obs = self.obs_np[env_id]
            for t in range(cfg.alpha):
                self._state_q.put((env_id, t, obs))
                t0 = time.perf_counter() if prof else 0.0
                got = self._action_slots[env_id].get()
                if got is _SHUTDOWN:
                    return              # a sibling worker died mid-interval
                action, blp = got
                if prof:
                    self._prof("actor_wait", time.perf_counter() - t0)
                if host.step_time is not None:
                    dt = host.step_time.sample(env_id, j * cfg.alpha + t,
                                               cfg.seed)
                    time.sleep(dt * host.time_scale)
                    if prof:
                        self._prof("sim_env_sleep", dt * host.time_scale)
                self._step_q.put((env_id, t, action))
                t0 = time.perf_counter() if prof else 0.0
                got = self._step_slots[env_id].get()
                if got is _SHUTDOWN:
                    return
                nobs, r, d = got
                if prof:
                    self._prof("env_step_wait", time.perf_counter() - t0)
                slab["obs"][t, env_id] = obs
                slab["actions"][t, env_id] = action
                slab["rewards"][t, env_id] = r
                slab["dones"][t, env_id] = d
                slab["behavior_logprob"][t, env_id] = blp
                obs = nobs
            self.obs_np[env_id] = obs
            boot[env_id] = obs
            self._end_barrier.wait()

    # --------------------------------------------------------------- run
    def run(self, n_intervals: int) -> RunResult:
        self.init()   # engine contract: every run starts from params0
        return self._segment(n_intervals)

    def _run_intervals(self, n_intervals: int) -> None:
        cfg, host = self.cfg, self.host
        K = cfg.staleness
        prof = host.profile
        self._spawn_pools()
        try:
            for j in range(self.j, self.j + n_intervals):
                self._check_pool()
                # ring-reuse barrier: the slab interval j rewrites was
                # last read by the gradient pass over interval j-K-1's
                # data, which the apply dispatched at interval j-1
                # consumed — blocking on the applied state therefore
                # guarantees "read exhausted" before the roles rotate
                # (DESIGN.md §4). With K > 1 that gradient was dispatched
                # K intervals ago, so a learner slower than one interval
                # no longer stalls every interval.
                t0 = time.perf_counter() if prof else 0.0
                jax.block_until_ready(self.dg)
                if prof:
                    self._prof("learner_drain", time.perf_counter() - t0)
                slab, boot = self._slabs.write_view(j)
                self._cur_j = j
                self._cur_slab, self._cur_boot = slab, boot
                self._behavior = self.dg.params     # theta_j
                self._actor_table, self._step_table = self._tables_fn(
                    jnp.asarray(j, jnp.int32))
                self._start_barrier.wait()          # release executors
                # learner apply runs concurrently with rollout j: consume
                # the K-intervals-old pending gradient (delay-K rule,
                # Eq. 6); the first K intervals have nothing pending yet
                # and skip (the behavior history already holds theta_0)
                if len(self._pending) == K:
                    # peek, wait, THEN pop: the entry must stay visible
                    # to _release_pool_waits while the coordinator is
                    # parked on its ready gate, or a dying sim-learner
                    # thread could strand the coordinator forever
                    ent = self._pending[0]
                    if ent["ready"] is not None:
                        t0 = time.perf_counter() if prof else 0.0
                        ent["ready"].wait()
                        if prof:
                            self._prof("sim_learner_wait",
                                       time.perf_counter() - t0)
                    self._pending.popleft()
                    self.dg = self._apply_fn(
                        self.dg.params_prev, self.dg.opt_state,
                        self.dg.step, self.dg.params, ent["grads"])
                t0 = time.perf_counter() if prof else 0.0
                self._end_barrier.wait()            # executors finished
                if prof:
                    self._prof("interval_barrier",
                               time.perf_counter() - t0)
                # interval done: dispatch the gradient for D_j at theta_j
                # immediately (by reference to the slab — only the small
                # reporting streams are copied). It now has K intervals
                # of rollout wall time before its apply blocks on it.
                traj_j = self._slabs.as_traj(j)
                grads = self._grad_fn(self._behavior, traj_j)
                if self._faults is not None:
                    # "learner" site, at interval j's gradient dispatch:
                    # exc -> the learner dies here (coordinator raise);
                    # nan -> the dispatched update is all-NaN, poisoning
                    # params at the apply K intervals later — detected
                    # by the supervisor's finite check BEFORE any save
                    # (core/trainer.LearnerDiverged)
                    ev = self._faults.fire("learner", j)
                    if ev is not None:          # kind == "nan"
                        grads = jax.tree.map(
                            lambda g: jnp.full_like(g, jnp.nan), grads)
                ready = None
                if self._sim_learner_on:
                    ready = threading.Event()
                    self._sim_q.put((j, grads, ready))
                self._pending.append({"j": j, "traj": traj_j,
                                      "grads": grads, "ready": ready})
                self.rewards_log.append(slab["rewards"].copy())
                self.dones_log.append(slab["dones"].copy())
                self.sps_steps += cfg.alpha * cfg.n_envs
                if self.on_interval is not None:
                    # the copies above decouple the observer from slab
                    # reuse; rollout j+1 proceeds while it runs
                    self.on_interval(j, {"rewards": self.rewards_log[-1],
                                         "dones": self.dones_log[-1]})
            self.j += n_intervals
        except threading.BrokenBarrierError:
            self._check_pool()
            raise
        finally:
            self._shutdown_pools()
        self._check_pool()

    def _segment(self, n_intervals: int, finalize: bool = True) -> RunResult:
        cfg = self.cfg
        t_start = time.perf_counter()
        if n_intervals > 0:
            self._run_intervals(n_intervals)
        # trailing learner drain of the K pending ring slots — REPORTING
        # ONLY: self.dg stays mid-stream (ring unconsumed), so
        # state()/run_from continue bit-exactly without double-applying
        # these updates (same split as ScanRuntimeBase._finalize).
        dg_final = self.dg
        if finalize:
            dg_final = self._final_fn(self.dg, self._buffer_ring(),
                                      jnp.asarray(self.j, jnp.int32))
        jax.block_until_ready(dg_final)   # honest wall time / SPS
        self.wall_time = time.perf_counter() - t_start
        empty = np.zeros((0, cfg.alpha, cfg.n_envs), np.float32)
        return RunResult(
            params=dg_final.params, state=dg_final, steps=self.sps_steps,
            wall_time=self.wall_time,
            sps=self.sps_steps / max(self.wall_time, 1e-9),
            rewards=np.stack(self.rewards_log) if self.rewards_log else empty,
            dones=np.stack(self.dones_log) if self.dones_log else empty)
