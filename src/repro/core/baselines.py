"""Baselines the paper compares against, in the same harness:

* ``make_sync_step``   — A2C/PPO with the conventional alternating schedule
  (rollout, then update at the *same* params; no delay, no overlap).
  Identical math to HTS-RL minus the one-step delay — used to show HTS-RL
  matches its sample efficiency (Fig. 5 top row) while the virtual-clock
  harness shows the throughput gap (bottom row).

* ``make_async_step``  — GA3C/IMPALA-style stale-policy training: the
  behavior policy lags k updates behind the target (k drawn from the
  queueing process in expectation; here fixed/configurable), with
  correction in {none, epsilon, truncated-IS, vtrace} (Eq. 5 + Sec. 2;
  the correction losses live in repro.algorithms.vtrace).

Both are also exposed as engine runtimes (``get_runtime("sync"/"async")``)
so benchmark sweeps drive every scheduler through one code path.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.algorithms import vtrace as vtrace_alg
from repro.core.engine import (HTSConfig, ScanRuntimeBase, TrainState,
                               register_runtime)
from repro.core.mesh_runtime import _interval_loss
from repro.core.rollout import RolloutConfig, rollout_interval
from repro.envs.device import batched_env
from repro.envs.interfaces import Env
from repro.optim import Optimizer, apply_updates


def make_sync_step(policy_apply: Callable, env: Env, opt: Optimizer,
                   cfg: HTSConfig):
    """Conventional synchronous A2C/PPO interval (paper Fig. 2(c))."""
    rcfg = RolloutConfig(cfg.alpha, cfg.n_envs)
    master = jax.random.key(cfg.seed)
    grad_fn = jax.grad(
        lambda p, traj: _interval_loss(policy_apply, p, traj, cfg)[0])

    def step(carry, _):
        params, opt_state, env_state, obs, j = carry
        traj, env_state, obs = rollout_interval(
            policy_apply, env, params, env_state, obs, master,
            j * cfg.alpha, rcfg)
        grads = grad_fn(params, traj)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"rewards": traj["rewards"], "dones": traj["dones"]}
        return (params, opt_state, env_state, obs, j + 1), metrics

    return step


def sync_init_carry(params, opt: Optimizer, env: Env, cfg: HTSConfig):
    keys = jax.random.split(jax.random.key(cfg.seed ^ 0x5EED), cfg.n_envs)
    env_state, obs = env.reset(keys)
    # copy: the engine donates the carry (in-place updates must not
    # invalidate the caller's params — see mesh_runtime.init_carry)
    params = jax.tree.map(jnp.copy, params)
    return (params, opt.init(params), env_state, obs,
            jnp.zeros((), jnp.int32))


class AsyncConfig(NamedTuple):
    staleness: int = 8             # behavior policy lag in updates
    correction: str = "none"       # none | epsilon | trunc_is | vtrace
    epsilon: float = 1e-3          # GA3C's eps-correction
    rho_max: float = 1.0


def _stale_loss(policy_apply, params_target, traj, cfg: HTSConfig,
                acfg: AsyncConfig):
    """Eq. (5): gradient at theta_j on data from theta_{j-k}, with the
    chosen correction (resolved from repro.algorithms.vtrace)."""
    alg = vtrace_alg.make_correction(acfg)
    return alg.loss(policy_apply, params_target, traj, cfg)[0]


def make_async_step(policy_apply: Callable, env: Env, opt: Optimizer,
                    cfg: HTSConfig, acfg: AsyncConfig):
    """Stale-policy actor-learner step: rollout uses params from k updates
    ago (a FIFO of snapshots in the carry), learner differentiates the
    current params on that stale data."""
    rcfg = RolloutConfig(cfg.alpha, cfg.n_envs)
    master = jax.random.key(cfg.seed)
    grad_fn = jax.grad(
        lambda p, traj: _stale_loss(policy_apply, p, traj, cfg, acfg))

    def step(carry, _):
        params, opt_state, history, env_state, obs, j = carry
        # behavior = oldest snapshot (k updates behind)
        behavior = jax.tree.map(lambda h: h[0], history)
        traj, env_state, obs = rollout_interval(
            policy_apply, env, behavior, env_state, obs, master,
            j * cfg.alpha, rcfg)
        grads = grad_fn(params, traj)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        # roll the snapshot FIFO
        history = jax.tree.map(
            lambda h, p: jnp.concatenate([h[1:], p[None]], axis=0),
            history, params)
        metrics = {"rewards": traj["rewards"], "dones": traj["dones"]}
        return (params, opt_state, history, env_state, obs, j + 1), metrics

    return step


def async_init_carry(params, opt: Optimizer, env: Env, cfg: HTSConfig,
                     acfg: AsyncConfig):
    keys = jax.random.split(jax.random.key(cfg.seed ^ 0x5EED), cfg.n_envs)
    env_state, obs = env.reset(keys)
    params = jax.tree.map(jnp.copy, params)   # donated carry — see sync
    history = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (acfg.staleness,) + p.shape),
        params)
    return (params, opt.init(params), history, env_state, obs,
            jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------- engine
class _BaselineRuntime(ScanRuntimeBase):
    """Baseline carries lead with plain params (no DelayedGradState)."""

    def __init__(self, env: Env, policy_apply: Callable, params,
                 opt: Optimizer, cfg: HTSConfig):
        super().__init__(env, policy_apply, params, opt, cfg)
        if cfg.staleness != 1:
            # the slab-ring staleness bound is an HTS-family knob: sync
            # has no delay at all and async models staleness through
            # AsyncConfig — silently ignoring cfg.staleness here would
            # make sweep comparisons lie
            raise ValueError(
                f"{type(self).__name__} does not implement "
                f"HTSConfig.staleness={cfg.staleness}; sync is undelayed "
                f"and async takes AsyncConfig(staleness=...)")
        self.venv = batched_env(env, cfg.n_envs, cfg.env_backend)

    def _result_state(self, carry):
        return carry[0], carry


@register_runtime("sync")
class SyncRuntime(_BaselineRuntime):
    """Alternating rollout/update baseline (paper Fig. 2(c))."""

    name = "sync"

    def _build(self) -> None:
        self._step = make_sync_step(self.policy_apply, self.venv, self.opt,
                                    self.cfg)

    def _initial_carry(self):
        return sync_init_carry(self.params0, self.opt, self.venv, self.cfg)

    # sync consumes each interval immediately — no unconsumed buffer,
    # so the TrainState capsule's ``buffer`` is empty
    def _carry_to_state(self, carry) -> TrainState:
        params, opt_state, env_state, obs, j = carry
        return TrainState((params, opt_state), env_state, obs, {}, j)

    def _state_to_carry(self, state: TrainState):
        params, opt_state = state.algo
        return (params, opt_state, state.env_state, state.obs,
                state.interval)


@register_runtime("async")
class AsyncRuntime(_BaselineRuntime):
    """Stale-policy baseline; pass ``acfg=AsyncConfig(...)`` (or its
    fields as kwargs) to control staleness/correction."""

    name = "async"

    def __init__(self, env, policy_apply, params, opt, cfg,
                 acfg: Optional[AsyncConfig] = None, **acfg_kwargs):
        super().__init__(env, policy_apply, params, opt, cfg)
        if acfg is not None and acfg_kwargs:
            # same guard as HostHTSRL: with both forms present the
            # kwargs used to be silently discarded — e.g.
            # AsyncRuntime(..., acfg=AsyncConfig(), staleness=16) ran
            # with staleness=8 and nobody noticed
            raise TypeError(
                f"pass either acfg=AsyncConfig(...) or AsyncConfig field "
                f"kwargs, not both (got acfg and {sorted(acfg_kwargs)})")
        self.acfg = acfg if acfg is not None else AsyncConfig(**acfg_kwargs)

    def _build(self) -> None:
        self._step = make_async_step(self.policy_apply, self.venv, self.opt,
                                     self.cfg, self.acfg)

    def _initial_carry(self):
        return async_init_carry(self.params0, self.opt, self.venv, self.cfg,
                                self.acfg)

    # the stale-snapshot FIFO is part of the schedule: dropping it on
    # resume would reset the behavior lag to zero and break the
    # run(a+b) == run(a)+run_from(b) contract
    def _carry_to_state(self, carry) -> TrainState:
        params, opt_state, history, env_state, obs, j = carry
        return TrainState((params, opt_state, history), env_state, obs,
                          {}, j)

    def _state_to_carry(self, state: TrainState):
        params, opt_state, history = state.algo
        return (params, opt_state, history, state.env_state, state.obs,
                state.interval)
