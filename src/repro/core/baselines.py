"""Baselines the paper compares against, in the same harness:

* ``make_sync_step``   — A2C/PPO with the conventional alternating schedule
  (rollout, then update at the *same* params; no delay, no overlap).
  Identical math to HTS-RL minus the one-step delay — used to show HTS-RL
  matches its sample efficiency (Fig. 5 top row) while the virtual-clock
  harness shows the throughput gap (bottom row).

* ``make_async_step``  — GA3C/IMPALA-style stale-policy training: the
  behavior policy lags k updates behind the target (k drawn from the
  queueing process in expectation; here fixed/configurable), with
  correction in {none, epsilon, truncated-IS, vtrace} (Eq. 5 + Sec. 2).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import losses, vtrace as vtrace_mod
from repro.core.mesh_runtime import HTSConfig, _interval_loss
from repro.core.rollout import RolloutConfig, rollout_interval
from repro.envs.interfaces import Env
from repro.optim import Optimizer, apply_updates


def make_sync_step(policy_apply: Callable, env: Env, opt: Optimizer,
                   cfg: HTSConfig):
    """Conventional synchronous A2C/PPO interval (paper Fig. 2(c))."""
    rcfg = RolloutConfig(cfg.alpha, cfg.n_envs)
    master = jax.random.key(cfg.seed)
    grad_fn = jax.grad(
        lambda p, traj: _interval_loss(policy_apply, p, traj, cfg)[0])

    def step(carry, _):
        params, opt_state, env_state, obs, j = carry
        traj, env_state, obs = rollout_interval(
            policy_apply, env, params, env_state, obs, master,
            j * cfg.alpha, rcfg)
        grads = grad_fn(params, traj)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"rewards": traj["rewards"], "dones": traj["dones"]}
        return (params, opt_state, env_state, obs, j + 1), metrics

    return step


def sync_init_carry(params, opt: Optimizer, env: Env, cfg: HTSConfig):
    keys = jax.random.split(jax.random.key(cfg.seed ^ 0x5EED), cfg.n_envs)
    env_state, obs = env.reset(keys)
    return (params, opt.init(params), env_state, obs,
            jnp.zeros((), jnp.int32))


class AsyncConfig(NamedTuple):
    staleness: int = 8             # behavior policy lag in updates
    correction: str = "none"       # none | epsilon | trunc_is | vtrace
    epsilon: float = 1e-3          # GA3C's eps-correction
    rho_max: float = 1.0


def _stale_loss(policy_apply, params_target, traj, cfg: HTSConfig,
                acfg: AsyncConfig):
    """Eq. (5): gradient at theta_j on data from theta_{j-k}, with the
    chosen correction."""
    A, N = traj["actions"].shape
    obs = traj["obs"]
    flat = obs.reshape((A * N,) + obs.shape[2:])
    logits, values = policy_apply(params_target, flat)
    logits = logits.reshape(A, N, -1)
    values = values.reshape(A, N)
    _, bv = policy_apply(params_target, traj["bootstrap_obs"])
    bv = jax.lax.stop_gradient(bv)

    if acfg.correction == "vtrace":
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        tlp = jnp.take_along_axis(
            logp, traj["actions"][..., None], axis=-1)[..., 0]
        vt = vtrace_mod.vtrace(traj["behavior_logprob"],
                               jax.lax.stop_gradient(tlp),
                               traj["rewards"], traj["dones"],
                               jax.lax.stop_gradient(values), bv, cfg.gamma,
                               acfg.rho_max)
        ent = -(jnp.exp(logp) * logp).sum(-1)
        pg = -(tlp * vt.pg_advantages).mean()
        vl = jnp.square(values - vt.vs).mean()
        return pg + cfg.value_coef * vl - cfg.entropy_coef * ent.mean()

    rets = losses.n_step_returns(traj["rewards"], traj["dones"], bv,
                                 cfg.gamma)
    adv = rets - jax.lax.stop_gradient(values)
    if acfg.correction == "trunc_is":
        st = losses.truncated_is_a2c_loss(
            logits, values, traj["actions"], adv, rets,
            traj["behavior_logprob"], acfg.rho_max,
            cfg.value_coef, cfg.entropy_coef)
        return st.total
    if acfg.correction == "epsilon":
        # GA3C: pi(a|s) <- pi(a|s) + eps inside the log
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        p_a = jnp.exp(jnp.take_along_axis(
            logp, traj["actions"][..., None], axis=-1))[..., 0]
        lp = jnp.log(p_a + acfg.epsilon)
        ent = -(jnp.exp(logp) * logp).sum(-1)
        pg = -(lp * jax.lax.stop_gradient(adv)).mean()
        vl = jnp.square(values - rets).mean()
        return pg + cfg.value_coef * vl - cfg.entropy_coef * ent.mean()
    st = losses.a2c_loss(logits, values, traj["actions"], adv, rets,
                         cfg.value_coef, cfg.entropy_coef)
    return st.total


def make_async_step(policy_apply: Callable, env: Env, opt: Optimizer,
                    cfg: HTSConfig, acfg: AsyncConfig):
    """Stale-policy actor-learner step: rollout uses params from k updates
    ago (a FIFO of snapshots in the carry), learner differentiates the
    current params on that stale data."""
    rcfg = RolloutConfig(cfg.alpha, cfg.n_envs)
    master = jax.random.key(cfg.seed)
    grad_fn = jax.grad(
        lambda p, traj: _stale_loss(policy_apply, p, traj, cfg, acfg))

    def step(carry, _):
        params, opt_state, history, env_state, obs, j = carry
        # behavior = oldest snapshot (k updates behind)
        behavior = jax.tree.map(lambda h: h[0], history)
        traj, env_state, obs = rollout_interval(
            policy_apply, env, behavior, env_state, obs, master,
            j * cfg.alpha, rcfg)
        grads = grad_fn(params, traj)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        # roll the snapshot FIFO
        history = jax.tree.map(
            lambda h, p: jnp.concatenate([h[1:], p[None]], axis=0),
            history, params)
        metrics = {"rewards": traj["rewards"], "dones": traj["dones"]}
        return (params, opt_state, history, env_state, obs, j + 1), metrics

    return step


def async_init_carry(params, opt: Optimizer, env: Env, cfg: HTSConfig,
                     acfg: AsyncConfig):
    keys = jax.random.split(jax.random.key(cfg.seed ^ 0x5EED), cfg.n_envs)
    env_state, obs = env.reset(keys)
    history = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (acfg.staleness,) + p.shape),
        params)
    return (params, opt.init(params), history, env_state, obs,
            jnp.zeros((), jnp.int32))
