"""Checkpointed continuation driver: wrap any engine runtime with
periodic checkpointing, resume-from-latest, and streaming evaluation.

The engine contract (core/engine.py) makes ``run(n)`` a reset-and-replay;
this module is what turns that into long-lived training that survives
preemption:

    rt = engine.make_runtime("sharded", env, papply, params, opt, cfg)
    trainer = Trainer(rt, checkpoint_dir="ckpts", ckpt_every=50)
    report = trainer.fit(10_000, resume=True)   # picks up where it died

``fit`` drives the runtime exclusively through ``run_from`` in
``ckpt_every``-interval segments, capturing the ``TrainState`` capsule
after each segment and writing it through ``repro.checkpoint.io`` with
versioned metadata (runtime name, algorithm, seed, interval count, and
the streaming-metric carry). Because ``run(a + b)`` equals any partition
into ``run_from`` segments bit-exactly (tests/test_continuation.py), a
checkpointed-and-killed run resumed by a fresh process produces the
EXACT parameters of the uninterrupted run — checkpointing is free of
training-dynamics side effects, on every runtime.

Per-segment reward/done streams feed a ``core.evaluate.ReturnStream``,
whose carry rides inside the checkpoint metadata — so the paper's
evaluation protocol survives preemption too: an episode spanning a
kill/resume boundary is counted once, with the correct return (bit-equal
to the uninterrupted trainer's stream; equal to the one-shot
computation bit-exactly for integer-valued rewards, to ~1 ulp for
arbitrary floats — see ReturnStream).
"""
from __future__ import annotations

import glob
import os
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core import evaluate
from repro.core.engine import Runtime, TrainState

CKPT_FORMAT = "hts-trainstate-v1"


@dataclass
class TrainReport:
    """What ``Trainer.fit`` returns."""
    params: Any
    state: TrainState            # mid-stream continuation capsule
    intervals: int               # total intervals completed (incl. resumed)
    resumed_from: int            # intervals already done at fit() entry
    steps: int                   # env steps executed by THIS fit call
    wall_time: float
    sps: float
    rewards: np.ndarray          # (intervals_this_fit, alpha, n_envs)
    dones: np.ndarray
    episode_returns: np.ndarray  # completion-order, incl. resumed history

    def final_metric(self, n_episodes: int = 100) -> float:
        eps = self.episode_returns
        return float(eps[-n_episodes:].mean()) if len(eps) else float("nan")


class Trainer:
    """Periodic-checkpoint driver over any registered runtime.

    * ``ckpt_every``   — intervals per segment (0: one segment, checkpoint
      only at the end when ``checkpoint_dir`` is set).
    * ``on_segment``   — optional ``callback(intervals_done, RunResult)``
      invoked after each segment's checkpoint is durable; used by tests to
      simulate preemption (raising from it loses no committed work). Note
      intermediate segments run with ``finalize=False``, so their
      RunResult.params are mid-stream (one reporting update behind).
    * ``keep``         — how many most-recent checkpoints to retain
      (0 = keep all).
    * ``on_interval``  — optional reporting-only metrics observer,
      ``callback(interval, {"rewards": (alpha, n_envs), "dones": ...})``
      called once per completed interval (global index, so a resumed fit
      continues the numbering), after each segment returns — the
      streaming hook repro.api.Session threads through here.
    """

    def __init__(self, runtime: Runtime, checkpoint_dir: Optional[str] = None,
                 ckpt_every: int = 0,
                 on_segment: Optional[Callable[[int, Any], None]] = None,
                 keep: int = 3,
                 on_interval: Optional[Callable[[int, dict], None]] = None):
        self.runtime = runtime
        self.checkpoint_dir = checkpoint_dir
        self.ckpt_every = ckpt_every
        self.on_segment = on_segment
        self.keep = keep
        self.on_interval = on_interval

    # ----------------------------------------------------------- ckpt io
    def _ckpt_path(self, intervals: int) -> str:
        return os.path.join(self.checkpoint_dir, f"step_{intervals:08d}")

    def latest_checkpoint(self) -> Optional[str]:
        if not self.checkpoint_dir:
            return None
        return ckpt_io.latest(self.checkpoint_dir)

    def _save(self, state: TrainState, intervals: int,
              stream: evaluate.ReturnStream) -> None:
        cfg = self.runtime.cfg
        ckpt_io.save(self._ckpt_path(intervals), state, metadata={
            "format": CKPT_FORMAT,
            "runtime": self.runtime.name,
            "algorithm": cfg.algorithm,
            "seed": cfg.seed,
            "alpha": cfg.alpha,
            "n_envs": cfg.n_envs,
            "staleness": cfg.staleness,
            "intervals": intervals,
            "metrics": stream.state_dict(),
        })
        self._prune(intervals)

    def _prune(self, newest: int) -> None:
        if not self.keep:
            return
        paths = sorted(glob.glob(
            os.path.join(self.checkpoint_dir, "step_*.json")))
        for p in paths[:-self.keep]:
            base = p[:-len(".json")]
            for suffix in (".json", ".npz"):
                try:
                    os.remove(base + suffix)
                except OSError:
                    pass

    def _resume(self) -> tuple[Optional[TrainState], int, Optional[dict]]:
        path = self.latest_checkpoint()
        if path is None:
            return None, 0, None
        meta = ckpt_io.load_metadata(path)
        if meta.get("format") != CKPT_FORMAT:
            raise ValueError(
                f"{path} is not a trainer checkpoint "
                f"(format={meta.get('format')!r})")
        cfg = self.runtime.cfg
        # staleness defaults to 1 for checkpoints written before the
        # slab-ring generalization (their capsules ARE K=1 capsules)
        for key, have, default in (
                ("runtime", self.runtime.name, None),
                ("algorithm", cfg.algorithm, None), ("seed", cfg.seed, None),
                ("alpha", cfg.alpha, None), ("n_envs", cfg.n_envs, None),
                ("staleness", getattr(cfg, "staleness", 1), 1)):
            # runtime may legitimately differ (the capsule is
            # cross-runtime, tests/test_continuation.py) — warn-level
            # concerns are config fields that change the math
            if key != "runtime" and meta.get(key, default) != have:
                raise ValueError(
                    f"resume mismatch: checkpoint has {key}="
                    f"{meta.get(key, default)!r}, runtime has {have!r}")
        state = ckpt_io.restore(path, self.runtime.state())
        return state, int(meta["intervals"]), meta.get("metrics")

    # --------------------------------------------------------------- fit
    def fit(self, n_intervals: int, resume: bool = False) -> TrainReport:
        """Train until ``n_intervals`` TOTAL intervals have run (a resumed
        fit counts the checkpointed intervals toward the target)."""
        cfg = self.runtime.cfg
        if not resume and self.latest_checkpoint() is not None:
            # refusing beats the alternative: a fresh run interleaved
            # with stale checkpoints would let keep-k pruning delete the
            # NEW checkpoints while a later resume picks up the old run
            raise ValueError(
                f"{self.checkpoint_dir} already holds checkpoints "
                f"({os.path.basename(self.latest_checkpoint())}); pass "
                f"resume=True to continue that run, or point "
                f"checkpoint_dir at a fresh directory")
        state, start, metric_state = (self._resume() if resume
                                      else (None, 0, None))
        stream = evaluate.ReturnStream(cfg.n_envs)
        if metric_state is not None:
            stream.load_state_dict(metric_state)
        if state is None:
            state = self.runtime.state()   # fresh initial capsule
        done = start
        out = None
        rewards_log, dones_log = [], []
        steps = 0
        t0 = time.perf_counter()
        while done < n_intervals:
            chunk = min(self.ckpt_every or (n_intervals - done),
                        n_intervals - done)
            # only the final segment pays the reporting-only trailing
            # learner pass; intermediate segments just stream metrics
            out = self.runtime.run_from(
                state, chunk, finalize=(done + chunk >= n_intervals))
            if self.on_interval is not None:
                for i, metrics in out.interval_metrics():
                    self.on_interval(done + i, metrics)
            done += chunk
            state = self.runtime.state()
            stream.extend(out.rewards, out.dones)
            rewards_log.append(out.rewards)
            dones_log.append(out.dones)
            steps += out.steps
            if self.checkpoint_dir:
                self._save(state, done, stream)
            if self.on_segment is not None:
                self.on_segment(done, out)
        if out is None:
            # nothing left to run (resumed at or past the target):
            # report the restored state's parameters via a 0-segment
            out = self.runtime.run_from(state, 0)
        wall = time.perf_counter() - t0
        empty = np.zeros((0, cfg.alpha, cfg.n_envs), np.float32)
        return TrainReport(
            params=out.params, state=state, intervals=done,
            resumed_from=start, steps=steps, wall_time=wall,
            sps=steps / max(wall, 1e-9),
            rewards=np.concatenate(rewards_log) if rewards_log else empty,
            dones=np.concatenate(dones_log) if dones_log else empty,
            episode_returns=stream.returns)
