"""Checkpointed continuation driver: wrap any engine runtime with
periodic checkpointing, resume-from-latest, and streaming evaluation.

The engine contract (core/engine.py) makes ``run(n)`` a reset-and-replay;
this module is what turns that into long-lived training that survives
preemption:

    rt = engine.make_runtime("sharded", env, papply, params, opt, cfg)
    trainer = Trainer(rt, checkpoint_dir="ckpts", ckpt_every=50)
    report = trainer.fit(10_000, resume=True)   # picks up where it died

``fit`` drives the runtime exclusively through ``run_from`` in
``ckpt_every``-interval segments, capturing the ``TrainState`` capsule
after each segment and writing it through ``repro.checkpoint.io`` with
versioned metadata (runtime name, algorithm, seed, interval count, and
the streaming-metric carry). Because ``run(a + b)`` equals any partition
into ``run_from`` segments bit-exactly (tests/test_continuation.py), a
checkpointed-and-killed run resumed by a fresh process produces the
EXACT parameters of the uninterrupted run — checkpointing is free of
training-dynamics side effects, on every runtime.

Per-segment reward/done streams feed a ``core.evaluate.ReturnStream``,
whose carry rides inside the checkpoint metadata — so the paper's
evaluation protocol survives preemption too: an episode spanning a
kill/resume boundary is counted once, with the correct return (bit-equal
to the uninterrupted trainer's stream; equal to the one-shot
computation bit-exactly for integer-valued rewards, to ~1 ulp for
arbitrary floats — see ReturnStream).
"""
from __future__ import annotations

import glob
import os
import sys
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import io as ckpt_io
from repro.core import evaluate
from repro.core.engine import Runtime, TrainState
from repro.faults import FaultInjector, FaultPlan

CKPT_FORMAT = "hts-trainstate-v1"


def checkpoint_metadata(runtime: Runtime, intervals: int,
                        stream: evaluate.ReturnStream) -> dict:
    """The versioned manifest written beside every trainer-format
    capsule. Module-level so every writer of ``CKPT_FORMAT``
    checkpoints (Trainer segments, TenantPool slice boundaries) emits
    the same manifest and the same ``_resume`` validation applies."""
    cfg = runtime.cfg
    meta = {
        "format": CKPT_FORMAT,
        "runtime": runtime.name,
        "algorithm": cfg.algorithm,
        "seed": cfg.seed,
        "alpha": cfg.alpha,
        "n_envs": cfg.n_envs,
        "staleness": cfg.staleness,
        "intervals": intervals,
        "metrics": stream.state_dict(),
    }
    # batch geometry rides in the MANIFEST, not the capsule (the
    # capsule is a pure-array pytree identical across geometries —
    # that is the point of the determinism contract). Recorded so
    # _resume can validate a restore onto a different factorization
    # loudly instead of guessing.
    geom = getattr(runtime, "geometry", None)
    if geom is not None:
        meta["batch"] = geom.canonical()
    return meta


def prune_checkpoints(checkpoint_dir: str, keep: int) -> None:
    """Retain the ``keep`` most-recent ``step_*`` checkpoints
    (0 = keep all)."""
    if not keep:
        return
    paths = sorted(glob.glob(os.path.join(checkpoint_dir, "step_*.json")))
    for p in paths[:-keep]:
        base = p[:-len(".json")]
        for suffix in (".json", ".npz"):
            try:
                os.remove(base + suffix)
            except OSError:
                pass


class LearnerDiverged(RuntimeError):
    """The segment produced non-finite parameters (a NaN'd/inf'd learner
    step). Raised BEFORE the capsule is checkpointed, so the divergence
    never becomes durable — the supervisor restores the last finite
    capsule and replays. Only checked when a fault plan is configured;
    without one, non-finite params flow through unchanged (pre-existing
    behavior)."""


@dataclass
class TrainReport:
    """What ``Trainer.fit`` returns."""
    params: Any
    state: TrainState            # mid-stream continuation capsule
    intervals: int               # total intervals completed (incl. resumed)
    resumed_from: int            # intervals already done at fit() entry
    steps: int                   # env steps executed by THIS fit call
    wall_time: float
    sps: float
    rewards: np.ndarray          # (intervals_this_fit, alpha, n_envs)
    dones: np.ndarray
    episode_returns: np.ndarray  # completion-order, incl. resumed history
    restarts: int = 0            # supervisor recoveries this fit
    recoveries: List[dict] = field(default_factory=list)
    # each: {"failure", "restored_to", "backoff_s", "restore_s"}

    def final_metric(self, n_episodes: int = 100) -> float:
        eps = self.episode_returns
        return float(eps[-n_episodes:].mean()) if len(eps) else float("nan")


class Trainer:
    """Periodic-checkpoint driver over any registered runtime.

    * ``ckpt_every``   — intervals per segment (0: one segment, checkpoint
      only at the end when ``checkpoint_dir`` is set).
    * ``on_segment``   — optional ``callback(intervals_done, RunResult)``
      invoked after each segment's checkpoint is durable; used by tests to
      simulate preemption (raising from it loses no committed work). Note
      intermediate segments run with ``finalize=False``, so their
      RunResult.params are mid-stream (one reporting update behind).
    * ``keep``         — how many most-recent checkpoints to retain
      (0 = keep all).
    * ``on_interval``  — optional reporting-only metrics observer,
      ``callback(interval, {"rewards": (alpha, n_envs), "dones": ...})``
      called once per completed interval (global index, so a resumed fit
      continues the numbering), after each segment returns — the
      streaming hook repro.api.Session threads through here.
    * ``faults``       — a ``FaultPlan`` or (shared) ``FaultInjector``.
      Arms two things: the ``checkpoint``-site truncation injection in
      ``_save``, and — when the plan's ``max_restarts > 0`` — the
      supervising loop (DESIGN.md §11): a failed segment (pool-guard
      RuntimeError, env exception, ``LearnerDiverged``) is absorbed by
      restoring the newest COMPLETE, uncorrupt checkpoint and replaying
      from it, with exponential backoff, up to ``max_restarts``
      CONSECUTIVE failures. Because ``run_from`` is bit-exact and
      injected events fire at most once, the recovered run's final
      params and episode-return stream equal the fault-free run's
      exactly (tests/test_faults.py). With ``faults=None`` (default)
      nothing changes: failures propagate as before this layer existed.
      Note one replay consequence: falling back PAST a corrupted newest
      checkpoint re-runs already-reported intervals, so ``on_interval``
      may see an index twice (identical metrics both times, by
      determinism); ``on_segment`` fires only after a durable save and
      is never replayed for an interval count it already saw, except in
      that same corrupt-fallback case.
    """

    def __init__(self, runtime: Runtime, checkpoint_dir: Optional[str] = None,
                 ckpt_every: int = 0,
                 on_segment: Optional[Callable[[int, Any], None]] = None,
                 keep: int = 3,
                 on_interval: Optional[Callable[[int, dict], None]] = None,
                 faults: Optional[FaultPlan | FaultInjector] = None):
        self.runtime = runtime
        self.checkpoint_dir = checkpoint_dir
        self.ckpt_every = ckpt_every
        self.on_segment = on_segment
        self.keep = keep
        self.on_interval = on_interval
        if faults is None or isinstance(faults, FaultInjector):
            self.faults = faults
        else:
            self.faults = FaultInjector(FaultPlan.of(faults))
        self._plan = self.faults.plan if self.faults is not None else None

    # ----------------------------------------------------------- ckpt io
    def _ckpt_path(self, intervals: int) -> str:
        return os.path.join(self.checkpoint_dir, f"step_{intervals:08d}")

    def latest_checkpoint(self) -> Optional[str]:
        if not self.checkpoint_dir:
            return None
        return ckpt_io.latest(self.checkpoint_dir)

    def _save(self, state: TrainState, intervals: int,
              stream: evaluate.ReturnStream) -> None:
        meta = checkpoint_metadata(self.runtime, intervals, stream)
        ckpt_io.save(self._ckpt_path(intervals), state, metadata=meta)
        if self.faults is not None:
            # checkpoint-site chaos: the atomic write (checkpoint/io)
            # makes a torn file impossible to PRODUCE, so the injectable
            # failure is post-write corruption — truncate the just-
            # written npz in place. Detected at restore as
            # CheckpointCorrupt; the supervisor falls back past it.
            ev = self.faults.poll("checkpoint", intervals)
            if ev is not None and ev.kind == "truncate":
                npz = self._ckpt_path(intervals) + ".npz"
                with open(npz, "r+b") as f:
                    size = f.seek(0, os.SEEK_END)
                    f.truncate(max(size // 2, 1))
        self._prune(intervals)

    def _prune(self, newest: int) -> None:
        prune_checkpoints(self.checkpoint_dir, self.keep)

    def _resume(self) -> tuple[Optional[TrainState], int, Optional[dict]]:
        path = self.latest_checkpoint()
        if path is None:
            return None, 0, None
        meta = ckpt_io.load_metadata(path)
        if meta.get("format") != CKPT_FORMAT:
            raise ValueError(
                f"{path} is not a trainer checkpoint "
                f"(format={meta.get('format')!r})")
        cfg = self.runtime.cfg
        # staleness defaults to 1 for checkpoints written before the
        # slab-ring generalization (their capsules ARE K=1 capsules)
        for key, have, default in (
                ("runtime", self.runtime.name, None),
                ("algorithm", cfg.algorithm, None), ("seed", cfg.seed, None),
                ("alpha", cfg.alpha, None), ("n_envs", cfg.n_envs, None),
                ("staleness", getattr(cfg, "staleness", 1), 1)):
            # runtime may legitimately differ (the capsule is
            # cross-runtime, tests/test_continuation.py) — warn-level
            # concerns are config fields that change the math
            if key != "runtime" and meta.get(key, default) != have:
                raise ValueError(
                    f"resume mismatch: checkpoint has {key}="
                    f"{meta.get(key, default)!r}, runtime has {have!r}")
        # batch geometry: a DIFFERENT factorization of the SAME global
        # batch is a supported restore (bit-exact by the determinism
        # contract, DESIGN.md §12) — announced loudly, never silent.
        # global_batch is pinned by the n_envs check above; checkpoints
        # written before BatchConfig carry no geometry (trivial default).
        geom = getattr(self.runtime, "geometry", None)
        saved = meta.get("batch")
        if (geom is not None and saved is not None
                and saved != geom.canonical()):
            print(f"[trainer] resume crosses batch geometries: "
                  f"checkpoint {saved} -> runtime {geom.canonical()} "
                  f"(same global_batch; bit-exact by the scale-out "
                  f"determinism contract)", file=sys.stderr)
        state = ckpt_io.restore(path, self.runtime.state())
        return state, int(meta["intervals"]), meta.get("metrics")

    # --------------------------------------------------------- recovery
    @staticmethod
    def _check_finite(params) -> None:
        for i, leaf in enumerate(jax.tree_util.tree_leaves(params)):
            a = np.asarray(jax.device_get(leaf))
            if np.issubdtype(a.dtype, np.floating) and \
                    not np.isfinite(a.astype(np.float32)).all():
                raise LearnerDiverged(
                    f"segment produced non-finite parameters (leaf {i})")

    def _recover(self, template, start0: int, entry_metrics):
        """Newest complete + UNCORRUPT checkpoint, walking past damaged
        ones loudly; ultimate fallback is the fit-entry capsule (replay
        everything this fit already ran). ``template`` is a host-side
        (numpy) snapshot of the entry capsule — deliberately NOT
        ``runtime.state()``: after a mid-interval failure the runtime's
        donated device buffers are not trustworthy."""
        if self.checkpoint_dir:
            for path in ckpt_io.complete_checkpoints(self.checkpoint_dir):
                meta = ckpt_io.load_metadata(path)
                if meta.get("format") != CKPT_FORMAT:
                    continue
                try:
                    state = ckpt_io.restore(path, template)
                except ckpt_io.CheckpointCorrupt as e:
                    print(f"[trainer] skipping corrupt checkpoint "
                          f"{os.path.basename(path)}: {e}",
                          file=sys.stderr)
                    continue
                return state, int(meta["intervals"]), meta.get("metrics")
        return (jax.tree_util.tree_map(jnp.asarray, template), start0,
                entry_metrics)

    # --------------------------------------------------------------- fit
    def fit(self, n_intervals: int, resume: bool = False) -> TrainReport:
        """Train until ``n_intervals`` TOTAL intervals have run (a resumed
        fit counts the checkpointed intervals toward the target)."""
        cfg = self.runtime.cfg
        if not resume and self.latest_checkpoint() is not None:
            # refusing beats the alternative: a fresh run interleaved
            # with stale checkpoints would let keep-k pruning delete the
            # NEW checkpoints while a later resume picks up the old run
            raise ValueError(
                f"{self.checkpoint_dir} already holds checkpoints "
                f"({os.path.basename(self.latest_checkpoint())}); pass "
                f"resume=True to continue that run, or point "
                f"checkpoint_dir at a fresh directory")
        state, start, metric_state = (self._resume() if resume
                                      else (None, 0, None))
        stream = evaluate.ReturnStream(cfg.n_envs)
        if metric_state is not None:
            stream.load_state_dict(metric_state)
        if state is None:
            state = self.runtime.state()   # fresh initial capsule
        plan = self._plan
        supervised = plan is not None and plan.max_restarts > 0
        if supervised:
            # host-side snapshot of the entry capsule: the restore
            # template and the ultimate fallback point. numpy copies —
            # immune to buffer donation by subsequent run_from calls.
            entry = jax.tree_util.tree_map(
                lambda a: np.asarray(jax.device_get(a)), state)
            entry_metrics = stream.state_dict()
        done = start
        out = None
        # committed segments as (done_after, rewards, dones, steps):
        # recovery to an older checkpoint truncates this list so the
        # reported reward/done arrays match the single surviving
        # timeline, bit-exactly — replayed segments replace, not append
        segs: list = []
        steps_executed = 0
        consec = 0
        restarts = 0
        recoveries: list = []
        t0 = time.perf_counter()
        while done < n_intervals:
            chunk = min(self.ckpt_every or (n_intervals - done),
                        n_intervals - done)
            try:
                # only the final segment pays the reporting-only trailing
                # learner pass; intermediate segments just stream metrics
                out = self.runtime.run_from(
                    state, chunk, finalize=(done + chunk >= n_intervals))
                if plan is not None:
                    # BEFORE the capsule is saved: a diverged step must
                    # never become durable
                    self._check_finite(out.params)
            except Exception as e:
                if not supervised or consec >= plan.max_restarts:
                    raise
                consec += 1
                restarts += 1
                delay = min(plan.backoff * (2 ** (consec - 1)),
                            plan.backoff_cap)
                print(f"[trainer] segment at interval {done} failed "
                      f"({type(e).__name__}: {e}); restart "
                      f"{consec}/{plan.max_restarts} after "
                      f"{delay:.3f}s backoff", file=sys.stderr)
                time.sleep(delay)
                r0 = time.perf_counter()
                state, done, mstate = self._recover(
                    entry, start, entry_metrics)
                stream = evaluate.ReturnStream(cfg.n_envs)
                if mstate is not None:
                    stream.load_state_dict(mstate)
                segs = [s for s in segs if s[0] <= done]
                recoveries.append({
                    "failure": f"{type(e).__name__}: {e}",
                    "restored_to": done,
                    "backoff_s": delay,
                    "restore_s": time.perf_counter() - r0,
                })
                continue
            consec = 0
            if self.on_interval is not None:
                for i, metrics in out.interval_metrics():
                    self.on_interval(done + i, metrics)
            done += chunk
            state = self.runtime.state()
            stream.extend(out.rewards, out.dones)
            segs.append((done, out.rewards, out.dones, out.steps))
            steps_executed += out.steps
            if self.checkpoint_dir:
                self._save(state, done, stream)
            if self.on_segment is not None:
                self.on_segment(done, out)
        if out is None:
            # nothing left to run (resumed at or past the target):
            # report the restored state's parameters via a 0-segment
            out = self.runtime.run_from(state, 0)
        wall = time.perf_counter() - t0
        empty = np.zeros((0, cfg.alpha, cfg.n_envs), np.float32)
        rewards_log = [s[1] for s in segs]
        dones_log = [s[2] for s in segs]
        return TrainReport(
            params=out.params, state=state, intervals=done,
            resumed_from=start, steps=steps_executed, wall_time=wall,
            sps=steps_executed / max(wall, 1e-9),
            rewards=np.concatenate(rewards_log) if rewards_log else empty,
            dones=np.concatenate(dones_log) if dones_log else empty,
            episode_returns=stream.returns,
            restarts=restarts, recoveries=recoveries)
