"""Vectorized executor/actor rollout (one synchronization interval).

``rollout_interval`` advances ``n_envs`` environment replicas ``alpha``
steps under a fixed behavior policy, producing the trajectory pytree the
learner consumes. Action sampling uses executor-derived keys
(core/determinism.py) so the result is independent of actor count and
batching — the jit'd equivalent of the paper's asynchronous
actor/executor interaction, which is *defined* to be
observation-order-independent.

``actor_forward`` is the single copy of the actor computation (policy
forward + per-observation-key sampling + behavior logprob); the threaded
host runtime batches racy observations through it while this module vmaps
it over a full interval — both paths produce bit-identical actions by the
determinism contract (DESIGN.md §3).

``env_offset`` shifts the env ids used for seed derivation: a data-parallel
shard holding replicas [offset, offset + n_envs) draws exactly the keys the
single-device run would for those envs, so sharding never changes the data.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import determinism
from repro.envs.interfaces import Env


class RolloutConfig(NamedTuple):
    alpha: int                 # synchronization interval (steps)
    n_envs: int


def actor_forward(policy_apply: Callable, params, obs, keys):
    """The actor computation for one batch of observations.

    obs: (n, ...) stacked observations; keys: (n,) executor-attached PRNG
    keys. Returns (actions (n,) int, behavior_logprob (n,) f32). Which
    actor runs this, and how observations were batched, cannot affect the
    result: the key is a pure function of (run_seed, env_id, step).
    """
    logits, _ = policy_apply(params, obs)
    actions = jax.vmap(determinism.sample_action)(keys, logits)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    blp = jnp.take_along_axis(logp, actions[:, None], axis=-1)[:, 0]
    return actions, blp


def rollout_interval(policy_apply: Callable, env: Env, params, env_state,
                     obs, master_key, start_step, cfg: RolloutConfig,
                     env_offset=0):
    """Returns (traj, env_state', obs').

    traj = {obs, actions, rewards, dones, behavior_logprob: (alpha, n_envs),
            bootstrap_obs: (n_envs,)+obs_shape}.
    policy_apply(params, obs) -> (logits (n, A), value (n,)).
    env_offset: global id of this shard's first env replica (0 unless
    running data-parallel under shard_map).
    """
    env_ids = env_offset + jnp.arange(cfg.n_envs)

    def step(carry, t):
        env_state, obs = carry
        gstep = start_step + t
        keys = determinism.obs_keys(master_key, env_ids, gstep)
        actions, blp = actor_forward(policy_apply, params, obs, keys)
        step_keys = jax.vmap(
            lambda e: determinism.obs_key(master_key, e + 1_000_003, gstep)
        )(env_ids)
        env_state, next_obs, reward, done = env.step(env_state, actions,
                                                     step_keys)
        out = {"obs": obs, "actions": actions, "rewards": reward,
               "dones": done, "behavior_logprob": blp}
        return (env_state, next_obs), out

    (env_state, obs), traj = jax.lax.scan(
        step, (env_state, obs), jnp.arange(cfg.alpha))
    traj["bootstrap_obs"] = obs
    return traj, env_state, obs
