"""The paper's evaluation protocol (Sec. 5, following Henderson et al.
2017 / Colas et al. 2018) as a reusable module.

* ``final_metric``          — mean over the last ``n_episodes`` completed
  evaluation episodes across the last ``n_policies`` policies (paper: 100
  episodes = 10 episodes x last 10 policies).
* ``final_time_metric``     — final_metric at a wall-clock budget: the
  training stream is truncated at ``time_limit`` (virtual or real
  seconds) before applying final_metric.
* ``required_time_metric``  — first time the running average of the most
  recent ``window`` completed episodes reaches ``target``.
* ``bootstrap_ci``          — percentile bootstrap CI over episode
  returns (paper: 10k resamples, 95%).
* ``evaluate_policy``       — runs no-op-started greedy/sampled episodes
  (the paper's 30-no-op Atari convention, parameterized).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import determinism
from repro.envs.interfaces import Env


def episode_returns_from_stream(rewards, dones) -> np.ndarray:
    """(T, N) reward/done streams -> array of completed episode returns
    in completion order (row-major over time, then env)."""
    r = np.asarray(rewards, np.float64)
    d = np.asarray(dones)
    acc = np.zeros(r.shape[1])
    out = []
    for t in range(r.shape[0]):
        acc += r[t]
        done_envs = np.nonzero(d[t] > 0)[0]
        for e in done_envs:
            out.append(acc[e])
            acc[e] = 0.0
    return np.asarray(out)


def final_metric(rewards, dones, n_episodes: int = 100) -> float:
    eps = episode_returns_from_stream(rewards, dones)
    if len(eps) == 0:
        return float("nan")
    return float(eps[-n_episodes:].mean())


def final_time_metric(rewards, dones, step_times,
                      time_limit: float, n_episodes: int = 100) -> float:
    """step_times: per-row wall/virtual duration (T,). Truncate the stream
    at the cumulative time budget, then final_metric."""
    ct = np.cumsum(np.asarray(step_times, np.float64))
    cut = int(np.searchsorted(ct, time_limit, side="right"))
    return final_metric(np.asarray(rewards)[:cut],
                        np.asarray(dones)[:cut], n_episodes)


def required_time_metric(rewards, dones, step_times, target: float,
                         window: int = 100) -> float:
    """Seconds (same unit as step_times) until the running mean of the
    last ``window`` completed episodes first reaches ``target``; inf if
    never."""
    r = np.asarray(rewards, np.float64)
    d = np.asarray(dones)
    ct = np.cumsum(np.asarray(step_times, np.float64))
    acc = np.zeros(r.shape[1])
    recent: list = []
    for t in range(r.shape[0]):
        acc += r[t]
        for e in np.nonzero(d[t] > 0)[0]:
            recent.append(acc[e])
            acc[e] = 0.0
        if recent and np.mean(recent[-window:]) >= target:
            return float(ct[t])
    return float("inf")


def bootstrap_ci(samples: Sequence[float], n_boot: int = 10_000,
                 alpha: float = 0.05, seed: int = 0
                 ) -> Tuple[float, float, float]:
    """(mean, lo, hi) percentile bootstrap CI (paper: Facebook Bootstrapped
    settings — 10k resamples, 95%)."""
    x = np.asarray(samples, np.float64)
    if len(x) == 0:
        return float("nan"), float("nan"), float("nan")
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(x), size=(n_boot, len(x)))
    means = x[idx].mean(axis=1)
    return (float(x.mean()),
            float(np.percentile(means, 100 * alpha / 2)),
            float(np.percentile(means, 100 * (1 - alpha / 2))))


def evaluate_policy(policy_apply: Callable, params, env: Env,
                    n_episodes: int = 10, max_steps: int = 1000,
                    noop_max: int = 0, noop_action: int = 0,
                    greedy: bool = True, seed: int = 0) -> np.ndarray:
    """Run evaluation episodes (single env, sequential). The paper's
    Atari convention applies up to ``noop_max`` no-op actions at episode
    start. Returns the per-episode returns."""
    master = determinism.master_key(seed)
    out = []
    for ep in range(n_episodes):
        key = jax.random.fold_in(master, ep)
        state, obs = env.reset(key)
        n_noop = int(jax.random.randint(jax.random.fold_in(key, 1), (),
                                        0, noop_max + 1)) if noop_max else 0
        ret, done = 0.0, False
        for t in range(max_steps):
            if t < n_noop:
                a = jnp.int32(noop_action)
            else:
                logits, _ = policy_apply(params, obs[None])
                if greedy:
                    a = jnp.argmax(logits[0]).astype(jnp.int32)
                else:
                    a = determinism.sample_action(
                        determinism.obs_key(master, ep, t), logits[0])
            state, obs, r, d = env.step(state, a,
                                        jax.random.fold_in(key, 100 + t))
            ret += float(r)
            if float(d) > 0:
                done = True
                break
        out.append(ret)
    return np.asarray(out)
