"""The paper's evaluation protocol (Sec. 5, following Henderson et al.
2017 / Colas et al. 2018) as a reusable module.

* ``final_metric``          — mean over the last ``n_episodes`` completed
  evaluation episodes across the last ``n_policies`` policies (paper: 100
  episodes = 10 episodes x last 10 policies).
* ``final_time_metric``     — final_metric at a wall-clock budget: the
  training stream is truncated at ``time_limit`` (virtual or real
  seconds) before applying final_metric.
* ``required_time_metric``  — first time the running average of the most
  recent ``window`` completed episodes reaches ``target``.
* ``bootstrap_ci``          — percentile bootstrap CI over episode
  returns (paper: 10k resamples, 95%).
* ``evaluate_policy``       — runs no-op-started greedy/sampled episodes
  (the paper's 30-no-op Atari convention, parameterized).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import determinism
from repro.envs.interfaces import Env


def _episode_returns_loop(rewards, dones) -> np.ndarray:
    """O(T*N) Python-loop reference for episode_returns_from_stream —
    kept as the property-test oracle (tests/test_eval_protocol.py)."""
    r = np.asarray(rewards, np.float64)
    d = np.asarray(dones)
    acc = np.zeros(r.shape[1])
    out = []
    for t in range(r.shape[0]):
        acc += r[t]
        done_envs = np.nonzero(d[t] > 0)[0]
        for e in done_envs:
            out.append(acc[e])
            acc[e] = 0.0
    return np.asarray(out)


def _episode_returns_vec(r: np.ndarray, d: np.ndarray, acc: np.ndarray):
    """Vectorized core: (T, N) f64 rewards, (T, N) bool dones, (N,)
    carried per-env partial-episode accumulator. Returns (completed
    episode returns in completion order, updated accumulator)."""
    T, N = r.shape
    acc_in = np.asarray(acc, np.float64)
    if T == 0:
        return np.zeros(0, np.float64), acc_in.copy()
    cs = acc_in[None, :] + np.cumsum(r, axis=0)        # (T, N) inclusive
    t_idx, e_idx = np.nonzero(d)       # row-major == completion order
    vals = cs[t_idx, e_idx]            # cumulative total at each done
    acc_out = cs[-1].copy()
    if len(t_idx) == 0:
        return np.zeros(0, np.float64), acc_out
    # per-env episode return = cumulative at this done minus cumulative
    # at the env's previous done (0 for its first episode): group the
    # done events by env (time-sorted within a group), difference, then
    # scatter back to completion order
    order = np.lexsort((t_idx, e_idx))
    v, e = vals[order], e_idx[order]
    prev = np.empty_like(v)
    prev[1:] = v[:-1]
    first_of_env = np.ones(len(e), bool)
    first_of_env[1:] = e[1:] != e[:-1]
    prev[first_of_env] = 0.0
    out = np.empty_like(vals)
    out[order] = v - prev
    # envs that completed an episode carry only the post-last-done tail
    last_of_env = np.ones(len(e), bool)
    last_of_env[:-1] = e[1:] != e[:-1]
    acc_out[e[last_of_env]] = cs[-1][e[last_of_env]] - v[last_of_env]
    return out, acc_out


def episode_returns_from_stream(rewards, dones) -> np.ndarray:
    """(T, N) reward/done streams -> array of completed episode returns
    in completion order (row-major over time, then env). Vectorized;
    bit-equal to the loop reference (hypothesis-tested)."""
    r = np.asarray(rewards, np.float64)
    d = np.asarray(dones) > 0
    out, _ = _episode_returns_vec(r, d, np.zeros(r.shape[1]))
    return out


class ReturnStream:
    """Streaming episode returns for chunked/checkpointed training
    (core/trainer.py): feed (T, N) or (intervals, alpha, N) reward/done
    chunks in order; episodes spanning chunk (and therefore checkpoint)
    boundaries are counted exactly once, because the per-env
    partial-episode accumulator is carried across ``extend`` calls.
    Feeding a stream in any chunking yields the returns of the one-shot
    ``episode_returns_from_stream`` on the concatenation — bit-exactly
    for integer-valued rewards (catch/gridmaze/football all emit small
    integers, so the f64 cumsums are exact), and to float rounding
    (~1 ulp, from re-associating the accumulator sum at chunk
    boundaries) for arbitrary real rewards.

    ``state_dict``/``load_state_dict`` round-trip the carry through JSON
    so the trainer's checkpoints resume the evaluation protocol, not just
    the parameters. The serialized history is CAPPED at the
    ``max_saved_returns`` most-recent episodes (plus the lifetime count)
    so checkpoint metadata stays O(1) over arbitrarily long runs — the
    paper's final metric only ever looks at the last 100 episodes.
    """

    def __init__(self, n_envs: int, max_saved_returns: int = 10_000):
        self.n_envs = n_envs
        self.max_saved_returns = max_saved_returns
        self.acc = np.zeros(n_envs, np.float64)
        self._returns: list = []
        self._n_dropped = 0      # pre-resume episodes truncated from tail

    def extend(self, rewards, dones) -> np.ndarray:
        """Append a chunk; returns the episodes completed within it."""
        r = np.asarray(rewards, np.float64).reshape(-1, self.n_envs)
        d = np.asarray(dones).reshape(-1, self.n_envs) > 0
        out, self.acc = _episode_returns_vec(r, d, self.acc)
        self._returns.extend(out.tolist())
        return out

    @property
    def returns(self) -> np.ndarray:
        """Known returns in completion order (a resumed stream may have
        dropped all but the last ``max_saved_returns`` of its pre-resume
        history; ``n_total`` keeps the lifetime count)."""
        return np.asarray(self._returns, np.float64)

    @property
    def n_total(self) -> int:
        return self._n_dropped + len(self._returns)

    def final_metric(self, n_episodes: int = 100) -> float:
        """Paper Sec. 5 final metric over the stream so far."""
        if not self._returns:
            return float("nan")
        return float(self.returns[-n_episodes:].mean())

    def state_dict(self) -> dict:
        return {"n_envs": self.n_envs, "acc": self.acc.tolist(),
                "returns": list(self._returns[-self.max_saved_returns:]),
                "n_total": self.n_total}

    def load_state_dict(self, state: dict) -> "ReturnStream":
        if int(state["n_envs"]) != self.n_envs:
            raise ValueError(
                f"ReturnStream resumed with n_envs={self.n_envs} but the "
                f"checkpoint recorded {state['n_envs']}")
        self.acc = np.asarray(state["acc"], np.float64)
        self._returns = list(state["returns"])
        self._n_dropped = (int(state.get("n_total", len(self._returns)))
                           - len(self._returns))
        return self


def final_metric(rewards, dones, n_episodes: int = 100) -> float:
    eps = episode_returns_from_stream(rewards, dones)
    if len(eps) == 0:
        return float("nan")
    return float(eps[-n_episodes:].mean())


def final_time_metric(rewards, dones, step_times,
                      time_limit: float, n_episodes: int = 100) -> float:
    """step_times: per-row wall/virtual duration (T,). Truncate the stream
    at the cumulative time budget, then final_metric."""
    ct = np.cumsum(np.asarray(step_times, np.float64))
    cut = int(np.searchsorted(ct, time_limit, side="right"))
    return final_metric(np.asarray(rewards)[:cut],
                        np.asarray(dones)[:cut], n_episodes)


def required_time_metric(rewards, dones, step_times, target: float,
                         window: int = 100) -> float:
    """Seconds (same unit as step_times) until the running mean of the
    last ``window`` completed episodes first reaches ``target``; inf if
    never."""
    r = np.asarray(rewards, np.float64)
    d = np.asarray(dones)
    ct = np.cumsum(np.asarray(step_times, np.float64))
    acc = np.zeros(r.shape[1])
    recent: list = []
    for t in range(r.shape[0]):
        acc += r[t]
        for e in np.nonzero(d[t] > 0)[0]:
            recent.append(acc[e])
            acc[e] = 0.0
        if recent and np.mean(recent[-window:]) >= target:
            return float(ct[t])
    return float("inf")


def bootstrap_ci(samples: Sequence[float], n_boot: int = 10_000,
                 alpha: float = 0.05, seed: int = 0
                 ) -> Tuple[float, float, float]:
    """(mean, lo, hi) percentile bootstrap CI (paper: Facebook Bootstrapped
    settings — 10k resamples, 95%)."""
    x = np.asarray(samples, np.float64)
    if len(x) == 0:
        return float("nan"), float("nan"), float("nan")
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(x), size=(n_boot, len(x)))
    means = x[idx].mean(axis=1)
    return (float(x.mean()),
            float(np.percentile(means, 100 * alpha / 2)),
            float(np.percentile(means, 100 * (1 - alpha / 2))))


def evaluate_policy(policy_apply: Callable, params, env: Env,
                    n_episodes: int = 10, max_steps: int = 1000,
                    noop_max: int = 0, noop_action: int = 0,
                    greedy: bool = True, seed: int = 0) -> np.ndarray:
    """Run evaluation episodes (single env, sequential). The paper's
    Atari convention applies up to ``noop_max`` no-op actions at episode
    start. Returns the per-episode returns."""
    master = determinism.master_key(seed)
    out = []
    for ep in range(n_episodes):
        key = jax.random.fold_in(master, ep)
        state, obs = env.reset(key)
        n_noop = int(jax.random.randint(jax.random.fold_in(key, 1), (),
                                        0, noop_max + 1)) if noop_max else 0
        ret, done = 0.0, False
        for t in range(max_steps):
            if t < n_noop:
                a = jnp.int32(noop_action)
            else:
                logits, _ = policy_apply(params, obs[None])
                if greedy:
                    a = jnp.argmax(logits[0]).astype(jnp.int32)
                else:
                    a = determinism.sample_action(
                        determinism.obs_key(master, ep, t), logits[0])
            state, obs, r, d = env.step(state, a,
                                        jax.random.fold_in(key, 100 + t))
            ret += float(r)
            if float(d) > 0:
                done = True
                break
        out.append(ret)
    return np.asarray(out)
