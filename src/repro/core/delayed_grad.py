"""One-step delayed gradient (paper Sec. 4.1, Eq. 6; appendix C).

    theta_{j+1} = theta_j + eta * grad_{theta_{j-1}} J(theta_{j-1}, D^{theta_{j-1}})

The gradient is computed at the *behavior* parameters (one update old) on
the data those parameters generated — so the pg estimator itself stays
on-policy — and only its application point is delayed by one. With the
double-buffer schedule the delay is exactly one by construction, keeping
the O(1/sqrt(T)) rate of the undelayed method (Langford et al., 2009).

``DelayedGradState`` carries (params_cur, params_prev, opt_state). The
``update`` is a pure function usable under jit/pjit; ``grads`` must have
been taken at ``state.params_prev``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer, apply_updates


class DelayedGradState(NamedTuple):
    params: Any         # theta_j  (target policy — receives updates)
    params_prev: Any    # theta_{j-1} (behavior policy — gradient point)
    opt_state: Any
    step: jnp.ndarray


def init(params, opt: Optimizer) -> DelayedGradState:
    return DelayedGradState(
        params=params,
        params_prev=jax.tree.map(jnp.copy, params),
        opt_state=opt.init(params),
        step=jnp.zeros((), jnp.int32),
    )


def update(state: DelayedGradState, grads, opt: Optimizer,
           skip: jnp.ndarray | None = None) -> DelayedGradState:
    """Apply a gradient taken at params_prev to params.

    skip: optional bool — when True the parameter update is suppressed but
    the behavior snapshot still advances (used for the bootstrap interval
    where the read storage is still empty). A skipped update does not
    count toward ``step``, so ``step`` always equals the number of
    updates actually applied (comparable across runtimes)."""
    updates, opt_state = opt.update(grads, state.opt_state, state.params)
    new_params = apply_updates(state.params, updates)
    applied = jnp.ones((), jnp.int32)
    if skip is not None:
        keep = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(skip, o, n), new, old)
        new_params = keep(new_params, state.params)
        opt_state = keep(opt_state, state.opt_state)
        applied = jnp.where(skip, 0, 1).astype(jnp.int32)
    return DelayedGradState(
        params=new_params,
        params_prev=state.params,     # behavior policy advances by one
        opt_state=opt_state,
        step=state.step + applied,
    )


def behavior_lag(state: DelayedGradState) -> int:
    """The structural guarantee: behavior is exactly one update behind."""
    return 1
