"""Delayed gradient with a configurable staleness bound K (paper Sec. 4.1,
Eq. 6 at K=1; appendix C):

    theta_{j+1} = theta_j + eta * grad_{theta_{j-K}} J(theta_{j-K}, D^{theta_{j-K}})

The gradient is computed at the *behavior* parameters (K updates old) on
the data those parameters generated — so the pg estimator itself stays
on-policy — and only its application point is delayed by K. With the
slab-ring schedule (core/buffers.SlabRing) the delay is exactly K by
construction: K=1 is the paper's double buffer ("price of determinism");
K>1 trades a bounded, structural staleness for pipeline slack (the
learner gets K rollout intervals of wall time per update — see
DESIGN.md §4 and benchmarks/staleness_sweep.py).

``DelayedGradState`` carries (params, params_prev, opt_state, step).
``params_prev`` is the behavior history:

* K=1 — the plain one-update-old parameter pytree (unchanged from the
  delay-1 implementation, so every existing delay-1 consumer — the LLM
  learner path, sharding rules, examples — keeps working untouched);
* K>1 — a stacked ring: each leaf gains a leading K axis, oldest first,
  holding theta_{j-K} .. theta_{j-1}.

The depth is *structural* — ``behavior_lag`` reads it off the leaf
shapes, so there is no staleness scalar to keep in sync (or to lose in a
checkpoint). ``update`` is a pure function usable under jit/pjit;
``grads`` must have been taken at ``behavior_params(state)``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer, apply_updates


class DelayedGradState(NamedTuple):
    params: Any         # theta_j  (target policy — receives updates)
    params_prev: Any    # behavior history (plain at K=1, (K, ...) ring else)
    opt_state: Any
    step: jnp.ndarray


def init(params, opt: Optimizer, staleness: int = 1) -> DelayedGradState:
    if staleness < 1:
        raise ValueError(f"staleness must be >= 1, got {staleness}")
    if staleness == 1:
        prev = jax.tree.map(jnp.copy, params)
    else:
        prev = jax.tree.map(
            lambda p: jnp.stack([jnp.asarray(p)] * staleness), params)
    return DelayedGradState(
        params=params,
        params_prev=prev,
        opt_state=opt.init(params),
        step=jnp.zeros((), jnp.int32),
    )


def behavior_lag(state: DelayedGradState) -> int:
    """The structural staleness bound K: how many updates the behavior
    history spans. Read off the leaf shapes — a ring leaf carries one
    extra leading axis relative to its parameter leaf — so the lag can
    never silently disagree with the stored history."""
    p = jax.tree.leaves(state.params)[0]
    h = jax.tree.leaves(state.params_prev)[0]
    return int(h.shape[0]) if h.ndim == p.ndim + 1 else 1


def behavior_params(state: DelayedGradState):
    """theta_{j-K} — the gradient point for the next update (the oldest
    behavior snapshot; at K=1 this is just ``params_prev``)."""
    if behavior_lag(state) == 1:
        return state.params_prev
    return jax.tree.map(lambda h: h[0], state.params_prev)


def _advance_history(state: DelayedGradState):
    """Roll the behavior history forward by one: drop theta_{j-K}, append
    theta_j. At K=1 the history IS theta_j."""
    if behavior_lag(state) == 1:
        return state.params
    return jax.tree.map(
        lambda h, p: jnp.concatenate([h[1:], p[None]], axis=0),
        state.params_prev, state.params)


def update(state: DelayedGradState, grads, opt: Optimizer,
           skip: jnp.ndarray | None = None) -> DelayedGradState:
    """Apply a gradient taken at ``behavior_params(state)`` to params.

    skip: optional bool — when True the parameter update is suppressed but
    the behavior history still advances (used for the first K bootstrap
    intervals, where the read ring slot is still empty). A skipped update
    does not count toward ``step``, so ``step`` always equals the number
    of updates actually applied (comparable across runtimes)."""
    updates, opt_state = opt.update(grads, state.opt_state, state.params)
    new_params = apply_updates(state.params, updates)
    applied = jnp.ones((), jnp.int32)
    if skip is not None:
        keep = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(skip, o, n), new, old)
        new_params = keep(new_params, state.params)
        opt_state = keep(opt_state, state.opt_state)
        applied = jnp.where(skip, 0, 1).astype(jnp.int32)
    return DelayedGradState(
        params=new_params,
        params_prev=_advance_history(state),  # behavior advances by one
        opt_state=opt_state,
        step=state.step + applied,
    )
