"""Multi-process data parallelism: ``jax.distributed`` wiring for the
sharded runtime.

One process per host (or per forced-host-device group) joins a
coordinator; afterwards ``jax.devices()`` spans every process and a
single :class:`~jax.sharding.Mesh` over them runs the SAME shard_map
program the single-process sharded runtime runs — same spec, same
geometry, same floats. The determinism contract (DESIGN.md §12) does
the heavy lifting: env ids are globally offset by the replica index and
the gradient is the canonical tree sum combined in env-index order, so
N processes produce the parameters of the 1-process run bit-exactly.

CPU specifics (and why this module exists at all): the default CPU
collective implementation cannot execute multi-process computations —
``jax_cpu_collectives_implementation`` must be switched to ``"gloo"``
BEFORE ``jax.distributed.initialize``, or every collective fails with
"Multiprocess computations aren't implemented on the CPU backend".
:func:`initialize` orders those two calls correctly and is idempotent.

Entry point: ``python -m repro.launch.distributed`` (one invocation per
process); CI exercises a 2-process run via subprocess with forced host
devices (tests/test_batch_geometry.py).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax

__all__ = ["initialize", "is_initialized", "global_data_mesh"]

_initialized = False


def is_initialized() -> bool:
    return _initialized


def initialize(coordinator_address: str, num_processes: int,
               process_id: int) -> None:
    """Join (or form) the ``jax.distributed`` cluster. Idempotent.

    Must run before any other JAX call touches the backend — device
    initialization locks the process topology, exactly like
    ``XLA_FLAGS`` device forcing.
    """
    global _initialized
    if _initialized:
        return
    if num_processes < 1 or not (0 <= process_id < num_processes):
        raise ValueError(
            f"bad process topology: process_id={process_id}, "
            f"num_processes={num_processes}")
    # ORDER MATTERS: the gloo switch must precede initialize() — the
    # default CPU collectives reject multi-process programs outright.
    # Set unconditionally (it only affects the CPU backend): probing
    # the backend first would itself initialize it and lock the
    # process topology.
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    _initialized = True


def global_data_mesh(axis: str = "data",
                     n_replicas: Optional[int] = None):
    """A 1-D mesh over the GLOBAL device list (all processes).

    ``n_replicas`` must equal the global device count when given: a
    mesh covering only some processes would leave the rest executing a
    program they hold no shard of — reject it loudly instead.
    """
    from jax.sharding import Mesh
    devices = jax.devices()
    if n_replicas is not None and n_replicas != len(devices):
        raise ValueError(
            f"batch.n_replicas={n_replicas} != {len(devices)} global "
            f"device(s) across {jax.process_count()} process(es); in "
            f"the multi-process path every device is a replica — size "
            f"the process topology to the geometry")
    return Mesh(np.array(devices), (axis,))
