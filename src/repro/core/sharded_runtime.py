"""Data-parallel HTS-RL: the fused interval step under ``shard_map``.

The first runtime that scales ``n_envs`` past one device. Environment
replicas are sharded along the mesh's ``data`` axis (launch/mesh.py);
each shard runs the SAME fused learner+rollout program as the mesh
runtime over its local slice, and the delayed gradient crosses replicas
through a single all-gather-and-tree-combine per logical step — the
only inter-device communication per interval (params stay replicated,
matching the paper's learner/actor split where only the update is
global).

Replica count comes from the batch geometry
(``repro.core.batch.BatchConfig``): an explicit ``batch.n_replicas``
sizes the data axis to EXACTLY that many devices (erroring when the
platform has fewer); the legacy default (``n_replicas=None``) keeps the
pre-BatchConfig behavior of spanning every local device. Within each
replica, ``grad_accumulation`` microbatch blocks are scanned before the
cross-replica combine — grads cross replicas once per logical step,
never per microbatch.

Determinism is preserved across device counts AND processes: rollout
env ids are offset by ``axis_index('data') * n_envs_local``, so env
replica e draws exactly the (run_seed, e, step) keys it would on one
device, whichever shard (or process) hosts it. Trajectories are
therefore bit-exact for any factorization — and since PR 9 the PARAMS
are too: the canonical per-env tree-sum gradient (repro.core.batch,
DESIGN.md §12) makes the d-device run bit-identical to the mesh
runtime for every validated geometry, not merely float-close.

Multi-process meshes (core/distributed.py): when the data axis spans
processes, the initial carry — computed identically on every process
from the shared seed — is assembled into global ``jax.Array``s per the
carry specs, and metric streams are all-gathered back to every host.
"""
from __future__ import annotations

from typing import Callable

import numpy as np
import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import mesh_runtime
from repro.core.batch import BatchConfig
from repro.core.engine import (HTSConfig, ScanRuntimeBase,
                               register_runtime)
from repro.envs.device import batched_env
from repro.envs.interfaces import Env
from repro.launch.mesh import make_host_mesh
from repro.optim import Optimizer


@register_runtime("sharded")
class ShardedHTSRL(ScanRuntimeBase):
    name = "sharded"

    def __init__(self, env: Env, policy_apply: Callable, params,
                 opt: Optimizer, cfg: HTSConfig, mesh=None,
                 axis: str = "data", batch=None):
        super().__init__(env, policy_apply, params, opt, cfg)
        if cfg.staleness < 1:
            raise ValueError(f"staleness must be >= 1, got {cfg.staleness}")
        self.batch = BatchConfig.of(batch)
        self.axis = axis
        if mesh is None:
            if self.batch.n_replicas is not None:
                # explicit geometry sizes the replica axis EXACTLY —
                # "however many devices happen to exist" is the thing
                # BatchConfig exists to remove
                want = self.batch.n_replicas
                devices = jax.devices()
                if len(devices) < want:
                    raise ValueError(
                        f"batch.n_replicas={want} but only "
                        f"{len(devices)} device(s) are visible; start "
                        f"more processes (core/distributed.py) or "
                        f"force host devices "
                        f"(--xla_force_host_platform_device_count)")
                mesh = Mesh(np.array(devices[:want]), (axis,))
            else:
                mesh = make_host_mesh()
        elif (self.batch.n_replicas is not None
              and mesh.shape[axis] != self.batch.n_replicas):
            raise ValueError(
                f"batch.n_replicas={self.batch.n_replicas} != the "
                f"{mesh.shape[axis]}-way '{axis}' axis of the provided "
                f"mesh; size the mesh from the batch geometry")
        self.mesh = mesh
        n_shards = self.mesh.shape[axis]
        # geometry checks (divisibility; power-of-two alignment for
        # explicit configs) with the spec-style field-named errors
        self.geometry = self.batch.resolve(cfg.n_envs,
                                           default_replicas=n_shards)
        if cfg.n_envs % n_shards:
            raise ValueError(
                f"n_envs={cfg.n_envs} not divisible by the {n_shards}-way "
                f"'{axis}' mesh axis")
        self.n_shards = n_shards
        self.lcfg = cfg._replace(n_envs=cfg.n_envs // n_shards)
        # does the data axis span OS processes? (core/distributed.py)
        self._multiprocess = len(
            {d.process_index for d in self.mesh.devices.flat}) > 1
        # a DeviceEnv steps any leading batch width, so the same port
        # serves both the per-shard body and the global init
        self.venv_local = batched_env(env, self.lcfg.n_envs,
                                      cfg.env_backend)
        self.venv_global = batched_env(env, cfg.n_envs, cfg.env_backend)

    def _build(self) -> None:
        # per-shard accumulation plus the global divide: gradients are
        # canonical tree SUMS locally, combined across the axis once
        # per logical step, divided by the GLOBAL env count at the end
        self._step = mesh_runtime.make_hts_step(
            self.policy_apply, self.venv_local, self.opt, self.lcfg,
            axis_name=self.axis,
            grad_accumulation=self.geometry.grad_accumulation,
            total_envs=self.cfg.n_envs)
        self._learn = mesh_runtime.make_learner_update(
            self.policy_apply, self.opt, self.lcfg, axis_name=self.axis,
            grad_accumulation=self.geometry.grad_accumulation,
            total_envs=self.cfg.n_envs)
        self._final_prog = None     # built lazily (needs carry specs)

    def _initial_carry(self):
        # global carry (identical to the mesh runtime's); shard_map slices
        # the env/trajectory leaves along the data axis per in_specs
        carry = mesh_runtime.init_carry(
            self.params0, self.opt, self.venv_global, self.cfg,
            self.policy_apply)
        if self._multiprocess:
            carry = self._globalize(carry)
        return carry

    def _globalize(self, carry):
        """Assemble per-process (identically computed) carry leaves into
        global ``jax.Array``s laid out per the carry specs. Every
        process computes the FULL logical carry from the shared seed —
        cheap at init — and contributes the shards its local devices
        own, so no cross-host transfer happens at all."""
        specs = self._carry_specs(carry)

        def wrap(x, spec):
            x = np.asarray(x)
            sharding = NamedSharding(self.mesh, spec)
            return jax.make_array_from_callback(
                x.shape, sharding, lambda idx, _x=x: _x[idx])

        return jax.tree.map(wrap, carry, specs)

    def _host_metrics(self, metrics):
        # metric streams are sharded over the data axis; on a
        # multi-process mesh each host holds only its slice, so gather
        # the global streams back to every process (they are reporting
        # data — tiny next to the training state)
        if self._multiprocess:
            from jax.experimental import multihost_utils
            metrics = multihost_utils.process_allgather(metrics,
                                                        tiled=True)
        return metrics

    def _carry_specs(self, carry):
        dg, env_state, obs, buf, j = carry
        rep = lambda tree: jax.tree.map(lambda _: P(), tree)
        shard0 = lambda tree: jax.tree.map(lambda _: P(self.axis), tree)
        # ring slots (K>1) prepend a replicated staleness axis in front
        # of the (alpha, n_envs, ...) trajectory leaves
        ring = (None,) if self.cfg.staleness > 1 else ()
        buf_spec = {k: (P(*ring, self.axis) if k == "bootstrap_obs"
                        else P(*ring, None, self.axis)) for k in buf}
        return (rep(dg), shard0(env_state), P(self.axis), buf_spec, P())

    def _program(self, n_intervals: int):
        carry_specs = self._carry_specs(self.carry)
        metric_specs = {"rewards": P(None, None, self.axis),
                        "dones": P(None, None, self.axis)}

        def body(carry):
            return jax.lax.scan(self._step, carry, None,
                                length=n_intervals)

        # carry donated like every scan runtime (see
        # engine.ScanRuntimeBase._program): params/opt-state/trajectory
        # shards update in place across the program boundary
        return jax.jit(shard_map(body, mesh=self.mesh,
                                 in_specs=(carry_specs,),
                                 out_specs=(carry_specs, metric_specs),
                                 check_rep=False),
                       donate_argnums=0)

    def _finalize(self, carry):
        # reporting-only trailing learner passes draining the K pending
        # ring slots (same update-count contract as host/mesh; skip
        # guards the not-yet-filled slots). Its collective needs the
        # mesh axis, so it is its own shard_map program — separate from
        # the scan, which must leave the carry mid-stream for run_from.
        # make_ring_drain's pass-per-dispatch structure (see its
        # docstring: chained passes fused into one program are not
        # value-stable across compilation contexts), with the
        # single-pass program wrapped in shard_map for the collective.
        if self._final_prog is None:
            dg_spec, _, _, buf_spec, j_spec = self._carry_specs(carry)
            slot_spec = {k: (P(self.axis) if k == "bootstrap_obs"
                             else P(None, self.axis)) for k in carry[3]}
            wrap = lambda f: jax.jit(shard_map(
                f, mesh=self.mesh,
                in_specs=(dg_spec, slot_spec, P()),
                out_specs=dg_spec, check_rep=False))
            self._final_prog = mesh_runtime.make_ring_drain(
                self._learn, self.cfg.staleness, wrap=wrap)
        dg, env_state, obs, buf, j = carry
        return (self._final_prog(dg, buf, j), env_state, obs, buf, j)

    def _result_state(self, carry):
        return carry[0].params, carry[0]
