"""Data-parallel HTS-RL: the fused interval step under ``shard_map``.

The first runtime that scales ``n_envs`` past one device. Environment
replicas are sharded along the mesh's ``data`` axis (launch/mesh.py);
each shard runs the SAME fused learner+rollout program as the mesh
runtime over its local slice, and the one-step delayed gradient crosses
replicas through a single ``pmean`` all-reduce before the update — the
only inter-device communication per interval (params stay replicated,
matching the paper's learner/actor split where only the update is
global).

Determinism is preserved across device counts: rollout env ids are offset
by ``axis_index('data') * n_envs_local``, so env replica e draws exactly
the (run_seed, e, step) keys it would on one device, whichever shard
hosts it. On a 1-device mesh the program is bit-identical to the mesh
runtime (tests/test_equivalence.py); on d devices only the gradient
reduction order changes (per-shard mean, then cross-shard mean), so
parameters agree to float tolerance while trajectories stay bit-exact.
"""
from __future__ import annotations

from typing import Callable

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import mesh_runtime
from repro.core.engine import (HTSConfig, ScanRuntimeBase,
                               register_runtime)
from repro.envs.device import batched_env
from repro.envs.interfaces import Env
from repro.launch.mesh import make_host_mesh
from repro.optim import Optimizer


@register_runtime("sharded")
class ShardedHTSRL(ScanRuntimeBase):
    name = "sharded"

    def __init__(self, env: Env, policy_apply: Callable, params,
                 opt: Optimizer, cfg: HTSConfig, mesh=None,
                 axis: str = "data"):
        super().__init__(env, policy_apply, params, opt, cfg)
        if cfg.staleness < 1:
            raise ValueError(f"staleness must be >= 1, got {cfg.staleness}")
        self.mesh = mesh if mesh is not None else make_host_mesh()
        self.axis = axis
        n_shards = self.mesh.shape[axis]
        if cfg.n_envs % n_shards:
            raise ValueError(
                f"n_envs={cfg.n_envs} not divisible by the {n_shards}-way "
                f"'{axis}' mesh axis")
        self.n_shards = n_shards
        self.lcfg = cfg._replace(n_envs=cfg.n_envs // n_shards)
        # a DeviceEnv steps any leading batch width, so the same port
        # serves both the per-shard body and the global init
        self.venv_local = batched_env(env, self.lcfg.n_envs,
                                      cfg.env_backend)
        self.venv_global = batched_env(env, cfg.n_envs, cfg.env_backend)

    def _build(self) -> None:
        self._step = mesh_runtime.make_hts_step(
            self.policy_apply, self.venv_local, self.opt, self.lcfg,
            axis_name=self.axis)
        self._learn = mesh_runtime.make_learner_update(
            self.policy_apply, self.opt, self.lcfg, axis_name=self.axis)
        self._final_prog = None     # built lazily (needs carry specs)

    def _initial_carry(self):
        # global carry (identical to the mesh runtime's); shard_map slices
        # the env/trajectory leaves along the data axis per in_specs
        return mesh_runtime.init_carry(
            self.params0, self.opt, self.venv_global, self.cfg,
            self.policy_apply)

    def _carry_specs(self, carry):
        dg, env_state, obs, buf, j = carry
        rep = lambda tree: jax.tree.map(lambda _: P(), tree)
        shard0 = lambda tree: jax.tree.map(lambda _: P(self.axis), tree)
        # ring slots (K>1) prepend a replicated staleness axis in front
        # of the (alpha, n_envs, ...) trajectory leaves
        ring = (None,) if self.cfg.staleness > 1 else ()
        buf_spec = {k: (P(*ring, self.axis) if k == "bootstrap_obs"
                        else P(*ring, None, self.axis)) for k in buf}
        return (rep(dg), shard0(env_state), P(self.axis), buf_spec, P())

    def _program(self, n_intervals: int):
        carry_specs = self._carry_specs(self.carry)
        metric_specs = {"rewards": P(None, None, self.axis),
                        "dones": P(None, None, self.axis)}

        def body(carry):
            return jax.lax.scan(self._step, carry, None,
                                length=n_intervals)

        # carry donated like every scan runtime (see
        # engine.ScanRuntimeBase._program): params/opt-state/trajectory
        # shards update in place across the program boundary
        return jax.jit(shard_map(body, mesh=self.mesh,
                                 in_specs=(carry_specs,),
                                 out_specs=(carry_specs, metric_specs),
                                 check_rep=False),
                       donate_argnums=0)

    def _finalize(self, carry):
        # reporting-only trailing learner passes draining the K pending
        # ring slots (same update-count contract as host/mesh; skip
        # guards the not-yet-filled slots). Its pmean needs the mesh
        # axis, so it is its own shard_map program — separate from the
        # scan, which must leave the carry mid-stream for run_from.
        if self._final_prog is None:
            dg_spec, _, _, buf_spec, j_spec = self._carry_specs(carry)
            fin = mesh_runtime.make_ring_drain(self._learn,
                                               self.cfg.staleness)
            self._final_prog = jax.jit(shard_map(
                fin, mesh=self.mesh,
                in_specs=(dg_spec, buf_spec, j_spec),
                out_specs=dg_spec, check_rep=False))
        dg, env_state, obs, buf, j = carry
        return (self._final_prog(dg, buf, j), env_state, obs, buf, j)

    def _result_state(self, carry):
        return carry[0].params, carry[0]
