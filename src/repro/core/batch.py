"""BatchConfig: typed batch geometry, and the canonical reduction that
makes it bit-exact.

The paper's "global batch" — every env-step the learner differentiates
per synchronization interval — was an implicit product of whatever
``n_envs`` and device count happened to be wired. This module makes it
a first-class typed axis:

    global_batch = micro_batch x grad_accumulation x n_replicas

``n_envs`` (HTSConfig) IS the global batch: each env contributes one
``alpha``-step column to the interval trajectory. ``BatchConfig``
factorizes it — ``n_replicas`` data-parallel shards, each accumulating
``grad_accumulation`` microbatches of ``micro_batch`` envs — with eager,
field-named validation (the ``ExperimentSpec`` style): a rejected
geometry says WHICH field is wrong and suggests the nearest valid
factorization, never a silent default.

The scale-out determinism contract (DESIGN.md §12)
--------------------------------------------------
Changing the factorization must not change the optimization problem —
not approximately, bit-for-bit. Floating-point addition is commutative
but not associative, so the contract is a REDUCTION-ORDER contract:

  * the gradient is computed per env (vmap of grad over width-1 env
    slices; per-env grads are bit-stable across batch widths because
    every model forward is row-independent);
  * per-env gradients are combined by the adjacent-pairwise tree sum
    (``pairwise_tree_sum``) over the GLOBAL env index, accumulated in
    fp32;
  * replicas contribute tree-SUMS (all-gathered in env-index order and
    tree-combined), and the divide by ``global_batch`` happens exactly
    once, after the last sum.

A contiguous block of ``micro_batch = 2^d`` envs is then an exact
subtree of the global reduction tree, so any factorization whose blocks
align with subtrees computes the identical float — the validation rules
below are precisely that alignment condition:

  * ``global_batch % (grad_accumulation * n_replicas) == 0``
  * ``micro_batch`` (the block size) is a power of two
  * ``grad_accumulation`` is a power of two (so the within-replica
    combine is itself a subtree of the global tree)
  * ``n_replicas`` is unconstrained beyond divisibility: the
    cross-replica combine runs the SAME pairwise algorithm the
    single-replica tree runs above block level.

``grad_accumulation * n_replicas == 1`` imposes nothing (a single
block is trivially the whole tree) — legacy configs with any ``n_envs``
keep working unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Union

__all__ = ["BatchConfig", "ResolvedBatch", "pairwise_tree_sum"]


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def pairwise_tree_sum(x):
    """Adjacent-pairwise tree sum over axis 0 — THE canonical reduction
    order of the batch-geometry contract (module docstring).

    Level by level, element ``2i`` is added to ``2i+1``; an odd
    leftover rides along unmodified to the next level. Equal-size
    contiguous blocks of power-of-two width are exact subtrees, which
    is what makes hierarchical (microbatch -> replica -> global)
    reduction bit-identical to the flat one. Works on any jnp array
    with a leading reduce axis; pure, jit/scan/shard_map-safe."""
    import jax.numpy as jnp
    while x.shape[0] > 1:
        n = x.shape[0]
        half = n // 2
        paired = x[0:2 * half:2] + x[1:2 * half:2]
        if n % 2:
            paired = jnp.concatenate([paired, x[n - 1:n]], axis=0)
        x = paired
    return x[0]


class ResolvedBatch(NamedTuple):
    """A concrete geometry: every axis an int, product == global."""
    micro_batch: int
    grad_accumulation: int
    n_replicas: int
    global_batch: int

    @property
    def chunks(self) -> int:
        """Total gradient blocks per interval (accumulation x replicas)
        — what a single-process runtime scans over to reproduce the
        multi-replica reduction bit-exactly."""
        return self.grad_accumulation * self.n_replicas

    def canonical(self) -> dict:
        return {"micro_batch": int(self.micro_batch),
                "grad_accumulation": int(self.grad_accumulation),
                "n_replicas": int(self.n_replicas),
                "global_batch": int(self.global_batch)}


def _valid_factorizations(n_envs: int):
    """All (grad_accumulation, n_replicas) the alignment rules accept
    for this global batch."""
    out = []
    a = 1
    while a <= n_envs:
        for r in range(1, n_envs // a + 1):
            if n_envs % (a * r) == 0 and (
                    a * r == 1 or _is_pow2(n_envs // (a * r))):
                out.append((a, r))
        a *= 2
    return out


def _nearest_valid(n_envs: int, a: int, r: int) -> str:
    """The suggestion string for rejection errors: the accepted
    (grad_accumulation, n_replicas) closest to what was asked."""
    best = min(_valid_factorizations(n_envs),
               key=lambda ar: (abs(ar[0] - a) + abs(ar[1] - r), ar[0] + ar[1]))
    return (f"nearest valid factorization for global_batch={n_envs}: "
            f"grad_accumulation={best[0]}, n_replicas={best[1]} "
            f"(micro_batch={n_envs // (best[0] * best[1])})")


@dataclass(frozen=True)
class BatchConfig:
    """The spec's ``batch`` block. All fields optional:

    * ``micro_batch``        — envs per gradient microbatch (per
      replica). ``None``: derived as
      ``n_envs // (grad_accumulation * n_replicas)``.
    * ``grad_accumulation``  — microbatches accumulated (in fp32)
      before the one optimizer step per interval.
    * ``n_replicas``         — data-parallel replicas. ``None``: the
      runtime decides (1 for host/mesh; every device on the mesh for
      sharded — the pre-BatchConfig behavior, preserved exactly).

    Field-level checks run eagerly here; the geometry checks (which
    need ``n_envs``) run in :meth:`resolve` — ``ExperimentSpec``
    validation calls it, so a bad spec still fails at construction
    time with the offending ``batch.<field>`` named."""
    micro_batch: Optional[int] = None
    grad_accumulation: int = 1
    n_replicas: Optional[int] = None

    def __post_init__(self):
        for name in ("micro_batch", "n_replicas"):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int)
                                  or isinstance(v, bool) or v < 1):
                raise ValueError(
                    f"batch.{name} must be a positive int or null, "
                    f"got {v!r}")
        a = self.grad_accumulation
        if not isinstance(a, int) or isinstance(a, bool) or a < 1:
            raise ValueError(
                f"batch.grad_accumulation must be a positive int, "
                f"got {a!r}")

    @property
    def is_default(self) -> bool:
        return (self.micro_batch is None and self.grad_accumulation == 1
                and self.n_replicas is None)

    # ------------------------------------------------------ resolution
    def resolve(self, n_envs: int, default_replicas: int = 1,
                strict: Optional[bool] = None) -> ResolvedBatch:
        """Concretize against the global batch (``n_envs``).

        ``default_replicas`` fills ``n_replicas=None`` (the runtime's
        legacy replica count). ``strict`` controls the power-of-two
        alignment rules of the bit-exactness contract: default is
        strict exactly when the config is non-default — an explicitly
        configured geometry must honor the contract, while legacy
        runtime-determined geometry (e.g. a 3-device mesh) keeps
        working with divisibility checks only."""
        if strict is None:
            strict = not self.is_default
        a = self.grad_accumulation
        r = self.n_replicas
        if r is None and self.micro_batch is not None:
            # micro_batch + accumulation given: replicas derived from
            # global_batch = micro_batch * grad_accumulation * n_replicas
            per = self.micro_batch * a
            if n_envs % per:
                raise ValueError(
                    f"batch.micro_batch={self.micro_batch} x "
                    f"batch.grad_accumulation={a} = {per} does not "
                    f"divide global_batch (hts.n_envs) = {n_envs}; "
                    + _nearest_valid(n_envs, a, max(1, n_envs // per)))
            r = n_envs // per
        elif r is None:
            r = default_replicas
        chunks = a * r
        if n_envs % chunks:
            raise ValueError(
                f"batch.grad_accumulation={a} x batch.n_replicas={r} = "
                f"{chunks} does not divide global_batch (hts.n_envs) = "
                f"{n_envs}; " + _nearest_valid(n_envs, a, r))
        micro = n_envs // chunks
        if strict and chunks > 1:
            if not _is_pow2(a):
                raise ValueError(
                    f"batch.grad_accumulation={a} must be a power of "
                    f"two (the within-replica combine must be a "
                    f"subtree of the canonical reduction tree); "
                    + _nearest_valid(n_envs, a, r))
            if not _is_pow2(micro):
                raise ValueError(
                    f"batch.grad_accumulation={a} x "
                    f"batch.n_replicas={r} gives micro_batch={micro}, "
                    f"which must be a power of two for blocks to align "
                    f"with the canonical reduction tree; "
                    + _nearest_valid(n_envs, a, r))
        if self.micro_batch is not None and self.micro_batch != micro:
            raise ValueError(
                f"batch.micro_batch={self.micro_batch} inconsistent: "
                f"global_batch (hts.n_envs) = {n_envs} with "
                f"grad_accumulation={a}, n_replicas={r} implies "
                f"micro_batch={micro} "
                f"(global = micro x accumulation x replicas); "
                + _nearest_valid(n_envs, a, r))
        return ResolvedBatch(micro, a, r, n_envs)

    # --------------------------------------------------- serialization
    def canonical(self) -> dict:
        return {"micro_batch": self.micro_batch,
                "grad_accumulation": int(self.grad_accumulation),
                "n_replicas": self.n_replicas}

    @staticmethod
    def of(value: Union[None, dict, "BatchConfig"]) -> "BatchConfig":
        if isinstance(value, BatchConfig):
            return value
        if value is None:
            return BatchConfig()
        if isinstance(value, dict):
            unknown = set(value) - {"micro_batch", "grad_accumulation",
                                    "n_replicas"}
            if unknown:
                raise ValueError(
                    f"unknown batch field(s) {sorted(unknown)}; known: "
                    f"['grad_accumulation', 'micro_batch', "
                    f"'n_replicas']")
            return BatchConfig(**value)
        raise TypeError(f"batch must be a dict or BatchConfig, got "
                        f"{type(value).__name__}")
