"""Claim 2: expected behavior/target policy latency of asynchronous
actor-learner systems (GA3C / IMPALA) — M/M/1 queue analysis + simulator.

    E[L] = n*rho0 / (1 - n*rho0),   rho0 = lambda0 / mu

HTS-RL's latency is identically 1 regardless of actor count (the double
buffer admits exactly one outstanding interval).
"""
from __future__ import annotations

import numpy as np


def expected_latency(n_actors: int, lam0: float, mu: float) -> float:
    rho = n_actors * lam0 / mu
    if rho >= 1.0:
        return float("inf")
    return rho / (1.0 - rho)


def simulate_latency(n_actors: int, lam0: float, mu: float,
                     horizon: float = 2000.0, seed: int = 0):
    """Event-driven M/M/1: n_actors Poisson producers (aggregate rate
    n*lam0), one exponential consumer (rate mu). Returns the mean queue
    length seen by consumed items ≈ policy lag in updates."""
    rng = np.random.default_rng(seed)
    t, q = 0.0, 0
    next_arrival = rng.exponential(1.0 / (n_actors * lam0))
    next_service = np.inf
    lags = []
    while t < horizon:
        if next_arrival <= next_service:
            t = next_arrival
            q += 1
            if q == 1:
                next_service = t + rng.exponential(1.0 / mu)
            next_arrival = t + rng.exponential(1.0 / (n_actors * lam0))
        else:
            t = next_service
            lags.append(q - 1)     # items still ahead when this one leaves
            q -= 1
            next_service = (t + rng.exponential(1.0 / mu)) if q > 0 else np.inf
    return float(np.mean(lags)) if lags else 0.0


def hts_latency(n_actors: int) -> int:
    """HTS-RL: constant, by construction (see core/delayed_grad.py)."""
    return 1
