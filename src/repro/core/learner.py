"""The HTS-RL learner at LLM scale: A2C/PPO updates over token
trajectories with any assigned backbone as the policy/value network.

``train_step`` is the learner half of the fused HTS-RL interval (the
gradient is taken at ``dg.params_prev`` — the behavior policy — per the
one-step delayed gradient), and is what the multi-pod dry-run lowers for
the ``train_4k`` shape. ``prefill_step``/``serve_step`` are the actor
side (what actors run while executors step environments), lowered for the
``prefill_32k`` / ``decode_32k`` / ``long_500k`` shapes.

The per-block forward inside the scan is wrapped in ``jax.checkpoint``
for training so the backward pass rematerializes instead of storing every
intermediate (80-layer x 1M-token batches would otherwise need PBs of
activation memory).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import delayed_grad, losses
from repro.models import backbone
from repro.optim import Optimizer
from repro.sharding.constraints import constrain


def policy_hidden(params, cfg: ModelConfig, batch, remat: bool = True):
    """(hidden (B,S,D), aux)."""
    hidden, _, aux = backbone.forward(
        params, cfg, batch["tokens"],
        positions=batch.get("positions"),
        mrope_positions=batch.get("mrope_positions"),
        patch_embeds=batch.get("patch_embeds"),
        audio_embeds=batch.get("audio_embeds"),
        remat=remat)
    return hidden, aux


def policy_outputs(params, cfg: ModelConfig, batch, remat: bool = True):
    """(logits (B,S,V) f32, values (B,S) f32, aux). Materializes the full
    logits tensor — fine at smoke-test scale; the production loss path is
    the chunked one below."""
    hidden, aux = policy_hidden(params, cfg, batch, remat)
    logits, values = backbone.logits_and_value(params, cfg, hidden)
    return logits, values, aux


def _chunked_rl_loss(params, cfg: ModelConfig, hidden, batch,
                     algorithm: str, value_coef: float, entropy_coef: float,
                     ppo_clip: float, chunk: int):
    """Sequence-chunked loss: the (B, S, V) logits tensor is never
    materialized — at train_4k x 200k-vocab scale it would be hundreds of
    TB in f32. Each chunk computes logits -> per-token loss sums and is
    rematerialized in the backward pass (jax.checkpoint around the chunk
    body inside the scan)."""
    from repro.models import layers as L

    B, S, D = hidden.shape
    chunk = min(chunk, S)
    while S % chunk:          # largest divisor <= requested chunk
        chunk -= 1
    n_chunks = S // chunk
    Sc = n_chunks * chunk
    lm_head, value_head = params["lm_head"], params["value_head"]

    def split(x, width=None):
        w = width if width is not None else chunk
        return jnp.moveaxis(
            x[:, :Sc].reshape(B, n_chunks, w, *x.shape[2:]), 1, 0)

    h_c = split(hidden)
    act_c = split(batch["actions"])
    adv_c = split(batch["advantages"])
    ret_c = split(batch["returns"])
    blp_c = split(batch["behavior_logprob"])
    mask = batch.get("loss_mask")
    mask_c = split(mask) if mask is not None else jnp.ones_like(adv_c)

    def chunk_sums(h, act, adv, ret, blp, m):
        h = constrain(h, "batch", None, None)
        logits = jnp.einsum("bsd,dv->bsv", h, lm_head).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "vocab")
        logits = L.softcap(logits, cfg.final_softcap)
        values = jnp.einsum("bsd,dk->bsk", h.astype(jnp.float32),
                            value_head)[..., 0]
        logp = jax.nn.log_softmax(logits, axis=-1)
        lp = jnp.take_along_axis(logp, act[..., None], axis=-1)[..., 0]
        ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)
        adv = jax.lax.stop_gradient(adv.astype(jnp.float32))
        if algorithm == "ppo":
            ratio = jnp.exp(lp - blp.astype(jnp.float32))
            un = ratio * adv
            cl = jnp.clip(ratio, 1 - ppo_clip, 1 + ppo_clip) * adv
            pg = -(jnp.minimum(un, cl) * m)
        else:
            pg = -(lp * adv * m)
        vl = jnp.square(values - ret.astype(jnp.float32)) * m
        return (pg.sum(), vl.sum(), (ent * m).sum(), m.sum())

    chunk_sums = jax.checkpoint(chunk_sums)

    def body(carry, xs):
        sums = chunk_sums(*xs)
        return jax.tree.map(jnp.add, carry, sums), None

    init = (jnp.zeros(()), jnp.zeros(()), jnp.zeros(()), jnp.zeros(()))
    (pg, vl, ent, cnt), _ = jax.lax.scan(
        body, init, (h_c, act_c, adv_c, ret_c, blp_c, mask_c))
    denom = jnp.maximum(cnt, 1.0)
    pg, vl, ent = pg / denom, vl / denom, ent / denom
    total = pg + value_coef * vl - entropy_coef * ent
    return losses.LossStats(total, pg, vl, ent)


def rl_loss(params, cfg: ModelConfig, batch, algorithm: str = "a2c",
            value_coef: float = 0.5, entropy_coef: float = 0.01,
            ppo_clip: float = 0.2, loss_chunk: int = 512):
    hidden, aux = policy_hidden(params, cfg, batch)
    hidden = constrain(hidden, "batch", None, None)
    st = _chunked_rl_loss(params, cfg, hidden, batch, algorithm,
                          value_coef, entropy_coef, ppo_clip, loss_chunk)
    return st.total + aux, st


def make_train_step(cfg: ModelConfig, opt: Optimizer,
                    algorithm: str = "a2c",
                    n_microbatches: int = 1,
                    batch_geometry=None) -> Callable:
    """(dg_state, batch) -> (dg_state', stats). Pure; pjit-able.

    n_microbatches > 1: gradient accumulation — the global batch is
    split on its leading axis and the backward runs per slice, dividing
    activation memory by the microbatch count at no collective cost
    (grads are summed in fp32 locally; the parameter update happens
    once per logical step). ``batch_geometry`` (a
    ``repro.core.batch.BatchConfig`` or its dict form) is the typed way
    to say the same thing: its ``grad_accumulation`` sets the microbatch
    count. This learner is single-replica — replica scale-out happens in
    the sharded runtimes — so ``n_replicas`` must be unset or 1. Unlike
    the core-runtime gradient (repro.core.batch), the accumulation here
    is the sequential scan sum: the LLM-scale path makes no
    cross-factorization bit-exactness promise, only the A=1 identity
    (n_microbatches=1 runs the exact unaccumulated computation)."""
    if batch_geometry is not None:
        from repro.core.batch import BatchConfig
        bc = BatchConfig.of(batch_geometry)
        if bc.n_replicas not in (None, 1):
            raise ValueError(
                f"batch.n_replicas={bc.n_replicas}: train_step is "
                f"single-replica; use the sharded runtime for replica "
                f"scale-out")
        if n_microbatches != 1 and n_microbatches != bc.grad_accumulation:
            raise ValueError(
                f"n_microbatches={n_microbatches} conflicts with "
                f"batch.grad_accumulation={bc.grad_accumulation}; pass "
                f"one or the other")
        n_microbatches = bc.grad_accumulation

    def grad_one(params, batch):
        grad_fn = jax.value_and_grad(
            lambda p: rl_loss(p, cfg, batch, algorithm), has_aux=True)
        (_, st), grads = grad_fn(params)
        return grads, st

    def train_step(dg: delayed_grad.DelayedGradState, batch):
        if n_microbatches <= 1:
            grads, st = grad_one(dg.params_prev, batch)
        else:
            def split(x):
                B = x.shape[0] if x.ndim else 1
                if x.ndim >= 1 and B % n_microbatches == 0:
                    return jnp.moveaxis(
                        x.reshape((n_microbatches, B // n_microbatches)
                                  + x.shape[1:]), 0, 0)
                return jnp.broadcast_to(x, (n_microbatches,) + x.shape)

            def split_batch(b):
                out = {}
                for k, v in b.items():
                    if k == "mrope_positions":   # (3, B, S)
                        out[k] = jnp.moveaxis(
                            v.reshape(v.shape[0], n_microbatches, -1,
                                      v.shape[2]), 1, 0)
                    else:
                        out[k] = split(v)
                return out

            micro = split_batch(batch)

            def body(carry, mb):
                g_acc, st_acc = carry
                g, st = grad_one(dg.params_prev, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                st_acc = jax.tree.map(jnp.add, st_acc, st)
                return (g_acc, st_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), dg.params_prev)
            st0 = losses.LossStats(*(jnp.zeros(()) for _ in range(4)))
            (grads, st), _ = jax.lax.scan(body, (g0, st0), micro)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            st = jax.tree.map(lambda x: x / n_microbatches, st)
        new_dg = delayed_grad.update(dg, grads, opt)
        stats = {"loss": st.total, "pg": st.pg, "value": st.value,
                 "entropy": st.entropy}
        return new_dg, stats

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return backbone.prefill(
            params, cfg, batch["tokens"], max_len,
            positions=batch.get("positions"),
            mrope_positions=batch.get("mrope_positions"),
            patch_embeds=batch.get("patch_embeds"),
            audio_embeds=batch.get("audio_embeds"))

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """One-token decode; the actor's hot path."""

    def serve_step(params, token, cache, pos, extras=None):
        extras = extras or {}
        logits, value, new_cache = backbone.decode_step(
            params, cfg, token, cache, pos,
            mrope_positions=extras.get("mrope_positions"),
            enc_out=extras.get("enc_out"))
        return logits, value, new_cache

    return serve_step
