"""The two data storages (paper Fig. 1(e)) — double-buffered trajectory
storage.

Two views:

* ``HostStorage`` / ``DoubleBuffer`` — preallocated numpy ring storage with
  the paper's swap discipline for the threaded host runtime: the roles of
  the two storages switch only when the write storage is full AND the read
  storage is exhausted (that barrier is what bounds staleness to one).

* ``device_rollout_buffer`` — a functional pytree used by the mesh runtime,
  where the "swap" is positional in the scan carry (the freshly produced
  rollout becomes next iteration's read buffer).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ host
class HostStorage:
    """Preallocated (capacity, ...) numpy arrays + a write cursor."""

    def __init__(self, capacity: int, specs: Dict[str, tuple]):
        # specs: name -> (shape_tail, dtype)
        self.capacity = capacity
        self.data = {k: np.zeros((capacity,) + tuple(s), d)
                     for k, (s, d) in specs.items()}
        self.write_idx = 0
        self.read_count = 0

    def write(self, **items) -> None:
        i = self.write_idx
        assert i < self.capacity, "storage overflow"
        self.write_slot(i, **items)
        self.write_idx += 1

    def write_slot(self, idx: int, **items) -> None:
        """Write one transition into an explicit slot without moving the
        cursor — the executor path, where slot = t * n_envs + env_id is
        owned by exactly one executor thread (so no lock is needed for the
        array stores; ``advance`` moves the cursor under the buffer lock)."""
        for k, v in items.items():
            self.data[k][idx] = v

    def advance(self, n: int) -> None:
        """Move the write cursor after ``n`` slot writes (call with the
        owning DoubleBuffer's lock held)."""
        self.write_idx = min(self.write_idx + n, self.capacity)

    @property
    def full(self) -> bool:
        return self.write_idx >= self.capacity

    def mark_read(self) -> None:
        self.read_count += 1

    @property
    def exhausted(self) -> bool:
        return self.read_count >= 1   # learner does >=1 pass then releases

    def reset(self) -> None:
        self.write_idx = 0
        self.read_count = 0


class DoubleBuffer:
    """Two HostStorages with the HTS-RL swap barrier.

    Executors call ``write``; the learner calls ``acquire_read`` /
    ``release_read``. ``swap`` blocks until (write full) & (read exhausted),
    which is exactly the synchronization in Sec. 4.1 — it bounds the
    behavior/target lag at one and is the price of determinism.
    """

    def __init__(self, capacity: int, specs: Dict[str, tuple]):
        self.storages = [HostStorage(capacity, specs),
                         HostStorage(capacity, specs)]
        self.write_role = 0
        self.cv = threading.Condition()
        self.generation = 0
        self._first = True

    @property
    def write_storage(self) -> HostStorage:
        return self.storages[self.write_role]

    @property
    def read_storage(self) -> HostStorage:
        return self.storages[1 - self.write_role]

    def writer_wait_until_writable(self, timeout=None) -> bool:
        with self.cv:
            return self.cv.wait_for(
                lambda: not self.write_storage.full, timeout=timeout)

    def write(self, **items) -> None:
        with self.cv:
            self.write_storage.write(**items)
            if self.write_storage.full:
                self.cv.notify_all()

    def reader_acquire(self, timeout=None) -> Optional[HostStorage]:
        """Block until a full storage is available to read; returns it."""
        with self.cv:
            ok = self.cv.wait_for(lambda: self.write_storage.full,
                                  timeout=timeout)
            if not ok:
                return None
            return self.write_storage

    def swap(self) -> None:
        """Called by the coordinator once learner + executors both finished
        their interval: the just-written storage becomes readable and the
        (now exhausted) read storage is recycled for writing."""
        with self.cv:
            self.read_storage.reset()
            self.write_role = 1 - self.write_role
            self.generation += 1
            self.cv.notify_all()


# ---------------------------------------------------------------- device
def device_rollout_buffer(n_envs: int, alpha: int, obs_shape, obs_dtype,
                          action_dtype=jnp.int32):
    """Zero-initialized (alpha, n_envs, ...) trajectory pytree for the mesh
    runtime's scan carry. The double buffer is positional: the learner reads
    the carry slot while the rollout fills a fresh pytree; the new pytree
    replaces the carry slot at the end of the interval."""
    return {
        "obs": jnp.zeros((alpha, n_envs) + tuple(obs_shape), obs_dtype),
        "actions": jnp.zeros((alpha, n_envs), action_dtype),
        "rewards": jnp.zeros((alpha, n_envs), jnp.float32),
        "dones": jnp.ones((alpha, n_envs), jnp.float32),
        "behavior_logprob": jnp.zeros((alpha, n_envs), jnp.float32),
        "bootstrap_obs": jnp.zeros((n_envs,) + tuple(obs_shape), obs_dtype),
    }
